"""Unit + property tests for the derived-GP gradient surrogate (paper eq. 4-5)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import gp_surrogate as gp


def _fit(key, f, n, d, cap, noise=0.0):
    xs = jax.random.uniform(key, (n, d))
    ys = jax.vmap(f)(xs)
    if noise:
        ys = ys + noise * jax.random.normal(jax.random.fold_in(key, 7), (n,))
    traj = gp.traj_init(cap, d)
    return gp.traj_append_batch(traj, xs, ys), xs, ys


def test_grad_mean_matches_autodiff_of_posterior():
    f = lambda x: jnp.sum(jnp.sin(2 * x)) + jnp.sum(x**2)
    traj, _, _ = _fit(jax.random.PRNGKey(0), f, 40, 6, 64)
    hyper = gp.default_hyper(0.5, 1e-5)
    xq = jnp.full((6,), 0.3)
    g_closed = gp.grad_mean(traj, hyper, xq)
    g_auto = jax.grad(lambda x: gp.mean_value(traj, hyper, x))(xq)
    np.testing.assert_allclose(np.asarray(g_closed), np.asarray(g_auto), atol=2e-4)


def test_grad_mean_approximates_true_gradient_with_dense_data():
    f = lambda x: jnp.sum(x**2)
    traj, _, _ = _fit(jax.random.PRNGKey(1), f, 200, 2, 256)
    hyper = gp.default_hyper(0.4, 1e-5)
    xq = jnp.array([0.5, 0.4])
    g = gp.grad_mean(traj, hyper, xq)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * xq), atol=0.05)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(3, 20),
    extra_cap=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_padding_invariance(n, extra_cap, seed):
    """The masked padded Gram solve must equal the exact-capacity solve."""
    d = 3
    key = jax.random.PRNGKey(seed)
    f = lambda x: jnp.sum(jnp.cos(3 * x))
    hyper = gp.default_hyper(0.7, 1e-4)
    xq = jax.random.uniform(jax.random.fold_in(key, 1), (d,))

    t_exact, xs, ys = _fit(key, f, n, d, n)
    t_padded = gp.traj_append_batch(gp.traj_init(n + extra_cap, d), xs, ys)
    g1 = gp.grad_mean(t_exact, hyper, xq)
    g2 = gp.grad_mean(t_padded, hyper, xq)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=5e-4)

    u1 = gp.grad_uncertainty_trace(t_exact, hyper, xq)
    u2 = gp.grad_uncertainty_trace(t_padded, hyper, xq)
    np.testing.assert_allclose(float(u1), float(u2), rtol=1e-2, atol=1e-4)


def test_ring_buffer_overwrites_oldest():
    traj = gp.traj_init(4, 2)
    for i in range(6):
        traj = gp.traj_append(traj, jnp.full((2,), float(i)), jnp.asarray(float(i)))
    assert int(traj.count) == 6
    assert int(traj.n_valid()) == 4
    vals = sorted(np.asarray(traj.ys).tolist())
    assert vals == [2.0, 3.0, 4.0, 5.0]  # 0 and 1 evicted


def test_uncertainty_decreases_with_data():
    f = lambda x: jnp.sum(x)
    hyper = gp.default_hyper(0.5, 1e-4)
    xq = jnp.full((3,), 0.5)
    key = jax.random.PRNGKey(3)
    t_small, xs, ys = _fit(key, f, 5, 3, 64)
    t_big = gp.traj_append_batch(
        t_small, jax.random.uniform(jax.random.fold_in(key, 2), (40, 3)),
        jnp.zeros((40,)),
    )
    assert float(gp.grad_uncertainty_trace(t_big, hyper, xq)) <= float(
        gp.grad_uncertainty_trace(t_small, hyper, xq)
    ) + 1e-6


def test_empty_trajectory_gives_zero_gradient_and_prior_uncertainty():
    traj = gp.traj_init(16, 4)
    hyper = gp.default_hyper(1.0, 1e-4)
    xq = jnp.full((4,), 0.5)
    np.testing.assert_allclose(np.asarray(gp.grad_mean(traj, hyper, xq)), 0.0)
    np.testing.assert_allclose(float(gp.grad_uncertainty_trace(traj, hyper, xq)), 4.0, rtol=1e-5)


def test_active_query_selection_prefers_unseen_regions():
    f = lambda x: jnp.sum(x)
    key = jax.random.PRNGKey(4)
    # all data clustered at 0.2; candidates near 0.8 should score higher
    xs = 0.2 + 0.01 * jax.random.normal(key, (30, 2))
    traj = gp.traj_append_batch(gp.traj_init(64, 2), xs, jnp.zeros((30,)))
    hyper = gp.default_hyper(0.3, 1e-4)
    scores_near = gp.grad_uncertainty_batch(traj, hyper, jnp.full((1, 2), 0.2))
    scores_far = gp.grad_uncertainty_batch(traj, hyper, jnp.full((1, 2), 0.8))
    assert float(scores_far[0]) > float(scores_near[0])
    sel = gp.select_active_queries(key, traj, hyper, jnp.full((2,), 0.5), 20, 5, 0.05)
    assert sel.shape == (5, 2)
    assert bool(jnp.all((sel >= 0.0) & (sel <= 1.0)))
