"""Mamba2/SSD correctness: the chunked scan must equal a step-by-step
recurrence oracle, and the decode step must continue the prefill state."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import ssm as S


def _naive_ssd(x, dt, a, bmat, cmat, h0=None):
    """Step-by-step oracle: h_t = exp(dt_t a) h_{t-1} + dt_t x_t B_t^T;
    y_t = C_t . h_t.  All f64 for reference."""
    bsz, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    x = np.asarray(x, np.float64)
    dt = np.asarray(dt, np.float64)
    a = np.asarray(a, np.float64)
    bm = np.repeat(np.asarray(bmat, np.float64), rep, axis=2)
    cm = np.repeat(np.asarray(cmat, np.float64), rep, axis=2)
    hstate = np.zeros((bsz, h, p, n)) if h0 is None else np.asarray(h0, np.float64)
    ys = np.zeros((bsz, l, h, p))
    for t in range(l):
        decay = np.exp(dt[:, t] * a[None, :])  # (B, H)
        inp = np.einsum("bh,bhp,bhn->bhpn", dt[:, t], x[:, t], bm[:, t])
        hstate = hstate * decay[:, :, None, None] + inp
        ys[:, t] = np.einsum("bhpn,bhn->bhp", hstate, cm[:, t])
    return ys, hstate


def _rand_inputs(key, bsz, l, h, p, g, n):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (bsz, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, h)) - 1.0)
    a = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=-1.0, maxval=1.0))
    bmat = jax.random.normal(ks[3], (bsz, l, g, n))
    cmat = jax.random.normal(jax.random.fold_in(key, 9), (bsz, l, g, n))
    return x, dt, a, bmat, cmat


@pytest.mark.parametrize("l,chunk", [(32, 8), (33, 8), (16, 16), (7, 32)])
def test_ssd_scan_matches_naive_recurrence(l, chunk):
    cfg = dataclasses.replace(get_config("mamba2_370m", "smoke"), ssm_chunk=chunk)
    x, dt, a, bmat, cmat = _rand_inputs(jax.random.PRNGKey(0), 2, l, 4, 8, 1, 16)
    y, hfin = S.ssd_scan(cfg, x, dt, a, bmat, cmat)
    y_ref, h_ref = _naive_ssd(x, dt, a, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hfin), h_ref, atol=2e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), l=st.integers(4, 40))
def test_ssd_padding_property(seed, l):
    """Padding the sequence to a chunk multiple never changes outputs."""
    cfg = dataclasses.replace(get_config("mamba2_370m", "smoke"), ssm_chunk=16)
    x, dt, a, bmat, cmat = _rand_inputs(jax.random.PRNGKey(seed), 1, l, 2, 4, 1, 8)
    y, hfin = S.ssd_scan(cfg, x, dt, a, bmat, cmat)
    y_ref, h_ref = _naive_ssd(x, dt, a, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=3e-3, rtol=3e-3)
    np.testing.assert_allclose(np.asarray(hfin), h_ref, atol=3e-3, rtol=3e-3)


def test_ssd_initial_state_continuation():
    """Scanning [first half] then [second half with h0] == scanning all."""
    cfg = dataclasses.replace(get_config("mamba2_370m", "smoke"), ssm_chunk=8)
    x, dt, a, bmat, cmat = _rand_inputs(jax.random.PRNGKey(3), 1, 24, 2, 4, 1, 8)
    y_all, h_all = S.ssd_scan(cfg, x, dt, a, bmat, cmat)
    y1, h1 = S.ssd_scan(cfg, x[:, :12], dt[:, :12], a, bmat[:, :12], cmat[:, :12])
    y2, h2 = S.ssd_scan(cfg, x[:, 12:], dt[:, 12:], a, bmat[:, 12:], cmat[:, 12:], h0=h1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, 12:]), atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_all), atol=2e-3, rtol=1e-3)


def test_decode_step_continues_recurrence():
    """One ssm_block_decode call == one more step of the naive recurrence,
    via the full block train/decode consistency at f32."""
    cfg = dataclasses.replace(get_config("mamba2_370m", "smoke"), dtype="float32")
    from repro.models.params import init_params
    from repro.models.model import _block_params

    key = jax.random.PRNGKey(0)
    p = init_params(key, cfg)
    bp = {k: v[0] for k, v in _block_params(p).items()}
    sp = S.pick_ssm(bp, "")
    x = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (1, 9, cfg.d_model), jnp.float32)

    # full-sequence block output at the last position
    y_full = S.ssm_block_train(sp, x, cfg)

    # prefill state from first 8 steps by replaying decode 9 times
    cache = S.init_ssm_cache(cfg, 1)
    for t in range(9):
        y_dec, cache = S.ssm_block_decode(sp, x[:, t : t + 1], cfg, cache)
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0]), np.asarray(y_full[:, -1]), atol=2e-4, rtol=1e-3
    )
