"""Partial-participation client pool tests (core/pool.py, DESIGN.md Sec. 9).

Covers the three layers of the pool engine:

  * the deterministic cohort sampler (pure in (seed, round, N, K), identity
    at K = N, loud validation);
  * the host-resident pool store (bitwise gather/scatter round trip,
    batched init == one-shot init == the dense engine's init);
  * the pooled round driver (K = N BITWISE equal to the dense engine on
    both front doors -- the equivalence oracle; resumed runs match
    uninterrupted ones; ONE cohort executable across sampled cohorts;
    fault rollback and quarantine re-admission before scatter-back).
"""

import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core import algorithms as alg
from repro.core import objectives as obj
from repro.core import pool as pool_mod
from repro.core import rff as rfflib
from repro.core import rounds as rounds_mod
from repro.core.federated import run_distributed
from repro.faults import FaultConfig, corrupt

ROUNDS = 8


@pytest.fixture(scope="module")
def quad():
    return obj.make_quadratic(jax.random.PRNGKey(0), 4, 8, 2.0, 0.001)


@pytest.fixture(scope="module")
def quad8():
    return obj.make_quadratic(jax.random.PRNGKey(0), 8, 8, 2.0, 0.001)


def _fzoos_cfg(**kw):
    base = dict(name="fzoos", dim=8, n_clients=4, local_steps=3,
                n_features=32, traj_capacity=32, active_per_iter=1,
                active_candidates=8, active_round_end=1, lengthscale=0.5)
    base.update(kw)
    return alg.AlgoConfig(**base)


def _sim(cfg, cobjs, rounds=ROUNDS, **kw):
    return alg.simulate(cfg, jax.random.PRNGKey(5), cobjs, obj.quadratic_query,
                        obj.quadratic_global_value, rounds, **kw)


def _dist(cfg, cobjs, rounds=ROUNDS, **kw):
    mesh = jax.make_mesh((1,), ("data",))
    return run_distributed(cfg, mesh, jax.random.PRNGKey(5), cobjs,
                           obj.quadratic_query, obj.quadratic_global_value,
                           rounds, **kw)


def _assert_results_equal(r0, r1):
    for field in r0._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r0, field)), np.asarray(getattr(r1, field)),
            err_msg=field,
        )


# ---------------------------------------------------------------------------
# Cohort sampler
# ---------------------------------------------------------------------------


def test_sample_cohort_deterministic_and_valid():
    a = pool_mod.sample_cohort(7, 3, 16, 5)
    b = pool_mod.sample_cohort(7, 3, 16, 5)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (5,)
    assert len(np.unique(a)) == 5  # without replacement
    assert a.min() >= 0 and a.max() < 16
    assert (np.diff(a) > 0).all()  # sorted: pool order == batch order
    # keyed on the absolute round: different rounds draw different cohorts
    c = pool_mod.sample_cohort(7, 4, 16, 5)
    assert not np.array_equal(a, c)
    # and on the seed
    d = pool_mod.sample_cohort(8, 3, 16, 5)
    assert not np.array_equal(a, d)


def test_sample_cohort_identity_at_full_participation():
    np.testing.assert_array_equal(pool_mod.sample_cohort(3, 9, 6, 6),
                                  np.arange(6))


def test_sample_cohort_validation():
    with pytest.raises(ValueError, match="cohort"):
        pool_mod.sample_cohort(0, 0, 8, 0)
    with pytest.raises(ValueError, match="cohort"):
        pool_mod.sample_cohort(0, 0, 8, 9)


# ---------------------------------------------------------------------------
# The pool store
# ---------------------------------------------------------------------------


def test_init_pool_matches_dense_init():
    """batch=None pool init is BITWISE the dense engine's init_states."""
    cfg = _fzoos_cfg(n_clients=8)
    x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    key = jax.random.PRNGKey(2)
    pool = pool_mod.init_pool(cfg, key, x0)
    dense = alg.init_states(cfg, key, x0)
    for a, b in zip(pool.leaves, jax.tree_util.tree_leaves(dense)):
        np.testing.assert_array_equal(a, np.asarray(jax.device_get(b)))


def test_init_pool_batched_matches_oneshot():
    """Initializing 3 clients at a time never changes the pool contents:
    per-client RNG comes from one up-front split over all N."""
    cfg = _fzoos_cfg(n_clients=8)
    x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    key = jax.random.PRNGKey(2)
    one = pool_mod.init_pool(cfg, key, x0)
    sliced = pool_mod.init_pool(cfg, key, x0, batch=3)
    for a, b in zip(one.leaves, sliced.leaves):
        np.testing.assert_array_equal(a, b)


def test_gather_scatter_roundtrip_bitwise():
    cfg = _fzoos_cfg(n_clients=8)
    x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    pool = pool_mod.init_pool(cfg, jax.random.PRNGKey(2), x0)
    before = [a.copy() for a in pool.leaves]
    idx = pool_mod.sample_cohort(0, 0, 8, 3)
    pool.scatter(idx, pool.gather(idx))
    for a, b in zip(pool.leaves, before):
        np.testing.assert_array_equal(a, b)


def test_scatter_validates_structure():
    cfg = _fzoos_cfg(n_clients=8)
    x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    pool = pool_mod.init_pool(cfg, jax.random.PRNGKey(2), x0)
    idx = np.arange(3)
    with pytest.raises(ValueError, match="structure"):
        pool.scatter(idx, {"not": "a client state"})


# ---------------------------------------------------------------------------
# K = N: the bitwise equivalence oracle
# ---------------------------------------------------------------------------


def test_full_participation_bitwise_sim(quad):
    """cohort == n_clients through the simulate front door is BITWISE the
    dense engine: identity sampling, same init, and the zero-rate masked
    aggregation the pooled body always runs reduces to the dense mean."""
    cfg = _fzoos_cfg()
    r_dense = _sim(cfg, quad, chunk=4)
    r_pool = _sim(cfg, quad, chunk=4, cohort=4)
    _assert_results_equal(r_dense, r_pool)


def test_full_participation_bitwise_distributed(quad):
    cfg = _fzoos_cfg()
    r_dense = _dist(cfg, quad, chunk=4)
    r_pool = _dist(cfg, quad, chunk=4, cohort=4)
    _assert_results_equal(r_dense, r_pool)


def test_cohort_requires_scan_driver(quad):
    cfg = _fzoos_cfg()
    with pytest.raises(ValueError, match="chunk"):
        _sim(cfg, quad, chunk=0, cohort=4)


# ---------------------------------------------------------------------------
# K < N: partial participation
# ---------------------------------------------------------------------------


def test_partial_participation_optimizes(quad8):
    """K=4 of N=8: the run stays finite and optimizes; only cohort-sized
    state ever exists on device (the dense K-client mesh footprint)."""
    cfg = _fzoos_cfg(n_clients=8)
    r = _sim(cfg, quad8, rounds=12, chunk=4, cohort=4)
    f = np.asarray(r.f_values)
    assert np.isfinite(f).all()
    assert f[-1] < f[0]


def test_cohort_schedule_topology_independent(quad8):
    """The sampler keys on (seed, round) only, so vmap and shard_map runs
    draw the SAME cohorts: identical query accounting, and iterates within
    the same bounded reduction-order divergence the dense engines show
    (vmap mean vs psum mean, cf. test_faults sim-vs-distributed)."""
    cfg = _fzoos_cfg(n_clients=8)
    r_sim = _sim(cfg, quad8, chunk=4, cohort=4)
    r_dist = _dist(cfg, quad8, chunk=4, cohort=4)
    np.testing.assert_array_equal(np.asarray(r_sim.queries),
                                  np.asarray(r_dist.queries))
    np.testing.assert_allclose(np.asarray(r_sim.xs), np.asarray(r_dist.xs),
                               atol=0.1)


def test_one_executable_serves_every_cohort(quad8):
    """The chunk step is keyed on K, not on the member ids: after the first
    cohort compiles it, every later cohort (different rows, same (K, ...)
    shapes) is a cache hit -- zero recompiles across the sweep."""
    from repro.analysis import no_recompiles

    cfg = _fzoos_cfg(n_clients=8)
    ccfg = dataclasses.replace(cfg, n_clients=4)
    x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    rff = rfflib.make_rff(jax.random.PRNGKey(1), cfg.n_features, cfg.dim,
                          cfg.lengthscale)
    pool = pool_mod.init_pool(cfg, jax.random.PRNGKey(2), x0)
    cobjs_host = jax.tree_util.tree_map(
        lambda a: np.asarray(jax.device_get(a)), quad8)
    step = rounds_mod.make_chunk_step(rounds_mod.sim_chunk_fn(
        ccfg, rff, obj.quadratic_query, obj.quadratic_global_value, None,
        2, 1, 6, faults=FaultConfig(),
    ))
    hist = rounds_mod.history_init(6, x0, obj.quadratic_global_value(quad8, x0))

    def boundary(off, hist, sx):
        idx = pool_mod.sample_cohort(0, off, 8, 4)
        cstates = pool.gather(idx)
        cco = jax.tree_util.tree_map(lambda a: jnp.asarray(a[idx]), cobjs_host)
        cstates, hist, sx = step(cstates, hist, cco, sx, jnp.int32(off))
        pool.scatter(idx, cstates)
        return hist, sx

    hist, sx = boundary(0, hist, x0)  # warm the one executable
    with no_recompiles() as g:
        for off in (2, 4):
            hist, sx = boundary(off, hist, sx)
    assert g.compiles == 0


# ---------------------------------------------------------------------------
# Checkpoint / resume / rollback
# ---------------------------------------------------------------------------


def test_pooled_resume_bitwise(quad8, tmp_path):
    """A pooled run killed mid-way resumes from the newest checkpoint and
    finishes BITWISE identical to the uninterrupted run: the cohort
    schedule keys on the absolute round, so the replayed boundary re-draws
    the same cohorts."""
    cfg = _fzoos_cfg(n_clients=8, local_steps=2)
    d = str(tmp_path / "ck")
    r_full = _sim(cfg, quad8, chunk=2, cohort=4, checkpoint_dir=d)
    assert ckpt_io.list_steps(d) == [2, 4, 6, 8]
    for dname in os.listdir(d):
        if int(dname.split("_")[1]) > 4:
            shutil.rmtree(os.path.join(d, dname))
    r_res = _sim(cfg, quad8, chunk=2, cohort=4, checkpoint_dir=d)
    _assert_results_equal(r_full, r_res)


def test_pooled_resume_falls_back_past_corrupt_step(quad8, tmp_path):
    cfg = _fzoos_cfg(n_clients=8, local_steps=2)
    d = str(tmp_path / "ck")
    r_full = _sim(cfg, quad8, chunk=2, cohort=4, checkpoint_dir=d)
    corrupt.flip_bytes(d, ckpt_io.list_steps(d)[-1])
    r_res = _sim(cfg, quad8, chunk=2, cohort=4, checkpoint_dir=d)
    _assert_results_equal(r_full, r_res)


def test_pooled_resume_identity_includes_cohort(quad8, tmp_path):
    """A pool checkpoint dir refuses to resume under a different cohort
    size or sampler seed (the schedule is part of the run identity)."""
    cfg = _fzoos_cfg(n_clients=8, local_steps=2)
    d = str(tmp_path / "ck")
    _sim(cfg, quad8, rounds=4, chunk=2, cohort=4, checkpoint_dir=d)
    with pytest.raises(ValueError, match="cohort"):
        _sim(cfg, quad8, rounds=4, chunk=2, cohort=2, checkpoint_dir=d)
    with pytest.raises(ValueError, match="cohort_seed"):
        _sim(cfg, quad8, rounds=4, chunk=2, cohort=4, cohort_seed=1,
             checkpoint_dir=d)


def test_pooled_rollback_recovers_poisoned_run(quad8, tmp_path, capsys):
    """tolerate=False + NaN faults under partial participation: the
    boundary health check catches the poisoned iterate BEFORE it scatters
    into the pool, rolls {pool, history} back and re-runs with tolerance
    forced on."""
    cfg = _fzoos_cfg(n_clients=8)
    fcfg = FaultConfig(seed=3, nan_rate=0.3, tolerate=False)
    d = str(tmp_path / "ck")
    r = _sim(cfg, quad8, chunk=4, cohort=4, checkpoint_dir=d, faults=fcfg)
    assert np.isfinite(np.asarray(r.f_values)).all()
    assert np.isfinite(np.asarray(r.xs)).all()
    out = capsys.readouterr().out
    assert "ROLLBACK" in out and "FORCED ON" in out


def test_pooled_faults_quarantine_never_persists(quad8):
    """Quarantined cohort members are re-admitted at the boundary before
    scatter-back: no client ever sits in the pool quarantined."""
    cfg = _fzoos_cfg(n_clients=8)
    x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    rff = rfflib.make_rff(jax.random.PRNGKey(1), cfg.n_features, cfg.dim,
                          cfg.lengthscale)
    pool = pool_mod.init_pool(cfg, jax.random.PRNGKey(2), x0)
    fcfg = FaultConfig(seed=3, nan_rate=0.3)
    pool, hist = pool_mod.run_pooled_rounds(
        cfg, rff, obj.quadratic_query, quad8, pool, x0,
        obj.quadratic_global_value, ROUNDS, 4, cohort=4, faults=fcfg,
    )
    assert np.asarray(hist.quarantine_rate).max() > 0  # faults did fire
    states = pool.gather(np.arange(8))
    assert not np.asarray(states.quarantined).any()
    for leaf in pool.leaves:
        if np.issubdtype(leaf.dtype, np.floating):
            assert np.isfinite(leaf).all()


# ---------------------------------------------------------------------------
# Static contracts
# ---------------------------------------------------------------------------


def test_pool_contracts_clean():
    from repro.analysis import contracts

    for name in ("fzoos-pool/simulate", "fzoos-pool/distributed",
                 "fedzo-pool/simulate", "fedzo-pool/distributed"):
        violations = contracts.check_contract(name)
        assert violations == [], f"{name}: {violations}"


# ---------------------------------------------------------------------------
# Launcher flag surface
# ---------------------------------------------------------------------------


def test_pool_flags_validated():
    import argparse

    from repro.launch import common

    ap = argparse.ArgumentParser()
    common.add_pool_flags(ap)
    args = ap.parse_args(["--pool-size", "16"])
    with pytest.raises(SystemExit, match="cohort"):
        common.pool_from_args(args)
    args = ap.parse_args(["--pool-size", "16", "--cohort", "4"])
    assert common.pool_from_args(args) == (16, 4)
    args = ap.parse_args(["--cohort", "0"])
    with pytest.raises(SystemExit, match="cohort"):
        common.pool_from_args(args)
