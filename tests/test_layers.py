"""Layer primitives: RoPE / M-RoPE properties, masks, norms, MoE invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import layers as L


def test_rmsnorm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32))
    y = L.rmsnorm(x, jnp.ones((32,)))
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    y = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=1e-4,
    )


def test_rope_relative_position_property():
    """q_m . k_n depends only on (m - n)."""
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))

    def score(m, n):
        qm = L.apply_rope(q, jnp.full((1, 1), m), 10_000.0)
        kn = L.apply_rope(k, jnp.full((1, 1), n), 10_000.0)
        return float(jnp.sum(qm * kn))

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(7, 0) == pytest.approx(score(107, 100), rel=1e-4)


def test_mrope_reduces_to_rope_for_equal_components():
    """With t == h == w positions, M-RoPE must equal standard RoPE."""
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 2, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[..., None], (2, 8, 3))
    y1 = L.apply_rope(x, pos, 10_000.0, "standard")
    y2 = L.apply_rope(x, pos3, 10_000.0, "mrope", sections=(8, 12, 12))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_attn_mask_causal_and_window():
    m = L._attn_mask(6, 6, causal=True, window=0)
    assert bool(m[3, 3]) and bool(m[3, 0]) and not bool(m[3, 4])
    mw = L._attn_mask(6, 6, causal=True, window=2)
    assert bool(mw[3, 3]) and bool(mw[3, 2]) and not bool(mw[3, 1])


def test_sliding_window_limits_attention_reach():
    """With window w, changing a token > w steps back cannot change output."""
    cfg = dataclasses.replace(
        get_config("llama4_scout_17b_16e", "smoke"), sliding_window=8, n_experts=4,
        moe_capacity_factor=8.0,
    )
    from repro.models.params import init_params
    from repro.models.model import _block_params

    p = init_params(jax.random.PRNGKey(0), cfg)
    bp = {k: v[0] for k, v in _block_params(p).items()}
    ap = L.pick_attn(bp, "attn.")
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 24, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(24)[None], (1, 24))
    y1 = L.attn_block(ap, x.astype(jnp.bfloat16), cfg, pos, window=8)
    x2 = x.at[0, 2].add(5.0)  # token 2 is > 8 steps behind position 23
    y2 = L.attn_block(ap, x2.astype(jnp.bfloat16), cfg, pos, window=8)
    np.testing.assert_allclose(
        np.asarray(y1[0, -1], np.float32), np.asarray(y2[0, -1], np.float32), atol=1e-6
    )
    # sanity: WITHOUT the window the same edit does propagate
    y3 = L.attn_block(ap, x2.astype(jnp.bfloat16), cfg, pos, window=0)
    y0 = L.attn_block(ap, x.astype(jnp.bfloat16), cfg, pos, window=0)
    assert float(jnp.abs(y3[0, -1] - y0[0, -1]).astype(jnp.float32).max()) > 0


def test_gqa_repeat_kv():
    k = jnp.arange(2 * 3 * 2 * 4, dtype=jnp.float32).reshape(2, 3, 2, 4)
    kr = L._repeat_kv(k, 6)
    assert kr.shape == (2, 3, 6, 4)
    np.testing.assert_allclose(np.asarray(kr[:, :, 0]), np.asarray(kr[:, :, 2]))
    np.testing.assert_allclose(np.asarray(kr[:, :, 3]), np.asarray(kr[:, :, 5]))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_moe_gate_normalization_and_aux(seed):
    cfg = dataclasses.replace(
        get_config("llama4_scout_17b_16e", "smoke"), moe_capacity_factor=8.0
    )
    from repro.models.params import init_params

    p = init_params(jax.random.PRNGKey(seed % 100), cfg)
    bp = {k[len("blocks/") :]: v[0] for k, v in p.items() if k.startswith("blocks/")}
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(seed), (2, 8, cfg.d_model), jnp.bfloat16)
    y, aux = L.moe_block(bp, "mlp.", x, cfg, return_aux=True)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    # balanced-router aux is ~1, catastrophic imbalance pushes it towards E
    assert 0.5 < float(aux) < cfg.n_experts + 1


def test_moe_capacity_zero_drop_equals_full_dispatch():
    """With capacity >= T*k no token drops: output must be a weighted sum of
    per-expert MLPs applied to every token (dense oracle)."""
    cfg = dataclasses.replace(
        get_config("llama4_scout_17b_16e", "smoke"),
        moe_capacity_factor=8.0, n_shared_experts=0,
    )
    from repro.models.params import init_params

    p = init_params(jax.random.PRNGKey(0), cfg)
    bp = {k[len("blocks/") :]: v[0] for k, v in p.items() if k.startswith("blocks/")}
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 6, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
    y = L.moe_block(bp, "mlp.", x, cfg)

    # dense oracle
    xn = L.rmsnorm(x, bp["mlp.ln"], cfg.norm_eps)
    t = xn.reshape(-1, cfg.d_model)
    logits = t.astype(jnp.float32) @ bp["mlp.router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for tok in range(t.shape[0]):
        acc = 0.0
        for slot in range(cfg.moe_top_k):
            e = int(idx[tok, slot])
            h = jax.nn.silu(t[tok] @ bp["mlp.we_gate"][e]) * (t[tok] @ bp["mlp.we_up"][e])
            acc = acc + gate[tok, slot] * (h @ bp["mlp.we_down"][e])
        outs.append(acc)
    oracle = jnp.stack(outs).reshape(1, 6, cfg.d_model)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(oracle, np.float32), atol=3e-2, rtol=3e-2
    )
