"""Fault-tolerant round engine tests (repro.faults + DESIGN.md Sec. 8).

Covers the three layers of the fault model:

  * the deterministic injector (reproducible, topology-independent,
    precedence- and window-correct draws);
  * the masked engine (every fault kind on both front doors; the faults-off
    BITWISE guarantee; quarantine reset == fresh-init oracle);
  * storage recovery (per-leaf checksums reject torn/bit-flipped
    checkpoints, resume falls back to the newest good step, chunk rollback
    re-runs a poisoned run to completion, writer retries transient I/O).

Scan-vs-oracle comparisons are bounded, not bitwise, for the same reason as
test_rounds.py: the quarantine-reset cadence differs (per round vs per
chunk boundary) inside the engine's bounded-divergence contract.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core import algorithms as alg
from repro.core import objectives as obj
from repro.core import rounds as rounds_mod
from repro.core.federated import run_distributed
from repro.faults import FaultConfig, corrupt, draw_faults, schedule_table

ROUNDS = 8


@pytest.fixture(scope="module")
def quad():
    return obj.make_quadratic(jax.random.PRNGKey(0), 4, 8, 2.0, 0.001)


def _fzoos_cfg(**kw):
    base = dict(name="fzoos", dim=8, n_clients=4, local_steps=3,
                n_features=32, traj_capacity=32, active_per_iter=1,
                active_candidates=8, active_round_end=1, lengthscale=0.5)
    base.update(kw)
    return alg.AlgoConfig(**base)


def _sim(cfg, quad, rounds=ROUNDS, **kw):
    return alg.simulate(cfg, jax.random.PRNGKey(5), quad, obj.quadratic_query,
                        obj.quadratic_global_value, rounds, **kw)


def _dist(cfg, quad, rounds=ROUNDS, **kw):
    mesh = jax.make_mesh((1,), ("data",))
    return run_distributed(cfg, mesh, jax.random.PRNGKey(5), quad,
                           obj.quadratic_query, obj.quadratic_global_value,
                           rounds, **kw)


# ---------------------------------------------------------------------------
# Injector
# ---------------------------------------------------------------------------


def test_draws_deterministic_and_identity_keyed():
    fcfg = FaultConfig(seed=7, drop_rate=0.3, straggle_rate=0.2, nan_rate=0.2,
                       inf_rate=0.2)
    ids = jnp.arange(8, dtype=jnp.int32)
    d1 = draw_faults(fcfg, jnp.int32(3), ids)
    d2 = draw_faults(fcfg, jnp.int32(3), ids)
    for k in d1._fields:
        np.testing.assert_array_equal(np.asarray(getattr(d1, k)),
                                      np.asarray(getattr(d2, k)))
    # draws key on CLIENT IDENTITY, not batch position: permuting the id
    # vector permutes the masks identically (topology independence)
    perm = np.array([5, 2, 7, 0, 1, 3, 4, 6])
    dp = draw_faults(fcfg, jnp.int32(3), jnp.asarray(perm, jnp.int32))
    for k in d1._fields:
        np.testing.assert_array_equal(np.asarray(getattr(dp, k)),
                                      np.asarray(getattr(d1, k))[perm])


def test_schedule_precedence_and_window():
    fcfg = FaultConfig(seed=1, drop_rate=0.4, straggle_rate=0.4, nan_rate=0.4,
                       inf_rate=0.4)
    tab = schedule_table(fcfg, 20, 8)
    assert tab["drop"].any() and tab["nan"].any()
    # a dropped client sends nothing: it cannot also straggle or poison
    assert not (tab["drop"] & tab["straggle"]).any()
    assert not (tab["drop"] & tab["nan"]).any()
    assert not (tab["drop"] & tab["inf"]).any()
    # nan wins over inf when both fire
    assert not (tab["nan"] & tab["inf"]).any()
    # the injection window gates every kind on the absolute round index
    wcfg = dataclasses.replace(fcfg, first_round=5, last_round=12)
    wtab = schedule_table(wcfg, 20, 8)
    for k in wtab:
        assert not wtab[k][:5].any() and not wtab[k][12:].any()
        np.testing.assert_array_equal(wtab[k][5:12], tab[k][5:12])


def test_zero_rate_config_draws_nothing():
    tab = schedule_table(FaultConfig(), 5, 4)
    for k in tab:
        assert not tab[k].any()


def test_rate_validation():
    with pytest.raises(ValueError):
        FaultConfig(drop_rate=1.5)
    with pytest.raises(ValueError):
        FaultConfig(nan_rate=-0.1)


def test_schedule_table_matches_per_round_draws():
    """Regression: the vmapped one-dispatch schedule_table must be BITWISE
    the per-round draw_faults loop it replaced (same fold_in keying per
    row), window gating included."""
    fcfg = FaultConfig(seed=9, drop_rate=0.3, straggle_rate=0.2, nan_rate=0.2,
                       inf_rate=0.1, first_round=2, last_round=15)
    tab = schedule_table(fcfg, 20, 6)
    ids = jnp.arange(6, dtype=jnp.int32)
    for r in range(20):
        d = draw_faults(fcfg, jnp.int32(r), ids)
        for k in d._fields:
            np.testing.assert_array_equal(tab[k][r], np.asarray(getattr(d, k)),
                                          err_msg=f"round {r} kind {k}")


def test_statically_empty_window_never_injects():
    """A config whose [first_round, last_round) window is empty can never
    fire, whatever the rates: ``injects`` is False and the engine treats it
    as faults=None."""
    from repro.faults.injector import effective_config

    assert not FaultConfig(nan_rate=0.5, first_round=5, last_round=5).injects
    assert not FaultConfig(nan_rate=0.5, first_round=7, last_round=3).injects
    # a non-empty window starting past the horizon injects in principle but
    # is never ACTIVE inside this run: effective_config normalizes to None
    late = FaultConfig(nan_rate=0.5, first_round=100)
    assert late.injects and not late.active_in(8)
    assert effective_config(late, 8) is None
    assert effective_config(late, 200) is late
    # zero rates pass through unchanged: an explicit --fault-tolerance
    # masked-engine opt-in must keep selecting the masked engine
    z = FaultConfig()
    assert effective_config(z, 8) is z
    assert effective_config(None, 8) is None


# ---------------------------------------------------------------------------
# Masked engine
# ---------------------------------------------------------------------------


def test_faults_off_bitwise_sim(quad):
    """An all-zero-rate tolerant config must be BITWISE identical to
    faults=None: zero rates lower to static constants, and the masked
    aggregation (sum / live-count) reduces to the same mean."""
    cfg = _fzoos_cfg()
    r0 = _sim(cfg, quad, chunk=4)
    r1 = _sim(cfg, quad, chunk=4, faults=FaultConfig())
    np.testing.assert_array_equal(np.asarray(r0.xs), np.asarray(r1.xs))
    np.testing.assert_array_equal(np.asarray(r0.f_values),
                                  np.asarray(r1.f_values))
    np.testing.assert_array_equal(np.asarray(r0.queries),
                                  np.asarray(r1.queries))
    assert not np.asarray(r1.drop_rate).any()
    assert not np.asarray(r1.quarantine_rate).any()


def test_faults_off_bitwise_distributed(quad):
    cfg = _fzoos_cfg()
    r0 = _dist(cfg, quad, chunk=4)
    r1 = _dist(cfg, quad, chunk=4, faults=FaultConfig())
    np.testing.assert_array_equal(np.asarray(r0.xs), np.asarray(r1.xs))
    np.testing.assert_array_equal(np.asarray(r0.f_values),
                                  np.asarray(r1.f_values))


def test_out_of_window_faults_bitwise_identity_sim(quad, tmp_path):
    """Regression: a rates>0 config whose window never intersects the run
    used to select the FAULTED engine (different compile key, masked psum
    columns, insurance checkpoint, per-boundary finiteness sync) even
    though it could never fire.  It must be BITWISE the faults=None run --
    including writing NO step-0 insurance checkpoint."""
    cfg = _fzoos_cfg()
    wcfg = FaultConfig(seed=3, nan_rate=0.9, tolerate=False, first_round=100)
    d = str(tmp_path / "ck")
    r0 = _sim(cfg, quad, chunk=4)
    # tolerate=False + nan_rate>0 would need a checkpoint_dir to roll back
    # to if the faulted engine were selected -- running fine without one is
    # itself evidence the window was normalized away
    r1 = _sim(cfg, quad, chunk=4, faults=wcfg)
    np.testing.assert_array_equal(np.asarray(r0.xs), np.asarray(r1.xs))
    np.testing.assert_array_equal(np.asarray(r0.f_values),
                                  np.asarray(r1.f_values))
    np.testing.assert_array_equal(np.asarray(r0.queries),
                                  np.asarray(r1.queries))
    _sim(cfg, quad, chunk=4, faults=wcfg, checkpoint_dir=d)
    assert 0 not in ckpt_io.list_steps(d)  # no rollback-insurance write


def test_out_of_window_faults_bitwise_identity_distributed(quad):
    cfg = _fzoos_cfg()
    wcfg = FaultConfig(seed=3, nan_rate=0.9, tolerate=False, first_round=100)
    r0 = _dist(cfg, quad, chunk=4)
    r1 = _dist(cfg, quad, chunk=4, faults=wcfg)
    np.testing.assert_array_equal(np.asarray(r0.xs), np.asarray(r1.xs))
    np.testing.assert_array_equal(np.asarray(r0.f_values),
                                  np.asarray(r1.f_values))


_KIND_RATES = {
    "drop": dict(drop_rate=0.3),
    "straggle": dict(straggle_rate=0.3),
    "nan": dict(nan_rate=0.3),
    "inf": dict(inf_rate=0.3),
}


@pytest.mark.parametrize("kind", sorted(_KIND_RATES))
@pytest.mark.parametrize("driver", ["simulate", "distributed"])
def test_fault_kind_matrix(quad, kind, driver):
    """Each fault kind, on each front door: the tolerant engine absorbs the
    faults (finite history end to end) and reports them in the stats."""
    cfg = _fzoos_cfg()
    fcfg = FaultConfig(seed=3, **_KIND_RATES[kind])
    run = _sim if driver == "simulate" else _dist
    r = run(cfg, quad, chunk=4, faults=fcfg)
    assert np.isfinite(np.asarray(r.f_values)).all()
    assert np.isfinite(np.asarray(r.xs)).all()
    drop = np.asarray(r.drop_rate)
    quar = np.asarray(r.quarantine_rate)
    if kind == "drop":
        assert drop.max() > 0
    elif kind in ("nan", "inf"):
        # poisoned clients are detected on device and quarantined; their
        # payloads never reach the aggregate (x stays finite above)
        assert quar.max() > 0
    else:  # straggle: late updates are absorbed, nobody is dropped
        assert not quar.any()


def test_faulted_scan_matches_loop_oracle(quad):
    """chunk=4 scan vs chunk=0 loop under the same fault schedule: bounded
    divergence (reset cadence differs), exact query accounting."""
    cfg = _fzoos_cfg()
    fcfg = FaultConfig(seed=3, drop_rate=0.2, nan_rate=0.1)
    r_scan = _sim(cfg, quad, chunk=4, faults=fcfg)
    r_loop = _sim(cfg, quad, chunk=0, faults=fcfg)
    np.testing.assert_allclose(np.asarray(r_scan.xs), np.asarray(r_loop.xs),
                               atol=0.1)
    np.testing.assert_allclose(np.asarray(r_scan.f_values),
                               np.asarray(r_loop.f_values), atol=5e-2)


def test_faulted_sim_matches_distributed(quad):
    """The fault schedule is topology-independent: vmap and shard_map runs
    inject the SAME (round, client) faults (identical drop_rate history)."""
    cfg = _fzoos_cfg()
    fcfg = FaultConfig(seed=3, drop_rate=0.2, nan_rate=0.1)
    r_sim = _sim(cfg, quad, chunk=4, faults=fcfg)
    r_dist = _dist(cfg, quad, chunk=4, faults=fcfg)
    np.testing.assert_array_equal(np.asarray(r_sim.drop_rate),
                                  np.asarray(r_dist.drop_rate))
    np.testing.assert_array_equal(np.asarray(r_sim.quarantine_rate),
                                  np.asarray(r_dist.quarantine_rate))
    np.testing.assert_allclose(np.asarray(r_sim.xs), np.asarray(r_dist.xs),
                               atol=0.1)


def test_no_tolerance_poisons_dense_mean(quad):
    """Without masking, one NaN payload poisons the dense psum mean -- the
    failure mode the tolerant engine removes (loop driver: no rollback)."""
    cfg = _fzoos_cfg()
    fcfg = FaultConfig(seed=3, nan_rate=0.3, tolerate=False)
    r = _sim(cfg, quad, chunk=0, faults=fcfg)
    assert not np.isfinite(np.asarray(r.xs)).all()


def test_dropout_run_still_converges(quad):
    """20% dropout: the renormalized mean keeps the run on track."""
    cfg = _fzoos_cfg()
    fcfg = FaultConfig(seed=11, drop_rate=0.2)
    r = _sim(cfg, quad, rounds=20, chunk=8, faults=fcfg)
    f = np.asarray(r.f_values)
    assert np.isfinite(f).all()
    assert f[-1] < f[0]  # still optimizes through the faults


# ---------------------------------------------------------------------------
# Quarantine reset
# ---------------------------------------------------------------------------


def _flagged_states(cfg, flags):
    x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    states = alg.init_states(cfg, jax.random.PRNGKey(2), x0)
    # make the quarantined clients' mutable state visibly non-fresh
    states = states._replace(
        x=states.x + 1.0,
        queries=states.queries + jnp.arange(cfg.n_clients, dtype=states.queries.dtype),
        quarantined=jnp.asarray(flags),
    )
    return states


def test_quarantine_reset_matches_fresh_init_oracle():
    """Reset clients == a fresh client joining at server_x: template leaves
    adopted, identity/RNG/query-count/w_global preserved, flag cleared.
    Un-flagged clients are bitwise untouched."""
    cfg = _fzoos_cfg()
    flags = np.array([True, False, False, True])
    states = _flagged_states(cfg, flags)
    before = jax.tree_util.tree_map(jnp.copy, states)
    sx = jnp.linspace(0.2, 0.8, cfg.dim, dtype=jnp.float32)
    out = rounds_mod.boundary_quarantine_reset(states, cfg, sx)

    template = alg.init_client_state(cfg, jax.random.PRNGKey(0),
                                     jnp.zeros((cfg.dim,), jnp.float32))
    assert not np.asarray(out.quarantined).any()
    for i in range(cfg.n_clients):
        if flags[i]:
            np.testing.assert_array_equal(np.asarray(out.x[i]), np.asarray(sx))
            np.testing.assert_array_equal(np.asarray(out.traj.xs[i]),
                                          np.asarray(template.traj.xs))
            # preserved across the reset: identity, RNG stream, query count
            np.testing.assert_array_equal(np.asarray(out.key[i]),
                                          np.asarray(before.key[i]))
            assert int(out.client_id[i]) == i
            np.testing.assert_array_equal(np.asarray(out.queries[i]),
                                          np.asarray(before.queries[i]))
        else:
            for a, b in zip(jax.tree_util.tree_leaves(
                    jax.tree_util.tree_map(lambda l: l[i], out)),
                    jax.tree_util.tree_leaves(
                    jax.tree_util.tree_map(lambda l: l[i], before))):
                if a.dtype == bool and a.shape == ():  # the cleared flag
                    continue
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quarantine_host_oracle_matches_device_gate():
    cfg = _fzoos_cfg()
    flags = np.array([False, True, False, False])
    sx = jnp.linspace(0.2, 0.8, cfg.dim, dtype=jnp.float32)
    dev = rounds_mod.boundary_quarantine_reset(_flagged_states(cfg, flags), cfg, sx)
    host, n = rounds_mod.quarantine_reset_flagged(_flagged_states(cfg, flags),
                                                  cfg, sx)
    assert n == 1
    for a, b in zip(jax.tree_util.tree_leaves(dev),
                    jax.tree_util.tree_leaves(host)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quarantine_reset_noop_without_flags():
    cfg = _fzoos_cfg()
    states = _flagged_states(cfg, np.zeros(4, bool))
    out, n = rounds_mod.quarantine_reset_flagged(
        states, cfg, jnp.zeros((cfg.dim,), jnp.float32))
    assert n == 0
    assert out is states  # host oracle short-circuits: zero dispatches


# ---------------------------------------------------------------------------
# Storage faults: checksums, restore fallback, rollback
# ---------------------------------------------------------------------------


def test_truncated_npz_rejected(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32), "b": jnp.ones((3, 2))}
    ckpt_io.save(str(tmp_path), tree, step=1)
    corrupt.truncate_npz(str(tmp_path), 1)
    with pytest.raises(ckpt_io.CorruptCheckpointError):
        ckpt_io.restore(str(tmp_path), tree, step=1)


def test_flipped_bytes_rejected(tmp_path):
    tree = {"a": jnp.arange(512, dtype=jnp.float32)}
    ckpt_io.save(str(tmp_path), tree, step=1)
    corrupt.flip_bytes(str(tmp_path), 1, n_bytes=16)
    with pytest.raises(ckpt_io.CorruptCheckpointError):
        ckpt_io.restore(str(tmp_path), tree, step=1)


def test_resume_falls_back_past_corrupt_steps(quad, tmp_path):
    """Torn newest step + bit-flipped second-newest: resume restores the
    newest GOOD step and completes bitwise-identically to the full run."""
    cfg = _fzoos_cfg(local_steps=2)
    d = str(tmp_path / "ck")
    r_full = _sim(cfg, quad, chunk=2, checkpoint_dir=d, checkpoint_every=1)
    steps = ckpt_io.list_steps(d)
    assert steps == [2, 4, 6, 8]
    corrupt.truncate_npz(d, steps[-1])
    corrupt.flip_bytes(d, steps[-2])
    r_res = _sim(cfg, quad, chunk=2, checkpoint_dir=d)
    np.testing.assert_array_equal(np.asarray(r_full.xs), np.asarray(r_res.xs))
    np.testing.assert_array_equal(np.asarray(r_full.f_values),
                                  np.asarray(r_res.f_values))


def test_rollback_recovers_poisoned_run(quad, tmp_path, capsys):
    """tolerate=False + NaN faults: the boundary health check detects the
    poisoned iterate, rolls back to the last good checkpoint and re-runs
    with tolerance forced on -- the run completes finite."""
    cfg = _fzoos_cfg()
    fcfg = FaultConfig(seed=3, nan_rate=0.3, tolerate=False)
    d = str(tmp_path / "ck")
    r = _sim(cfg, quad, chunk=4, checkpoint_dir=d, faults=fcfg)
    assert np.isfinite(np.asarray(r.f_values)).all()
    assert np.isfinite(np.asarray(r.xs)).all()
    out = capsys.readouterr().out
    assert "ROLLBACK" in out and "FORCED ON" in out


def test_rollback_without_checkpoint_dir_fails_loudly(quad):
    cfg = _fzoos_cfg()
    fcfg = FaultConfig(seed=3, nan_rate=0.3, tolerate=False)
    with pytest.raises(FloatingPointError, match="no checkpoint_dir"):
        _sim(cfg, quad, chunk=4, faults=fcfg)


def test_final_boundary_write_failure_rolls_back(quad, tmp_path, capsys,
                                                 monkeypatch):
    """Regression: a failed async write at the FINAL boundary used to
    surface from the post-loop ``finally: writer.wait()`` drain -- escaping
    the rollback machinery entirely and killing an otherwise-finished run.
    The final boundary now drains inside the rollback-capable block: the
    failure rolls back to the last good step and the replayed chunk
    completes bitwise identically."""
    cfg = _fzoos_cfg(local_steps=2)
    d_ref = str(tmp_path / "ref")
    r_ref = _sim(cfg, quad, chunk=4, checkpoint_dir=d_ref,
                 faults=FaultConfig())

    real = ckpt_io.write_round_state
    fails = []

    def flaky(root, round_idx, payload, extra_meta=None):
        # exhaust one full submit cycle (1 try + 2 writer retries) of the
        # LAST boundary's write, then heal for the post-rollback replay
        if round_idx == ROUNDS and len(fails) < 3:
            fails.append(1)
            raise OSError("injected: final write torn")
        return real(root, round_idx, payload, extra_meta=extra_meta)

    monkeypatch.setattr(ckpt_io, "write_round_state", flaky)
    d = str(tmp_path / "ck")
    r = _sim(cfg, quad, chunk=4, checkpoint_dir=d, faults=FaultConfig())
    assert len(fails) == 3  # the injected failure was actually exercised
    out = capsys.readouterr().out
    assert "ROLLBACK" in out
    assert ckpt_io.latest_step(d) == ROUNDS  # the replayed final write landed
    np.testing.assert_array_equal(np.asarray(r_ref.xs), np.asarray(r.xs))
    np.testing.assert_array_equal(np.asarray(r_ref.f_values),
                                  np.asarray(r.f_values))


def test_resume_identity_includes_faults(quad, tmp_path):
    """A checkpoint dir written under one fault schedule refuses to resume
    under a different one (the schedule is part of the run identity)."""
    cfg = _fzoos_cfg(local_steps=2)
    d = str(tmp_path / "ck")
    _sim(cfg, quad, rounds=4, chunk=2, checkpoint_dir=d,
         faults=FaultConfig(seed=1, drop_rate=0.2))
    with pytest.raises(ValueError, match="faults"):
        _sim(cfg, quad, rounds=4, chunk=2, checkpoint_dir=d,
             faults=FaultConfig(seed=2, drop_rate=0.2))


# ---------------------------------------------------------------------------
# Async writer retry
# ---------------------------------------------------------------------------


def test_writer_retries_transient_oserror():
    w = ckpt_io.AsyncCheckpointWriter(retries=2, backoff_s=0.01)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")

    w.submit(flaky)
    w.wait()  # retried to success: no raise
    assert len(calls) == 3


def test_writer_permanent_oserror_raises():
    w = ckpt_io.AsyncCheckpointWriter(retries=1, backoff_s=0.01)
    calls = []

    def bad():
        calls.append(1)
        raise OSError("disk on fire")

    w.submit(bad)
    with pytest.raises(OSError, match="disk on fire"):
        w.wait()
    assert len(calls) == 2  # 1 try + 1 retry


def test_writer_non_io_errors_not_retried():
    w = ckpt_io.AsyncCheckpointWriter(retries=5, backoff_s=0.01)
    calls = []

    def bug():
        calls.append(1)
        raise ValueError("logic bug")

    w.submit(bug)
    with pytest.raises(ValueError, match="logic bug"):
        w.wait()
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# Static contracts
# ---------------------------------------------------------------------------


def test_fault_contracts_clean():
    from repro.analysis import contracts

    for name in ("fzoos-faults/simulate", "fzoos-faults/distributed",
                 "fedzo-faults/simulate", "fedzo-faults/distributed",
                 "chunk-step-donation/faulted",
                 "chunk-step-donation/faulted-distributed",
                 "quarantine-reset"):
        violations = contracts.check_contract(name)
        assert violations == [], f"{name}: {violations}"
