"""Per-architecture smoke tests (deliverable f) + cross-path consistency:
reduced configs run a real forward/train/prefill/decode step on CPU with
shape and finiteness assertions; cached decode must agree with the full
forward pass."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    count_params,
    decode_step,
    forward,
    init_train_state,
    input_specs,
    prefill,
    train_step,
)
from repro.sharding.rules import ShardingPolicy

POLICY = ShardingPolicy(remat=False)
B, L = 2, 48


def _batch(cfg, key, length=L, labels=True):
    out = {"tokens": jax.random.randint(key, (B, length), 0, cfg.vocab_size)}
    if labels:
        out["labels"] = jax.random.randint(jax.random.fold_in(key, 1), (B, length), 0, cfg.vocab_size)
    if cfg.arch_type == "vlm":
        out["patches"] = 0.1 * jax.random.normal(key, (B, cfg.n_patches, cfg.d_model), jnp.float32)
        out["positions"] = jnp.broadcast_to(
            jnp.arange(length)[None, :, None], (B, length, 3)
        ).astype(jnp.int32)
    if cfg.arch_type == "encdec":
        out["frames"] = 0.1 * jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    p, opt = init_train_state(key, cfg)
    p2, opt2, metrics = train_step(p, opt, cfg, _batch(cfg, key), POLICY, lr=1e-3)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = max(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_config(arch, "smoke")
    key = jax.random.PRNGKey(0)
    p, _ = init_train_state(key, cfg)
    batch = _batch(cfg, key, labels=False)
    logits, cache = prefill(p, cfg, batch, POLICY, cache_len=L + 8)
    assert logits.shape == (B, cfg.vocab_size)
    assert int(cache.pos) == L
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = decode_step(p, cfg, cache, tok, POLICY)
    assert logits2.shape == (B, cfg.vocab_size)
    assert int(cache2.pos) == L + 1
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all()


@pytest.mark.parametrize(
    "arch,tol",
    [
        # bf16: one ULP (2^-8 ~ 4e-3) of headroom.  The qkv-bias epilogue
        # fuses differently between the L-token forward and the 1-token
        # decode matmuls, so bitwise equality (which the biasless dense
        # archs happen to achieve) is not a guaranteed property here.
        ("qwen1_5_0_5b", 1e-2),
        ("gemma_7b", 1e-5),
        ("yi_34b", 1e-5),
        ("minitron_8b", 1e-5),
        ("llama4_scout_17b_16e", 1e-5),  # capacity-safe at this size
        ("qwen2_vl_7b", 1e-5),
        ("mamba2_370m", 0.05),  # bf16 recurrent-vs-chunked paths
        ("whisper_base", 0.02),
        ("jamba_1_5_large_398b", 0.08),
    ],
)
def test_decode_matches_forward(arch, tol):
    """decode_step(t=L) must equal forward's logits at position L."""
    cfg = get_config(arch, "smoke")
    if cfg.is_moe_mlp:
        # make token-drop impossible so both paths see identical routing
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p, _ = init_train_state(key, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L + 1), 0, cfg.vocab_size)
    bf = _batch(cfg, key, length=L + 1, labels=False)
    bf["tokens"] = toks
    bp = _batch(cfg, key, length=L, labels=False)
    bp["tokens"] = toks[:, :L]
    lg_full, _ = forward(p, cfg, bf, POLICY)
    _, cache = prefill(p, cfg, bp, POLICY, cache_len=L + 8)
    lg_dec, _ = decode_step(p, cfg, cache, toks[:, L : L + 1].astype(jnp.int32), POLICY)
    scale = float(jnp.abs(lg_full.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(lg_dec.astype(jnp.float32) - lg_full[:, L].astype(jnp.float32)).max())
    assert err / scale < tol, (err, scale)


def test_input_specs_cover_all_shapes():
    from repro.models.model import INPUT_SHAPES

    for arch in ARCH_IDS:
        cfg = get_config(arch, "full")
        for shape in INPUT_SHAPES:
            specs = input_specs(cfg, shape)
            assert isinstance(specs, dict) and specs
            for v in jax.tree_util.tree_leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_param_count_analytic_vs_actual():
    """config.param_count() (roofline bookkeeping) tracks real param counts."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, "smoke")
        analytic = cfg.param_count()
        actual = count_params(cfg)
        assert abs(analytic - actual) / actual < 0.15, (arch, analytic, actual)


def test_full_config_numbers_match_assignment():
    """The ten FULL configs carry exactly the published dimensions."""
    want = {
        "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 8192, 202048, 128),
        "llama4_scout_17b_16e": (48, 5120, 40, 8, 8192, 202048, 16),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536, 16),
        "gemma_7b": (28, 3072, 16, 16, 24576, 256000, 0),
        "yi_34b": (60, 7168, 56, 8, 20480, 64000, 0),
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000, 0),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064, 0),
        "qwen1_5_0_5b": (24, 1024, 16, 16, 2816, 151936, 0),
        "whisper_base": (6, 512, 8, 8, 2048, 51865, 0),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280, 0),
    }
    for arch, (nl, dm, nh, kv, ff, vs, ne) in want.items():
        cfg = get_config(arch, "full")
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size, cfg.n_experts)
        assert got == (nl, dm, nh, kv, ff, vs, ne), (arch, got)
    assert get_config("mamba2_370m", "full").ssm_state == 128
    assert get_config("jamba_1_5_large_398b", "full").attn_every == 8
    assert get_config("jamba_1_5_large_398b", "full").moe_top_k == 2
    assert get_config("qwen2_vl_7b", "full").qkv_bias
    assert get_config("qwen1_5_0_5b", "full").qkv_bias
    assert get_config("gemma_7b", "full").head_dim == 256
    assert get_config("gemma_7b", "full").mlp_act == "geglu"
