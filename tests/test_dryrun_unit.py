"""Unit tests for the dry-run analysis machinery (no 512-device init --
pure parsing/extrapolation logic)."""

import pytest

from repro.launch.dryrun import (
    _COLLECTIVES,
    _extrapolate,
    _shape_bytes,
    applicable,
    depth_variant,
    parse_collectives,
)
from repro.configs import ARCH_IDS, get_config


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]") == 8 * 16 * 4
    assert _shape_bytes("bf16[2,3,4]") == 24 * 2
    assert _shape_bytes("(f32[8], bf16[4,4])") == 32 + 32
    assert _shape_bytes("pred[7]") == 7
    assert _shape_bytes("token[]") == 0


SAMPLE_HLO = """
HloModule test
fused_computation {
  x = f32[128,256] parameter(0)
}
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[2048,256]{1,0} all-gather(f32[128,256]{1,0} %p0), dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %p0), to_apply=add
  %ars = f32[128,256]{1,0} all-reduce-start(f32[128,256]{1,0} %p0), to_apply=add
  %ard = f32[128,256]{1,0} all-reduce-done(f32[128,256]{1,0} %ars)
  %rs = f32[8,256]{1,0} reduce-scatter(f32[128,256]{1,0} %p0), dimensions={0}
  %a2a = f32[128,256]{1,0} all-to-all(f32[128,256]{1,0} %p0), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(f32[128,256]{1,0} %p0), source_target_pairs={{0,1}}
  %t = (f32[64,64]{1,0}, f32[64,64]{1,0}) all-gather(f32[32,64] %p0x, f32[32,64] %p0y), dimensions={0}
}
"""


def test_parse_collectives_counts_and_bytes():
    got = parse_collectives(SAMPLE_HLO)
    f = lambda n: n * 4
    assert got["all-gather"]["count"] == 2
    assert got["all-gather"]["bytes"] == f(2048 * 256) + 2 * f(64 * 64)
    # all-reduce: plain + start form; -done NOT double counted
    assert got["all-reduce"]["count"] == 2
    assert got["all-reduce"]["bytes"] == 2 * f(128 * 256)
    assert got["reduce-scatter"]["bytes"] == f(8 * 256)
    assert got["all-to-all"]["count"] == 1
    assert got["collective-permute"]["count"] == 1
    assert got["total_bytes"] == sum(
        got[c]["bytes"] for c in _COLLECTIVES
    )


def test_extrapolation_linear_exact():
    d2 = {"cost": {"flops": 100.0, "bytes accessed": 10.0},
          "collectives": {"all-reduce": {"bytes": 8, "count": 2}, "total_bytes": 8,
                          "all-gather": {"bytes": 0, "count": 0},
                          "reduce-scatter": {"bytes": 0, "count": 0},
                          "all-to-all": {"bytes": 0, "count": 0},
                          "collective-permute": {"bytes": 0, "count": 0}}}
    d4 = {"cost": {"flops": 160.0, "bytes accessed": 14.0},
          "collectives": {"all-reduce": {"bytes": 12, "count": 4}, "total_bytes": 12,
                          "all-gather": {"bytes": 0, "count": 0},
                          "reduce-scatter": {"bytes": 0, "count": 0},
                          "all-to-all": {"bytes": 0, "count": 0},
                          "collective-permute": {"bytes": 0, "count": 0}}}
    ex = _extrapolate(d2, d4, 10, ka=2, kb=4)
    # per-block = 30 flops; F(10) = 100 + 8*30 = 340
    assert ex["cost"]["flops"] == 340.0
    assert ex["cost"]["bytes accessed"] == pytest.approx(10 + 8 * 2.0)
    assert ex["collectives"]["all-reduce"] == 8 + 8 * 2.0
    assert ex["per_block"]["flops"] == 30.0
    # default depths 1/2
    ex2 = _extrapolate(d2, d4, 3)
    assert ex2["cost"]["flops"] == pytest.approx(100 + 2 * 60.0)


def test_depth_variant_families():
    for arch in ARCH_IDS:
        cfg = get_config(arch, "full")
        dv = depth_variant(cfg, 2)
        assert dv.n_blocks == 2, arch
        assert dv.d_model == cfg.d_model
        if cfg.arch_type == "encdec":
            assert dv.n_enc_layers == 2


def test_applicability_matrix():
    """The skip table from DESIGN.md Arch-applicability."""
    long_ok = {"llama4_maverick_400b_a17b", "llama4_scout_17b_16e",
               "mamba2_370m", "jamba_1_5_large_398b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch, "full")
        ok, why = applicable(cfg, "long_500k")
        assert ok == (arch in long_ok), (arch, why)
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = applicable(cfg, shape)
            assert ok, (arch, shape)


def test_expected_combo_count():
    """10 archs x 4 shapes = 40 combos; 6 long_500k skips -> 34 lowered."""
    lowered = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch, "full")
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if applicable(cfg, shape)[0]:
                lowered += 1
    assert lowered == 34
