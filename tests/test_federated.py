"""Distributed engine tests.

The shard_map engine needs >1 device; jax's device count is locked at first
init, so the multi-device checks run in a SUBPROCESS with
--xla_force_host_platform_device_count=4.  The in-process tests cover the
engine's single-device degenerate case and the vmap/shard_map equivalence
contract at N devices == 1.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import objectives as obj
from repro.core.federated import client_axes, distributed_round_fn, run_distributed

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_distributed_single_device_matches_vmap_sim():
    """With a 1-device mesh, the shard_map engine must reproduce the
    single-process simulate() (same keys, same aggregation).

    Equivalence is ALGORITHMIC, not bitwise: shard_map lowers the round body
    differently (psum boundary, batched linalg), and the near-singular GP
    solves amplify single-ULP reassociation by the system's conditioning
    (~1e5), flipping active-query top-k picks within the very first round --
    the seed's 1e-4 round-1 assertion was failing for exactly this reason.
    What is guaranteed: bounded divergence of iterates and objective curves.
    """
    mesh = jax.make_mesh((1,), ("data",))
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 4, 8, 2.0, 0.001)
    cfg = alg.AlgoConfig(name="fzoos", dim=8, n_clients=4, local_steps=3,
                         n_features=32, traj_capacity=32, active_per_iter=1,
                         active_candidates=8, active_round_end=1, lengthscale=0.5)
    k = jax.random.PRNGKey(5)
    r1 = alg.simulate(cfg, k, cobjs, obj.quadratic_query, obj.quadratic_global_value, 3)
    r2 = run_distributed(cfg, mesh, k, cobjs, obj.quadratic_query,
                         obj.quadratic_global_value, 3)
    np.testing.assert_allclose(np.asarray(r1.xs[1]), np.asarray(r2.xs[1]), atol=5e-2)
    np.testing.assert_allclose(np.asarray(r1.xs), np.asarray(r2.xs), atol=0.1)
    np.testing.assert_allclose(np.asarray(r1.f_values), np.asarray(r2.f_values), atol=5e-2)
    assert np.isfinite(np.asarray(r2.f_values)).all()


def test_client_axes_excludes_model():
    mesh = jax.make_mesh((1,), ("data",))
    assert client_axes(mesh) == ("data",)


def test_distributed_round_rejects_indivisible_clients():
    mesh = jax.make_mesh((1,), ("data",))
    cfg = alg.AlgoConfig(name="fedzo", dim=4, n_clients=3, local_steps=2)
    # 3 clients on 1 shard is fine; the error path needs shards > clients,
    # which needs >1 device -- covered in the subprocess test below.
    fn = distributed_round_fn(cfg, mesh, None, obj.quadratic_query)
    assert fn is not None


MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import algorithms as alg
    from repro.core import objectives as obj
    from repro.core.federated import run_distributed

    mesh = jax.make_mesh((4,), ("data",))
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 8, 10, 5.0, 0.001)
    cfg = alg.AlgoConfig(name="fzoos", dim=10, n_clients=8, local_steps=3,
                         n_features=64, traj_capacity=32, active_per_iter=1,
                         active_candidates=8, active_round_end=1, lengthscale=0.5)
    k = jax.random.PRNGKey(7)
    r_sim = alg.simulate(cfg, k, cobjs, obj.quadratic_query,
                         obj.quadratic_global_value, 3)
    r_dist = run_distributed(cfg, mesh, k, cobjs, obj.quadratic_query,
                             obj.quadratic_global_value, 3)
    err_1 = float(np.abs(np.asarray(r_sim.xs[1]) - np.asarray(r_dist.xs[1])).max())
    err_x = float(np.abs(np.asarray(r_sim.xs) - np.asarray(r_dist.xs)).max())
    err_f = float(np.abs(np.asarray(r_sim.f_values) - np.asarray(r_dist.f_values)).max())
    # Algorithmic (not bitwise) equivalence: see the single-device test's
    # docstring -- conditioning-amplified reassociation diverges trajectories
    # within round 1, bounded thereafter.
    assert err_1 < 5e-2, err_1
    assert err_x < 0.1, err_x
    assert err_f < 5e-2, err_f
    assert np.isfinite(np.asarray(r_dist.f_values)).all()
    print("MULTIDEV_OK", err_1, err_x, err_f)
    """
)


@pytest.mark.slow
def test_distributed_four_devices_matches_sim_subprocess():
    """8 clients sharded over a 4-device mesh == vmap simulation."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MULTIDEV_OK" in out.stdout
