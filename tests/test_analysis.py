"""Static-analysis subsystem tests (repro.analysis, DESIGN.md Sec. 7).

Two halves:

  * **negative suite** -- one deliberately-violating program per rule
    (inline eigh in a scan body, bf16 carry promoted, un-donated buffer,
    extra psum vs the declared census, host callback in a scanned body),
    each caught WITH a jaxpr source location pointing at this file;
  * **positive gate** -- every shipping contract in the registry lints
    clean, and the ``python -m repro.analysis`` CLI round-trips.
"""

import io
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    SteadyStateViolation,
    check_all,
    no_recompiles,
    steady_state_guard,
)
from repro.analysis import hlo_audit, jaxpr_lint


# ---------------------------------------------------------------------------
# Negative suite: each rule catches its seeded violation
# ---------------------------------------------------------------------------


def test_inline_eigh_in_scan_body_caught():
    def body(c, _):
        w, _v = jnp.linalg.eigh(c)  # the violation under test
        return c + jnp.diag(w), None

    closed = jax.make_jaxpr(
        lambda c: jax.lax.scan(body, c, None, length=2)
    )(jnp.eye(3, dtype=jnp.float32))
    vs = jaxpr_lint.find_forbidden(closed, jaxpr_lint.EIGH_PRIMITIVES,
                                   rule="no-eigh")
    assert len(vs) == 1
    assert vs[0].rule == "no-eigh"
    assert "scan" in vs[0].path  # located inside the scanned body
    assert "test_analysis" in vs[0].source  # points at repo source, not soup


def test_bf16_carry_promotion_caught():
    def body(p, g):
        p32 = p.astype(jnp.float32)  # the PR 4 drift signature
        return (p32 - 0.1 * g).astype(jnp.bfloat16), None

    gs = jnp.zeros((3, 4), jnp.float32)
    closed = jax.make_jaxpr(
        lambda p: jax.lax.scan(body, p, gs)
    )(jnp.zeros((4,), jnp.bfloat16))
    vs = jaxpr_lint.find_carry_promotions(closed)
    assert len(vs) == 1
    assert vs[0].rule == "carry-promotion"
    assert "bfloat16" in vs[0].message and "float32" in vs[0].message
    assert "test_analysis" in vs[0].source
    # the clean version of the same update lints clean
    def ok_body(p, g):
        return p - (0.1 * g).astype(p.dtype), None
    clean = jax.make_jaxpr(
        lambda p: jax.lax.scan(ok_body, p, gs)
    )(jnp.zeros((4,), jnp.bfloat16))
    assert jaxpr_lint.find_carry_promotions(clean) == []


def test_dropped_donation_caught():
    """XLA silently drops a donation whose output has no shape/dtype-matched
    buffer; the audit turns the silence into a violation."""
    def f(a):
        return a.astype(jnp.float32) + 1.0

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # jax warns about the unused donation
        txt = jax.jit(f, donate_argnums=0).lower(
            jnp.zeros((4,), jnp.bfloat16)).as_text()
    assert hlo_audit.aliased_inputs(txt) == {}
    vs = hlo_audit.check_donation(txt, expected_aliased=1, where="seeded")
    assert len(vs) == 1 and vs[0].rule == "donation-dropped"

    # control: a dtype-preserving donated update aliases and lints clean
    txt_ok = jax.jit(lambda a: a + 1, donate_argnums=0).lower(
        jnp.zeros((4,), jnp.float32)).as_text()
    assert hlo_audit.check_donation(txt_ok, expected_aliased=1) == []


def test_extra_psum_vs_census_caught():
    def f(x):
        return jax.lax.psum(x, "i") + jax.lax.psum(x.sum(), "i")

    closed = jax.make_jaxpr(f, axis_env=[("i", 2)])(jnp.zeros((4,), jnp.float32))
    assert jaxpr_lint.psum_census(closed) == {"psum_array": 1, "psum_scalar": 1}
    assert jaxpr_lint.check_psum_census(
        closed, {"psum_array": 1, "psum_scalar": 1}) == []
    # declaring only the array psum makes the scalar one a violation...
    vs = jaxpr_lint.check_psum_census(closed, {"psum_array": 1})
    assert [v.rule for v in vs] == ["collective-census"]
    assert "psum_scalar" in vs[0].message
    # ...and a MISSING declared collective is equally a violation
    vs2 = jaxpr_lint.check_psum_census(
        closed, {"psum_array": 2, "psum_scalar": 1})
    assert len(vs2) == 1 and "psum_array" in vs2[0].message


def test_host_callback_in_scan_body_caught():
    def body(c, _):
        y = jax.pure_callback(
            lambda v: np.sin(v), jax.ShapeDtypeStruct((), jnp.float32), c)
        return c + y, None

    closed = jax.make_jaxpr(
        lambda c: jax.lax.scan(body, c, None, length=3)
    )(jnp.float32(0.0))
    vs = jaxpr_lint.find_host_ops(closed)
    assert any(v.rule == "host-op" and "pure_callback" in v.message
               and "scan" in v.path for v in vs)


def test_io_dtype_drift_caught():
    closed = jax.make_jaxpr(
        lambda p, g: (p.astype(jnp.float32) - g, None)
    )(jnp.zeros((4,), jnp.bfloat16), jnp.zeros((4,), jnp.float32))
    vs = jaxpr_lint.check_io_dtypes(closed, [(0, 0)])
    assert len(vs) == 1 and vs[0].rule == "dtype-drift"
    assert jaxpr_lint.check_io_dtypes(closed, [(1, 0)]) == []  # f32 -> f32


def test_ungated_eigh_caught():
    """eigh outside any cond: the steady state would pay it unconditionally."""
    closed = jax.make_jaxpr(lambda a: jnp.linalg.eigh(a)[0])(jnp.eye(3))
    vs = jaxpr_lint.eigh_only_behind_cond(closed)
    assert len(vs) == 1 and vs[0].rule == "eigh-not-gated"

    gated = jax.make_jaxpr(
        lambda a, flag: jax.lax.cond(
            flag, lambda m: jnp.linalg.eigh(m)[0], lambda m: m[:, 0], a)
    )(jnp.eye(3), jnp.asarray(True))
    assert jaxpr_lint.eigh_only_behind_cond(gated) == []


def test_fingerprints_are_shared_and_nonempty():
    """The probe-derived fingerprints back every eigh assertion in the repo;
    they must resolve on this backend and match a live eigh lowering."""
    markers = hlo_audit.eigh_fingerprints()
    assert markers and all(isinstance(m, str) for m in markers)
    txt = jax.jit(lambda a: jnp.linalg.eigh(a)[0]).lower(jnp.eye(4)).as_text()
    assert hlo_audit.contains_eigh(txt)
    assert hlo_audit.found_markers(txt, markers)
    assert not hlo_audit.contains_eigh("stablehlo.add only")
    assert hlo_audit.cholesky_fingerprints()


# ---------------------------------------------------------------------------
# Steady-state guard
# ---------------------------------------------------------------------------


def test_guard_catches_device_get():
    x = jnp.zeros(())
    with pytest.raises(SteadyStateViolation, match="device_get"):
        with steady_state_guard(allow_compiles=None, allow_device_gets=0):
            jax.device_get(x)


def test_guard_counts_within_budget():
    x = jnp.zeros(())
    with steady_state_guard(allow_compiles=None, allow_device_gets=2) as g:
        jax.device_get(x)
    assert g.device_gets == 1


def test_no_recompiles_guard():
    f = jax.jit(lambda x: x * 2 + 1)
    a, b = jnp.zeros((3,)), jnp.zeros((5,))
    f(a).block_until_ready()  # warm the (3,) executable outside the guard
    with no_recompiles() as g:
        f(a).block_until_ready()  # cache hit: no fresh compile
    assert g.compiles == 0
    with pytest.raises(SteadyStateViolation, match="compiled"):
        with no_recompiles():
            f(b).block_until_ready()  # new shape: fresh executable


def test_guard_restores_device_get_on_error():
    real = jax.device_get
    with pytest.raises(RuntimeError, match="boom"):
        with steady_state_guard(allow_device_gets=0):
            raise RuntimeError("boom")
    assert jax.device_get is real


# ---------------------------------------------------------------------------
# Positive gate: the shipping contracts + the CLI
# ---------------------------------------------------------------------------


def test_all_shipping_contracts_clean():
    """Every registered contract lints clean -- the same gate
    ``python -m repro.analysis`` applies in verify.sh/CI."""
    results = check_all(out=io.StringIO())
    bad = {k: [str(v) for v in vs] for k, vs in results.items() if vs}
    assert not bad, bad


def test_check_all_rejects_unknown_contract():
    with pytest.raises(KeyError, match="unknown contract"):
        check_all(["no-such-contract"], out=io.StringIO())


def test_runner_exits_nonzero_on_violation(capsys):
    """A violating contract turns into exit code 1 with a source-located
    report (registered transiently; the shipping registry stays clean)."""
    from repro.analysis.contracts import CONTRACTS, register
    from repro.analysis.runner import main

    def seeded():
        def body(c, _):
            return c + jnp.diag(jnp.linalg.eigh(c)[0]), None
        closed = jax.make_jaxpr(
            lambda c: jax.lax.scan(body, c, None, length=2))(jnp.eye(3))
        return jaxpr_lint.find_forbidden(closed, jaxpr_lint.EIGH_PRIMITIVES,
                                         rule="no-eigh")

    name = "test-seeded-violation"
    register(name, "transient negative fixture")(seeded)
    try:
        rc = main(["--only", name])
    finally:
        del CONTRACTS[name]
    out = capsys.readouterr().out
    assert rc == 1
    assert "FAIL test-seeded-violation" in out
    assert "no-eigh" in out and "test_analysis" in out  # source-located
    assert "1/1 contract(s) violated" in out


def test_runner_wraps_lowering_errors(capsys):
    from repro.analysis.contracts import CONTRACTS, register
    from repro.analysis.runner import main

    name = "test-broken-contract"
    register(name, "raises instead of lowering")(
        lambda: (_ for _ in ()).throw(RuntimeError("broken fixture")))
    try:
        rc = main(["--only", name])
    finally:
        del CONTRACTS[name]
    assert rc == 1
    assert "lowering-error" in capsys.readouterr().out


def test_cli_smoke():
    """`python -m repro.analysis --list` and a single cheap contract run in a
    fresh interpreter (forced onto CPU so the probe never touches a TPU)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(repo, "src"))
    listing = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list"],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert listing.returncode == 0, listing.stderr
    assert "fzoos-deferred/simulate" in listing.stdout
    single = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--only", "optimizer-dtype"],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert single.returncode == 0, single.stdout + single.stderr
    assert "1 contract(s) clean" in single.stdout
