"""Static-analysis subsystem tests (repro.analysis, DESIGN.md Sec. 7).

Two halves:

  * **negative suite** -- one deliberately-violating program per rule
    (inline eigh in a scan body, bf16 carry promoted, un-donated buffer,
    extra psum vs the declared census, host callback in a scanned body;
    plus one violating ``KernelSpec`` per kernel-audit rule and one seeded
    PRNG misuse per key-flow rule), each caught WITH a source location --
    this file for jaxpr rules, kernel name + grid cell for launch rules;
  * **positive gate** -- every shipping contract in the registry lints
    clean, and the ``python -m repro.analysis`` CLI round-trips.
"""

import io
import json
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    SteadyStateViolation,
    check_all,
    no_recompiles,
    steady_state_guard,
)
from repro.analysis import hlo_audit, jaxpr_lint, kernel_audit, key_flow
from repro.kernels.spec import ArraySpec, BlockDecl, KernelSpec, ScratchDecl


# ---------------------------------------------------------------------------
# Negative suite: each rule catches its seeded violation
# ---------------------------------------------------------------------------


def test_inline_eigh_in_scan_body_caught():
    def body(c, _):
        w, _v = jnp.linalg.eigh(c)  # the violation under test
        return c + jnp.diag(w), None

    closed = jax.make_jaxpr(
        lambda c: jax.lax.scan(body, c, None, length=2)
    )(jnp.eye(3, dtype=jnp.float32))
    vs = jaxpr_lint.find_forbidden(closed, jaxpr_lint.EIGH_PRIMITIVES,
                                   rule="no-eigh")
    assert len(vs) == 1
    assert vs[0].rule == "no-eigh"
    assert "scan" in vs[0].path  # located inside the scanned body
    assert "test_analysis" in vs[0].source  # points at repo source, not soup


def test_bf16_carry_promotion_caught():
    def body(p, g):
        p32 = p.astype(jnp.float32)  # the PR 4 drift signature
        return (p32 - 0.1 * g).astype(jnp.bfloat16), None

    gs = jnp.zeros((3, 4), jnp.float32)
    closed = jax.make_jaxpr(
        lambda p: jax.lax.scan(body, p, gs)
    )(jnp.zeros((4,), jnp.bfloat16))
    vs = jaxpr_lint.find_carry_promotions(closed)
    assert len(vs) == 1
    assert vs[0].rule == "carry-promotion"
    assert "bfloat16" in vs[0].message and "float32" in vs[0].message
    assert "test_analysis" in vs[0].source
    # the clean version of the same update lints clean
    def ok_body(p, g):
        return p - (0.1 * g).astype(p.dtype), None
    clean = jax.make_jaxpr(
        lambda p: jax.lax.scan(ok_body, p, gs)
    )(jnp.zeros((4,), jnp.bfloat16))
    assert jaxpr_lint.find_carry_promotions(clean) == []


def test_dropped_donation_caught():
    """XLA silently drops a donation whose output has no shape/dtype-matched
    buffer; the audit turns the silence into a violation."""
    def f(a):
        return a.astype(jnp.float32) + 1.0

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # jax warns about the unused donation
        txt = jax.jit(f, donate_argnums=0).lower(
            jnp.zeros((4,), jnp.bfloat16)).as_text()
    assert hlo_audit.aliased_inputs(txt) == {}
    vs = hlo_audit.check_donation(txt, expected_aliased=1, where="seeded")
    assert len(vs) == 1 and vs[0].rule == "donation-dropped"

    # control: a dtype-preserving donated update aliases and lints clean
    txt_ok = jax.jit(lambda a: a + 1, donate_argnums=0).lower(
        jnp.zeros((4,), jnp.float32)).as_text()
    assert hlo_audit.check_donation(txt_ok, expected_aliased=1) == []


def test_extra_psum_vs_census_caught():
    def f(x):
        return jax.lax.psum(x, "i") + jax.lax.psum(x.sum(), "i")

    closed = jax.make_jaxpr(f, axis_env=[("i", 2)])(jnp.zeros((4,), jnp.float32))
    assert jaxpr_lint.psum_census(closed) == {"psum_array": 1, "psum_scalar": 1}
    assert jaxpr_lint.check_psum_census(
        closed, {"psum_array": 1, "psum_scalar": 1}) == []
    # declaring only the array psum makes the scalar one a violation...
    vs = jaxpr_lint.check_psum_census(closed, {"psum_array": 1})
    assert [v.rule for v in vs] == ["collective-census"]
    assert "psum_scalar" in vs[0].message
    # ...and a MISSING declared collective is equally a violation
    vs2 = jaxpr_lint.check_psum_census(
        closed, {"psum_array": 2, "psum_scalar": 1})
    assert len(vs2) == 1 and "psum_array" in vs2[0].message


def test_host_callback_in_scan_body_caught():
    def body(c, _):
        y = jax.pure_callback(
            lambda v: np.sin(v), jax.ShapeDtypeStruct((), jnp.float32), c)
        return c + y, None

    closed = jax.make_jaxpr(
        lambda c: jax.lax.scan(body, c, None, length=3)
    )(jnp.float32(0.0))
    vs = jaxpr_lint.find_host_ops(closed)
    assert any(v.rule == "host-op" and "pure_callback" in v.message
               and "scan" in v.path for v in vs)


def test_io_dtype_drift_caught():
    closed = jax.make_jaxpr(
        lambda p, g: (p.astype(jnp.float32) - g, None)
    )(jnp.zeros((4,), jnp.bfloat16), jnp.zeros((4,), jnp.float32))
    vs = jaxpr_lint.check_io_dtypes(closed, [(0, 0)])
    assert len(vs) == 1 and vs[0].rule == "dtype-drift"
    assert jaxpr_lint.check_io_dtypes(closed, [(1, 0)]) == []  # f32 -> f32


def test_ungated_eigh_caught():
    """eigh outside any cond: the steady state would pay it unconditionally."""
    closed = jax.make_jaxpr(lambda a: jnp.linalg.eigh(a)[0])(jnp.eye(3))
    vs = jaxpr_lint.eigh_only_behind_cond(closed)
    assert len(vs) == 1 and vs[0].rule == "eigh-not-gated"

    gated = jax.make_jaxpr(
        lambda a, flag: jax.lax.cond(
            flag, lambda m: jnp.linalg.eigh(m)[0], lambda m: m[:, 0], a)
    )(jnp.eye(3), jnp.asarray(True))
    assert jaxpr_lint.eigh_only_behind_cond(gated) == []


def test_fingerprints_are_shared_and_nonempty():
    """The probe-derived fingerprints back every eigh assertion in the repo;
    they must resolve on this backend and match a live eigh lowering."""
    markers = hlo_audit.eigh_fingerprints()
    assert markers and all(isinstance(m, str) for m in markers)
    txt = jax.jit(lambda a: jnp.linalg.eigh(a)[0]).lower(jnp.eye(4)).as_text()
    assert hlo_audit.contains_eigh(txt)
    assert hlo_audit.found_markers(txt, markers)
    assert not hlo_audit.contains_eigh("stablehlo.add only")
    assert hlo_audit.cholesky_fingerprints()


# ---------------------------------------------------------------------------
# Kernel-launch audit: one violating KernelSpec per rule
# ---------------------------------------------------------------------------


def _spec(**over):
    """A clean 2x2-grid fixture spec; each test perturbs ONE declaration."""
    base = dict(
        name="test.fixture",
        grid=(2, 2),
        in_shapes=(ArraySpec((32, 16), jnp.float32),),
        in_specs=(BlockDecl((16, 16), lambda i, j: (i, 0)),),
        out_shapes=(ArraySpec((32, 32), jnp.float32),),
        out_specs=(BlockDecl((16, 16), lambda i, j: (i, j)),),
    )
    base.update(over)
    return KernelSpec(**base)


def test_clean_spec_fixture_audits_clean():
    assert kernel_audit.audit_spec(_spec()) == []


def test_seeded_write_race_caught_with_cell():
    """Two grid cells differing OUTSIDE the revisit axes write one block."""
    spec = _spec(out_shapes=(ArraySpec((32, 16), jnp.float32),),
                 out_specs=(BlockDecl((16, 16), lambda i, j: (i, 0)),))
    vs = kernel_audit.check_geometry(spec)
    assert {v.rule for v in vs} == {"kernel-write-race"}
    assert "test.fixture" in vs[0].message  # kernel name...
    assert "(0, 0)" in vs[0].message and "(0, 1)" in vs[0].message  # ...cells
    assert vs[0].source == "test.fixture"
    # the SAME mapping is legal once the second axis is a declared reduction
    ok = _spec(out_shapes=(ArraySpec((32, 16), jnp.float32),),
               out_specs=(BlockDecl((16, 16), lambda i, j: (i, 0)),),
               scratch=(ScratchDecl((16, 16), jnp.float32),),
               revisit_axes=(1,), init_axes=(1,))
    assert kernel_audit.check_geometry(ok) == []


def test_unwritten_output_block_caught():
    spec = _spec(grid=(2,),
                 in_specs=(BlockDecl((16, 16), lambda i: (i, 0)),),
                 out_specs=(BlockDecl((16, 16), lambda i: (i, 0)),))
    vs = kernel_audit.check_geometry(spec)
    assert {v.rule for v in vs} == {"kernel-unwritten-block"}
    assert "(0, 1)" in vs[0].message  # the stranded block


def test_oob_index_map_caught_with_cell():
    spec = _spec(grid=(2,),
                 in_specs=(BlockDecl((16, 16), lambda i: (i + 1, 0)),),
                 out_shapes=(ArraySpec((32, 16), jnp.float32),),
                 out_specs=(BlockDecl((16, 16), lambda i: (i, 0)),))
    vs = kernel_audit.check_geometry(spec)
    assert [v.rule for v in vs] == ["kernel-oob-index"]
    assert "grid cell (1)" in vs[0].message  # offending grid cell
    assert "beyond padded bound 32" in vs[0].message


def test_leading_revisit_axis_caught():
    spec = _spec(out_shapes=(ArraySpec((16, 32), jnp.float32),),
                 out_specs=(BlockDecl((16, 16), lambda i, j: (0, j)),),
                 scratch=(ScratchDecl((16, 16), jnp.float32),),
                 revisit_axes=(0,), init_axes=(0,))
    vs = kernel_audit.check_geometry(spec)
    assert "kernel-revisit-order" in {v.rule for v in vs}


def test_misaligned_block_caught():
    spec = _spec(in_shapes=(ArraySpec((30, 16), jnp.float32),))
    vs = kernel_audit.check_geometry(spec)
    assert any(v.rule == "kernel-block-misaligned"
               and "axes [0]" in v.message for v in vs)


def test_missing_accumulator_caught():
    spec = _spec(out_shapes=(ArraySpec((32, 16), jnp.float32),),
                 out_specs=(BlockDecl((16, 16), lambda i, j: (i, 0)),),
                 revisit_axes=(1,), init_axes=(1,))
    vs = kernel_audit.check_geometry(spec)
    assert "kernel-accum-missing" in {v.rule for v in vs}


def test_accumulator_init_mismatch_caught():
    spec = _spec(out_shapes=(ArraySpec((32, 16), jnp.float32),),
                 out_specs=(BlockDecl((16, 16), lambda i, j: (i, 0)),),
                 scratch=(ScratchDecl((16, 16), jnp.float32),),
                 revisit_axes=(1,), init_axes=())
    vs = kernel_audit.check_geometry(spec)
    assert any(v.rule == "kernel-accum-init"
               and "(0, 1)" in v.message for v in vs)  # first revisiting cell


def test_bf16_accumulator_caught_on_real_rff_grad_spec():
    """rff_grad accumulates IN its output ref, so a bf16 launch would sum
    partials in bf16 -- the audit must reject the REAL spec at bf16 (the
    shipping contract pins it to f32)."""
    from repro.kernels.rff_grad import grad_spec

    vs = kernel_audit.check_geometry(
        grad_spec(128, 256, 32, jnp.bfloat16, block_n=64, block_m=128))
    assert [v.rule for v in vs] == ["kernel-accum-dtype"]
    assert "rff_grad" in vs[0].message and "bfloat16" in vs[0].message


def test_over_budget_block_pick_caught():
    """A block pair the tuner would never emit -- but a user CAN pin --
    blows the per-cell VMEM budget and is caught statically."""
    from repro.kernels.gp_score import score_tiled_spec

    spec = score_tiled_spec(256, 2048, 256, jnp.float32,
                            block_n=256, block_cap=1024)
    vs = kernel_audit.check_vmem(spec, backend="tpu")
    assert [v.rule for v in vs] == ["kernel-vmem-budget"]
    assert "gp_score.tiled" in vs[0].message
    assert "budget" in vs[0].message and "(0, 0, 0)" in vs[0].message
    # the tuner's own pick for the same shape fits
    from repro.kernels import autotune

    bn, bc = autotune.select_blocks("score", n=256, cap=2048, d=256,
                                    backend="tpu")
    ok = score_tiled_spec(256, 2048, 256, jnp.float32, block_n=bn,
                          block_cap=min(bc, 2048))
    assert kernel_audit.check_vmem(ok, backend="tpu") == []


# ---------------------------------------------------------------------------
# PRNG key-flow lint: one seeded misuse per rule
# ---------------------------------------------------------------------------


def test_reused_key_caught_with_location():
    def f(key):
        a = jax.random.uniform(key, (3,))
        b = jax.random.normal(key, (3,))  # seeded reuse of `key`
        return a + b

    vs = key_flow.check_key_flow(jax.make_jaxpr(f)(jax.random.PRNGKey(0)))
    assert [v.rule for v in vs] == ["key-reuse"]
    assert "test_analysis" in vs[0].source


def test_sample_then_derive_caught():
    """split/fold of an already-sampled key walks the same counter stream."""
    def f(key):
        a = jax.random.uniform(key, (3,))
        kb = jax.random.fold_in(key, 7)  # seeded derive-after-sample
        return a + jax.random.normal(kb, (3,))

    vs = key_flow.check_key_flow(jax.make_jaxpr(f)(jax.random.PRNGKey(0)))
    assert [v.rule for v in vs] == ["key-reuse"]
    # distinct-parameter derivations of an UNSAMPLED key stay clean
    def ok(key):
        a = jax.random.uniform(jax.random.fold_in(key, 1), (3,))
        return a + jax.random.normal(jax.random.fold_in(key, 2), (3,))

    assert key_flow.check_key_flow(
        jax.make_jaxpr(ok)(jax.random.PRNGKey(0))) == []


def test_same_fold_constant_twice_caught():
    def f(key):
        a = jax.random.uniform(jax.random.fold_in(key, 3), (3,))
        b = jax.random.normal(jax.random.fold_in(key, 3), (3,))  # collision
        return a + b

    vs = key_flow.check_key_flow(jax.make_jaxpr(f)(jax.random.PRNGKey(0)))
    assert [v.rule for v in vs] == ["key-reuse"]


def test_scan_carry_unsplit_caught():
    def f(key):
        def body(c, _):
            return c, jax.random.uniform(c, ())  # carry never split

        _, ys = jax.lax.scan(body, key, None, length=4)
        return ys

    vs = key_flow.check_key_flow(jax.make_jaxpr(f)(jax.random.PRNGKey(0)))
    assert [v.rule for v in vs] == ["key-carry-unsplit"]
    assert "test_analysis" in vs[0].source
    # the split-every-iteration version is clean
    def ok(key):
        def body(c, _):
            c, sub = jax.random.split(c)
            return c, jax.random.uniform(sub, ())

        return jax.lax.scan(body, key, None, length=4)[1]

    assert key_flow.check_key_flow(
        jax.make_jaxpr(ok)(jax.random.PRNGKey(0))) == []


def test_constant_key_caught_at_creation_site():
    def f(x):
        kk = jax.random.PRNGKey(777)
        return x + jax.random.normal(kk, (3,))

    vs = key_flow.check_key_flow(jax.make_jaxpr(f)(jnp.ones(3)))
    assert [v.rule for v in vs] == ["key-constant"]
    assert "test_analysis" in vs[0].source


def test_suppression_comment_honored():
    def f(x):
        kk = jax.random.PRNGKey(777)  # key-flow: ok (negative-test fixture)
        return x + jax.random.normal(kk, (3,))

    report = key_flow.analyze_key_flow(jax.make_jaxpr(f)(jnp.ones(3)))
    assert report.violations == []
    assert [v.rule for v in report.suppressed] == ["key-constant"]


def test_split_family_element_reuse_caught():
    def f(key):
        ks = jax.random.split(key, 3)
        return jax.random.uniform(ks[0], ()) + jax.random.normal(ks[0], ())

    vs = key_flow.check_key_flow(jax.make_jaxpr(f)(jax.random.PRNGKey(0)))
    assert [v.rule for v in vs] == ["key-reuse"]
    # distinct elements of the family are distinct keys
    def ok(key):
        ks = jax.random.split(key, 3)
        return jax.random.uniform(ks[0], ()) + jax.random.normal(ks[1], ())

    assert key_flow.check_key_flow(
        jax.make_jaxpr(ok)(jax.random.PRNGKey(0))) == []


# ---------------------------------------------------------------------------
# Steady-state guard
# ---------------------------------------------------------------------------


def test_guard_catches_device_get():
    x = jnp.zeros(())
    with pytest.raises(SteadyStateViolation, match="device_get"):
        with steady_state_guard(allow_compiles=None, allow_device_gets=0):
            jax.device_get(x)


def test_guard_counts_within_budget():
    x = jnp.zeros(())
    with steady_state_guard(allow_compiles=None, allow_device_gets=2) as g:
        jax.device_get(x)
    assert g.device_gets == 1


def test_no_recompiles_guard():
    f = jax.jit(lambda x: x * 2 + 1)
    a, b = jnp.zeros((3,)), jnp.zeros((5,))
    f(a).block_until_ready()  # warm the (3,) executable outside the guard
    with no_recompiles() as g:
        f(a).block_until_ready()  # cache hit: no fresh compile
    assert g.compiles == 0
    with pytest.raises(SteadyStateViolation, match="compiled"):
        with no_recompiles():
            f(b).block_until_ready()  # new shape: fresh executable


def test_guard_restores_device_get_on_error():
    real = jax.device_get
    with pytest.raises(RuntimeError, match="boom"):
        with steady_state_guard(allow_device_gets=0):
            raise RuntimeError("boom")
    assert jax.device_get is real


# ---------------------------------------------------------------------------
# Positive gate: the shipping contracts + the CLI
# ---------------------------------------------------------------------------


def test_all_shipping_contracts_clean():
    """Every registered contract lints clean -- the same gate
    ``python -m repro.analysis`` applies in verify.sh/CI."""
    results = check_all(out=io.StringIO())
    bad = {k: [str(v) for v in vs] for k, vs in results.items() if vs}
    assert not bad, bad


def test_check_all_rejects_unknown_contract():
    with pytest.raises(KeyError, match="unknown contract"):
        check_all(["no-such-contract"], out=io.StringIO())


def test_runner_exits_nonzero_on_violation(capsys):
    """A violating contract turns into exit code 1 with a source-located
    report (registered transiently; the shipping registry stays clean)."""
    from repro.analysis.contracts import CONTRACTS, register
    from repro.analysis.runner import main

    def seeded():
        def body(c, _):
            return c + jnp.diag(jnp.linalg.eigh(c)[0]), None
        closed = jax.make_jaxpr(
            lambda c: jax.lax.scan(body, c, None, length=2))(jnp.eye(3))
        return jaxpr_lint.find_forbidden(closed, jaxpr_lint.EIGH_PRIMITIVES,
                                         rule="no-eigh")

    name = "test-seeded-violation"
    register(name, "transient negative fixture")(seeded)
    try:
        rc = main(["--only", name])
    finally:
        del CONTRACTS[name]
    out = capsys.readouterr().out
    assert rc == 1
    assert "FAIL test-seeded-violation" in out
    assert "no-eigh" in out and "test_analysis" in out  # source-located
    assert "1/1 contract(s) violated" in out


def test_runner_wraps_lowering_errors(capsys):
    from repro.analysis.contracts import CONTRACTS, register
    from repro.analysis.runner import main

    name = "test-broken-contract"
    register(name, "raises instead of lowering")(
        lambda: (_ for _ in ()).throw(RuntimeError("broken fixture")))
    try:
        rc = main(["--only", name])
    finally:
        del CONTRACTS[name]
    assert rc == 1
    assert "lowering-error" in capsys.readouterr().out


def test_contract_registry_floor():
    """The registry must carry the full contract population: the engine
    contracts plus the kernel-audit and key-flow families (the verify.sh
    --static floor guards the same count in CI)."""
    from repro.analysis.contracts import CONTRACTS

    assert len(CONTRACTS) >= 27, sorted(CONTRACTS)
    assert sum(n.startswith("kernel/") for n in CONTRACTS) >= 11
    assert sum(n.startswith("key-flow/") for n in CONTRACTS) >= 5


def test_cli_json_report(tmp_path):
    """--json writes the machine-readable report CI uploads as an artifact."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(repo, "src"))
    path = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--only", "optimizer-dtype",
         "--json", str(path)],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(path.read_text())
    assert report["clean"] is True
    assert report["n_contracts"] == 1 and report["n_violations"] == 0
    entry = report["contracts"]["optimizer-dtype"]
    assert entry["violations"] == [] and "bf16" in entry["description"]


def test_cli_smoke():
    """`python -m repro.analysis --list` and a single cheap contract run in a
    fresh interpreter (forced onto CPU so the probe never touches a TPU)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(repo, "src"))
    listing = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list"],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert listing.returncode == 0, listing.stderr
    assert "fzoos-deferred/simulate" in listing.stdout
    single = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--only", "optimizer-dtype"],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert single.returncode == 0, single.stdout + single.stderr
    assert "1 contract(s) clean" in single.stdout
