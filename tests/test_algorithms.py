"""Algorithm-level tests: Prop. 1, query/communication accounting, one-round
execution of all five algorithms, and the paper's headline ordering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import algorithms as alg
from repro.core import fd as fdlib
from repro.core import objectives as obj


def _cfg(name, **kw):
    base = dict(
        name=name, dim=10, n_clients=4, local_steps=4, q=8, n_features=64,
        traj_capacity=48, active_per_iter=2, active_candidates=16,
        active_round_end=2, eta=0.01, lengthscale=0.5, noise=1e-5,
    )
    base.update(kw)
    return alg.AlgoConfig(**base)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_prop1_gamma_star_minimizes_disparity(seed):
    """Prop. 1: gamma* is the argmin of Xi(gamma) -- check against a grid."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    d = 6
    grad_f = jax.random.normal(k1, (d,))
    g_loc = jax.random.normal(k2, (d,))
    corr = jax.random.normal(k3, (d,))
    g_star = float(alg.optimal_gamma_star(grad_f, g_loc, corr))

    def xi(gamma):
        return float(alg.disparity(g_loc + gamma * corr, grad_f))

    for g in np.linspace(g_star - 2, g_star + 2, 41):
        assert xi(g_star) <= xi(float(g)) + 1e-5


def test_prop1_zero_disparity_iff_perfect_alignment():
    d = 5
    grad_f = jnp.arange(1.0, d + 1)
    g_loc = jnp.ones((d,))
    corr = grad_f - g_loc  # perfectly aligned drift
    assert float(alg.optimal_gamma_star(grad_f, g_loc, corr)) == pytest.approx(1.0, abs=1e-6)
    assert float(alg.disparity(g_loc + 1.0 * corr, grad_f)) == pytest.approx(0.0, abs=1e-10)


def test_query_accounting_static_vs_runtime():
    """The runtime query counters must match the static prediction."""
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 4, 10, 1.0, 0.001)
    for name in alg.ALGORITHMS:
        cfg = _cfg(name)
        res = alg.simulate(cfg, jax.random.PRNGKey(1), cobjs, obj.quadratic_query,
                           obj.quadratic_global_value, rounds=3)
        expected = 3 * cfg.queries_per_round()
        assert int(res.queries[-1]) == expected, (name, int(res.queries[-1]), expected)


def test_comm_accounting():
    fz = _cfg("fzoos", n_features=100)
    assert fz.comm_floats_per_round() == 10 + 100
    assert _cfg("fedzo").comm_floats_per_round() == 10
    assert _cfg("scaffold1").comm_floats_per_round() == 20
    assert _cfg("fedprox").comm_floats_per_round() == 10


@pytest.mark.parametrize("name", alg.ALGORITHMS)
def test_one_round_runs_and_is_finite(name):
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 4, 10, 5.0, 0.001)
    cfg = _cfg(name)
    res = alg.simulate(cfg, jax.random.PRNGKey(1), cobjs, obj.quadratic_query,
                       obj.quadratic_global_value, rounds=2)
    assert np.isfinite(np.asarray(res.f_values)).all()
    assert np.isfinite(np.asarray(res.xs)).all()
    assert bool(jnp.all((res.xs >= 0) & (res.xs <= 1)))


def test_fd_estimator_accuracy_improves_with_q():
    f = lambda cp, x, key: jnp.sum(x**2)  # noiseless query
    x = jnp.full((8,), 0.3)
    true = 2 * x

    def err(q, seed):
        dirs = fdlib.sample_directions(jax.random.PRNGKey(seed), q, 8)
        g = fdlib.fd_grad(f, None, x, jax.random.PRNGKey(seed + 1), dirs, 1e-4)
        return float(jnp.linalg.norm(g - true))

    e_small = np.mean([err(4, s) for s in range(5)])
    e_big = np.mean([err(64, s + 50) for s in range(5)])
    assert e_big < e_small


def test_fzoos_beats_fedzo_in_query_efficiency():
    """The paper's headline (Fig. 1): FZooS reaches a better F with FEWER
    queries than FedZO on the heterogeneous quadratic."""
    key = jax.random.PRNGKey(0)
    d, n = 20, 5
    cobjs = obj.make_quadratic(key, n, d, 5.0, 0.001)
    common = dict(dim=d, n_clients=n, local_steps=10, eta=0.005,
                  lengthscale=0.5, noise=1e-5)
    fz = alg.AlgoConfig(name="fzoos", n_features=256, traj_capacity=128,
                        active_per_iter=5, active_candidates=50, active_round_end=5,
                        **common)
    fd = alg.AlgoConfig(name="fedzo", q=20, fd_lambda=5e-3, **common)
    r_fz = alg.simulate(fz, jax.random.PRNGKey(1), cobjs, obj.quadratic_query,
                        obj.quadratic_global_value, rounds=15)
    r_fd = alg.simulate(fd, jax.random.PRNGKey(1), cobjs, obj.quadratic_query,
                        obj.quadratic_global_value, rounds=15)
    assert float(jnp.min(r_fz.f_values)) < float(jnp.min(r_fd.f_values)) + 5e-3
    assert int(r_fz.queries[-1]) < int(r_fd.queries[-1])


def test_round_resets_client_iterate_to_server_x():
    """After every round all clients hold the aggregated x (Algo. 2 line 3/7)."""
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 4, 6, 1.0, 0.001)
    cfg = _cfg("fzoos", dim=6)
    states = alg.init_states(cfg, jax.random.PRNGKey(1), jnp.full((6,), 0.5))
    mean_fn = lambda tree: jax.tree_util.tree_map(lambda a: jnp.mean(a, axis=0), tree)
    import repro.core.rff as rfflib

    rff = rfflib.make_rff(jax.random.PRNGKey(2), cfg.n_features, 6, cfg.lengthscale)
    states, stats = alg.run_round(cfg, rff, obj.quadratic_query, cobjs, states,
                                  jnp.full((6,), 0.5), mean_fn)
    xs = np.asarray(states.x)
    np.testing.assert_allclose(xs, np.broadcast_to(np.asarray(stats.server_x), xs.shape), atol=1e-6)
    # every client holds the SAME aggregated w (eq. 7 broadcast)
    wg = np.asarray(states.w_global)
    assert np.allclose(wg, wg[0:1], atol=1e-6)
