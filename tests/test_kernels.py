"""Pallas kernel validation: interpret-mode execution vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (8, 16, 64),      # tiny, all-padding path
    (64, 64, 256),    # block-aligned-ish
    (128, 128, 512),  # exactly aligned
    (130, 300, 513),  # deliberately misaligned everything
    (1, 2189, 1000),  # the paper's Covertype dims (d=2189, M=1e3)
]
DTYPES = [jnp.float32]


def _data(n, d, m, dtype, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(k1, (n, d), dtype)
    v = jax.random.normal(k2, (m, d), dtype)
    b = jax.random.uniform(k3, (m,), dtype, maxval=6.2831)
    w = jax.random.normal(k4, (m,), dtype)
    return x, v, b, w


@pytest.mark.parametrize("n,d,m", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rff_features_kernel(n, d, m, dtype):
    x, v, b, _ = _data(n, d, m, dtype)
    got = ops.rff_features(x, v, b, force_pallas=True, block_n=64, block_m=128)
    want = ref.rff_features(x, v, b)
    assert got.shape == want.shape == (n, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("n,d,m", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rff_grad_kernel(n, d, m, dtype):
    x, v, b, w = _data(n, d, m, dtype)
    got = ops.rff_grad(x, v, b, w, force_pallas=True, block_n=64, block_m=128)
    want = ref.rff_grad(x, v, b, w)
    assert got.shape == want.shape == (n, d)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(
        np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5
    )


@pytest.mark.parametrize("n,d,m", SHAPES)
def test_sqexp_kernel(n, d, m):
    x, v, _, _ = _data(n, d, m, jnp.float32)
    got = ops.sqexp(x, v, 1.3, force_pallas=True, block_n=64, block_m=64)
    want = ref.sqexp(x, v, 1.3)
    assert got.shape == want.shape == (n, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_kernels_match_core_math():
    """ops.* and the core GP/RFF modules must agree (single source of truth)."""
    from repro.core import gp_surrogate as gp
    from repro.core import rff as rfflib

    key = jax.random.PRNGKey(1)
    d, m = 7, 130
    params = rfflib.make_rff(key, m, d, 0.9)
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (9, d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (m,))

    np.testing.assert_allclose(
        np.asarray(ops.rff_features(xs, params.v, params.b, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(rfflib.features(params, xs)),
        atol=2e-5,
    )
    np.testing.assert_allclose(
        np.asarray(ops.rff_grad(xs, params.v, params.b, w, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(rfflib.grad_features_t_w_batch(params, xs, w)),
        atol=5e-5,
    )
    np.testing.assert_allclose(
        np.asarray(ops.sqexp(xs, xs, 0.9, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(gp.sqexp(xs, xs, 0.9)),
        atol=2e-6,
    )


# ---------------------------------------------------------------------------
# Fused GP-surrogate kernels (gp_score / gp_grad)
# ---------------------------------------------------------------------------

GP_SHAPES = [
    (4, 3, 16),       # all-padding path (n < block)
    (64, 8, 64),      # block-aligned candidates
    (100, 20, 128),   # the paper's active-query shape (n_cand=100, cap=128)
    (130, 5, 96),     # misaligned candidate count
]


def _gp_data(n, d, cap, seed=0):
    key = jax.random.PRNGKey(seed)
    cands = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (cap, d))
    a = jax.random.normal(jax.random.fold_in(key, 2), (cap, cap)) / np.sqrt(cap)
    binv = a @ a.T + 0.1 * jnp.eye(cap)  # any SPD stand-in for the Gram inverse
    pmat = binv * (xs @ xs.T)
    alpha = jax.random.normal(jax.random.fold_in(key, 3), (cap,))
    return cands, xs, binv, pmat, alpha


@pytest.mark.parametrize("n,d,cap", GP_SHAPES)
def test_uncertainty_scores_kernel(n, d, cap):
    cands, xs, binv, pmat, _ = _gp_data(n, d, cap)
    got = ops.uncertainty_scores(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_n=64, force_pallas=True,
    )
    want = ref.uncertainty_scores(cands, xs, binv, pmat, 0.8, d / 0.64)
    assert got.shape == want.shape == (n,)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)


@pytest.mark.parametrize("n,d,cap", GP_SHAPES)
def test_grad_mean_kernel(n, d, cap):
    cands, xs, _, _, alpha = _gp_data(n, d, cap)
    got = ops.grad_mean_batch(
        cands, xs, alpha, lengthscale=0.8, block_n=64, force_pallas=True
    )
    want = ref.grad_mean_batch(cands, xs, alpha, 0.8)
    assert got.shape == want.shape == (n, d)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)


def test_gp_kernels_match_surrogate_math():
    """ops fast paths == the first-principles gp_surrogate oracle."""
    from repro.core import gp_surrogate as gp

    cap, d = 48, 6
    key = jax.random.PRNGKey(4)
    hyper = gp.default_hyper(0.7, 1e-4)
    traj = gp.traj_init(cap, d)
    factor = gp.factor_init(traj, hyper)
    for i in range(10):
        xs = jax.random.uniform(jax.random.fold_in(key, i), (4, d))
        traj, factor = gp.traj_extend(traj, factor, xs, jnp.sin(xs.sum(-1)), hyper)
    xq = jax.random.uniform(jax.random.fold_in(key, 99), (9, d))

    u_direct = gp.grad_uncertainty_batch(traj, hyper, xq)
    u_fast = gp.grad_uncertainty_batch_cached(traj, factor, hyper, xq)
    np.testing.assert_allclose(np.asarray(u_fast), np.asarray(u_direct), atol=2e-3)

    alpha = gp.gp_alpha_cached(traj, factor, hyper)
    g_direct = jax.vmap(lambda x: gp.grad_mean_cached(traj, factor, hyper, x))(xq)
    g_fast = ops.grad_mean_batch(xq, traj.xs, alpha, lengthscale=0.7)
    np.testing.assert_allclose(np.asarray(g_fast), np.asarray(g_direct), atol=1e-5)


CLIENT_SHAPES = [
    (1, 4, 3, 16),     # single client (the per-device distributed shape)
    (3, 64, 8, 64),    # block-aligned candidates
    (8, 100, 20, 128), # the paper's active-query shape, 8 clients
    (5, 130, 5, 96),   # misaligned candidate count
]


def _gp_data_clients(nb, n, d, cap, seed=0):
    key = jax.random.PRNGKey(seed)
    cands = jax.random.uniform(jax.random.fold_in(key, 0), (nb, n, d))
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (nb, cap, d))
    a = jax.random.normal(jax.random.fold_in(key, 2), (nb, cap, cap)) / np.sqrt(cap)
    binv = jnp.einsum("bij,bkj->bik", a, a) + 0.1 * jnp.eye(cap)
    pmat = binv * jnp.einsum("bcd,bkd->bck", xs, xs)
    alpha = jax.random.normal(jax.random.fold_in(key, 3), (nb, cap))
    return cands, xs, binv, pmat, alpha


@pytest.mark.parametrize("nb,n,d,cap", CLIENT_SHAPES)
def test_uncertainty_scores_clients_kernel(nb, n, d, cap):
    """Client-batched kernel == batched oracle == vmap of the single-client
    oracle (the client grid dimension is a pure layout change)."""
    cands, xs, binv, pmat, _ = _gp_data_clients(nb, n, d, cap)
    got = ops.uncertainty_scores_clients(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_n=64, force_pallas=True,
    )
    want = ref.uncertainty_scores_clients(cands, xs, binv, pmat, 0.8, d / 0.64)
    single = jax.vmap(lambda c, x, b, p: ref.uncertainty_scores(c, x, b, p, 0.8, d / 0.64))(
        cands, xs, binv, pmat)
    assert got.shape == want.shape == (nb, n)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)
    np.testing.assert_allclose(np.asarray(want) / scale, np.asarray(single) / scale, atol=5e-5)


@pytest.mark.parametrize("nb,n,d,cap", CLIENT_SHAPES)
def test_grad_mean_clients_kernel(nb, n, d, cap):
    cands, xs, _, _, alpha = _gp_data_clients(nb, n, d, cap)
    got = ops.grad_mean_clients(
        cands, xs, alpha, lengthscale=0.8, block_n=64, force_pallas=True
    )
    want = ref.grad_mean_clients(cands, xs, alpha, 0.8)
    single = jax.vmap(lambda c, x, a: ref.grad_mean_batch(c, x, a, 0.8))(cands, xs, alpha)
    assert got.shape == want.shape == (nb, n, d)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)
    np.testing.assert_allclose(np.asarray(want) / scale, np.asarray(single) / scale, atol=5e-5)


def test_clients_kernels_candidate_padding_invariance():
    """The per-client candidate axis is zero-padded to the block multiple;
    padded rows yield junk that must be sliced away, and the client axis is
    NEVER padded (it is a grid dimension, any N launches)."""
    nb, n, d, cap = 3, 37, 6, 32  # n far from the 64 block
    cands, xs, binv, pmat, alpha = _gp_data_clients(nb, n, d, cap, seed=7)
    got = ops.uncertainty_scores_clients(
        cands, xs, binv, pmat, lengthscale=0.9, prior=d / 0.81,
        block_n=64, force_pallas=True,
    )
    assert got.shape == (nb, n)
    assert bool(jnp.isfinite(got).all())
    want = ref.uncertainty_scores_clients(cands, xs, binv, pmat, 0.9, d / 0.81)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)
    g_got = ops.grad_mean_clients(cands, xs, alpha, lengthscale=0.9,
                                  block_n=64, force_pallas=True)
    g_want = ref.grad_mean_clients(cands, xs, alpha, 0.9)
    gs = max(float(jnp.abs(g_want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(g_got) / gs, np.asarray(g_want) / gs, atol=5e-5)


def test_clients_kernels_traced_hyper_fall_back_to_oracle():
    cands, xs, binv, pmat, _ = _gp_data_clients(2, 16, 4, 32)

    @jax.jit
    def scores(ls):
        return ops.uncertainty_scores_clients(
            cands, xs, binv, pmat, lengthscale=ls, prior=4.0 / ls**2,
            force_pallas=True,
        )

    got = scores(jnp.asarray(0.8))
    want = ref.uncertainty_scores_clients(cands, xs, binv, pmat, 0.8, 4.0 / 0.64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gp_kernels_traced_hyper_fall_back_to_oracle():
    """Traced lengthscale (e.g. inside the jitted round loop) must not
    attempt to bake a tracer into the Pallas program."""
    cands, xs, binv, pmat, alpha = _gp_data(16, 4, 32)

    @jax.jit
    def scores(ls):
        return ops.uncertainty_scores(
            cands, xs, binv, pmat, lengthscale=ls, prior=4.0 / ls**2,
            force_pallas=True,
        )

    got = scores(jnp.asarray(0.8))
    want = ref.uncertainty_scores(cands, xs, binv, pmat, 0.8, 4.0 / 0.64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# ops padding paths: zero-row padding invariants (see ops.py docstrings)
# ---------------------------------------------------------------------------

ODD_SHAPES = [
    (1, 1, 1),        # degenerate: everything padded
    (5, 3, 17),       # tiny odd
    (129, 7, 257),    # one past a block boundary on both axes
    (63, 2189, 999),  # one short of a block boundary, paper-sized d
]


@pytest.mark.parametrize("n,d,m", ODD_SHAPES)
def test_padding_invariance_rff_features(n, d, m):
    x, v, b, _ = _data(n, d, m, jnp.float32, seed=3)
    got = ops.rff_features(x, v, b, force_pallas=True)  # default 128/256 blocks
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.rff_features(x, v, b)), atol=2e-5
    )


@pytest.mark.parametrize("n,d,m", ODD_SHAPES)
def test_padding_invariance_rff_grad(n, d, m):
    """Padded feature slots carry v == 0 AND w == 0: exactly zero
    contribution, so the sliced result equals the unpadded oracle."""
    x, v, b, w = _data(n, d, m, jnp.float32, seed=4)
    got = ops.rff_grad(x, v, b, w, force_pallas=True)
    want = ref.rff_grad(x, v, b, w)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(
        np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5
    )
    # The invariant itself, at the kernel level: zero-padded feature slots
    # (v == 0 AND w == 0) contribute nothing PROVIDED n_features still names
    # the live count -- the sqrt(2/M) normalization is part of phi's
    # definition, so padding without pinning n_features is NOT a no-op.
    from repro.kernels.rff_grad import rff_grad_kernel

    pad = 128 - (m % 128) if m % 128 else 128
    npad = 64 - (n % 64) if n % 64 else 0
    got_k = rff_grad_kernel(
        jnp.pad(x, ((0, npad), (0, 0))), jnp.pad(v, ((0, pad), (0, 0))),
        jnp.pad(b, (0, pad)), jnp.pad(w, (0, pad)),
        n_features=m, block_n=64, block_m=128, interpret=True,
    )[:n]
    np.testing.assert_allclose(
        np.asarray(got_k) / scale, np.asarray(want) / scale, atol=5e-5
    )


@pytest.mark.parametrize("n,d,m", ODD_SHAPES)
def test_padding_invariance_sqexp(n, d, m):
    """Padded rows produce exp(-||x||^2/2l^2) junk INSIDE the kernel; the
    wrapper must slice it away (padding is zeros, never NaN)."""
    x, v, _, _ = _data(n, d, m, jnp.float32, seed=5)
    got = ops.sqexp(x, v, 0.9, force_pallas=True)
    assert got.shape == (n, m)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.sqexp(x, v, 0.9)), atol=2e-6)
