"""Pallas kernel validation: interpret-mode execution vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (8, 16, 64),      # tiny, all-padding path
    (64, 64, 256),    # block-aligned-ish
    (128, 128, 512),  # exactly aligned
    (130, 300, 513),  # deliberately misaligned everything
    (1, 2189, 1000),  # the paper's Covertype dims (d=2189, M=1e3)
]
DTYPES = [jnp.float32]


def _data(n, d, m, dtype, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(k1, (n, d), dtype)
    v = jax.random.normal(k2, (m, d), dtype)
    b = jax.random.uniform(k3, (m,), dtype, maxval=6.2831)
    w = jax.random.normal(k4, (m,), dtype)
    return x, v, b, w


@pytest.mark.parametrize("n,d,m", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rff_features_kernel(n, d, m, dtype):
    x, v, b, _ = _data(n, d, m, dtype)
    got = ops.rff_features(x, v, b, force_pallas=True, block_n=64, block_m=128)
    want = ref.rff_features(x, v, b)
    assert got.shape == want.shape == (n, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("n,d,m", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rff_grad_kernel(n, d, m, dtype):
    x, v, b, w = _data(n, d, m, dtype)
    got = ops.rff_grad(x, v, b, w, force_pallas=True, block_n=64, block_m=128)
    want = ref.rff_grad(x, v, b, w)
    assert got.shape == want.shape == (n, d)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(
        np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5
    )


@pytest.mark.parametrize("n,d,m", SHAPES)
def test_sqexp_kernel(n, d, m):
    x, v, _, _ = _data(n, d, m, jnp.float32)
    got = ops.sqexp(x, v, 1.3, force_pallas=True, block_n=64, block_m=64)
    want = ref.sqexp(x, v, 1.3)
    assert got.shape == want.shape == (n, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_kernels_match_core_math():
    """ops.* and the core GP/RFF modules must agree (single source of truth)."""
    from repro.core import gp_surrogate as gp
    from repro.core import rff as rfflib

    key = jax.random.PRNGKey(1)
    d, m = 7, 130
    params = rfflib.make_rff(key, m, d, 0.9)
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (9, d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (m,))

    np.testing.assert_allclose(
        np.asarray(ops.rff_features(xs, params.v, params.b, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(rfflib.features(params, xs)),
        atol=2e-5,
    )
    np.testing.assert_allclose(
        np.asarray(ops.rff_grad(xs, params.v, params.b, w, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(rfflib.grad_features_t_w_batch(params, xs, w)),
        atol=5e-5,
    )
    np.testing.assert_allclose(
        np.asarray(ops.sqexp(xs, xs, 0.9, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(gp.sqexp(xs, xs, 0.9)),
        atol=2e-6,
    )
