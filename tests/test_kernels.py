"""Pallas kernel validation: interpret-mode execution vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (8, 16, 64),      # tiny, all-padding path
    (64, 64, 256),    # block-aligned-ish
    (128, 128, 512),  # exactly aligned
    (130, 300, 513),  # deliberately misaligned everything
    (1, 2189, 1000),  # the paper's Covertype dims (d=2189, M=1e3)
]
DTYPES = [jnp.float32]


def _data(n, d, m, dtype, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(k1, (n, d), dtype)
    v = jax.random.normal(k2, (m, d), dtype)
    b = jax.random.uniform(k3, (m,), dtype, maxval=6.2831)
    w = jax.random.normal(k4, (m,), dtype)
    return x, v, b, w


@pytest.mark.parametrize("n,d,m", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rff_features_kernel(n, d, m, dtype):
    x, v, b, _ = _data(n, d, m, dtype)
    got = ops.rff_features(x, v, b, force_pallas=True, block_n=64, block_m=128)
    want = ref.rff_features(x, v, b)
    assert got.shape == want.shape == (n, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("n,d,m", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rff_grad_kernel(n, d, m, dtype):
    x, v, b, w = _data(n, d, m, dtype)
    got = ops.rff_grad(x, v, b, w, force_pallas=True, block_n=64, block_m=128)
    want = ref.rff_grad(x, v, b, w)
    assert got.shape == want.shape == (n, d)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(
        np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5
    )


@pytest.mark.parametrize("n,d,m", SHAPES)
def test_sqexp_kernel(n, d, m):
    x, v, _, _ = _data(n, d, m, jnp.float32)
    got = ops.sqexp(x, v, 1.3, force_pallas=True, block_n=64, block_m=64)
    want = ref.sqexp(x, v, 1.3)
    assert got.shape == want.shape == (n, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_kernels_match_core_math():
    """ops.* and the core GP/RFF modules must agree (single source of truth)."""
    from repro.core import gp_surrogate as gp
    from repro.core import rff as rfflib

    key = jax.random.PRNGKey(1)
    d, m = 7, 130
    params = rfflib.make_rff(key, m, d, 0.9)
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (9, d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (m,))

    np.testing.assert_allclose(
        np.asarray(ops.rff_features(xs, params.v, params.b, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(rfflib.features(params, xs)),
        atol=2e-5,
    )
    np.testing.assert_allclose(
        np.asarray(ops.rff_grad(xs, params.v, params.b, w, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(rfflib.grad_features_t_w_batch(params, xs, w)),
        atol=5e-5,
    )
    np.testing.assert_allclose(
        np.asarray(ops.sqexp(xs, xs, 0.9, force_pallas=True, block_n=64, block_m=64)),
        np.asarray(gp.sqexp(xs, xs, 0.9)),
        atol=2e-6,
    )


# ---------------------------------------------------------------------------
# Fused GP-surrogate kernels (gp_score / gp_grad)
# ---------------------------------------------------------------------------

GP_SHAPES = [
    (4, 3, 16),       # all-padding path (n < block)
    (64, 8, 64),      # block-aligned candidates
    (100, 20, 128),   # the paper's active-query shape (n_cand=100, cap=128)
    (130, 5, 96),     # misaligned candidate count
]


def _gp_data(n, d, cap, seed=0):
    key = jax.random.PRNGKey(seed)
    cands = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (cap, d))
    a = jax.random.normal(jax.random.fold_in(key, 2), (cap, cap)) / np.sqrt(cap)
    binv = a @ a.T + 0.1 * jnp.eye(cap)  # any SPD stand-in for the Gram inverse
    pmat = binv * (xs @ xs.T)
    alpha = jax.random.normal(jax.random.fold_in(key, 3), (cap,))
    return cands, xs, binv, pmat, alpha


@pytest.mark.parametrize("n,d,cap", GP_SHAPES)
def test_uncertainty_scores_kernel(n, d, cap):
    cands, xs, binv, pmat, _ = _gp_data(n, d, cap)
    got = ops.uncertainty_scores(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_n=64, force_pallas=True,
    )
    want = ref.uncertainty_scores(cands, xs, binv, pmat, 0.8, d / 0.64)
    assert got.shape == want.shape == (n,)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)


@pytest.mark.parametrize("n,d,cap", GP_SHAPES)
def test_grad_mean_kernel(n, d, cap):
    cands, xs, _, _, alpha = _gp_data(n, d, cap)
    got = ops.grad_mean_batch(
        cands, xs, alpha, lengthscale=0.8, block_n=64, force_pallas=True
    )
    want = ref.grad_mean_batch(cands, xs, alpha, 0.8)
    assert got.shape == want.shape == (n, d)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)


def test_gp_kernels_match_surrogate_math():
    """ops fast paths == the first-principles gp_surrogate oracle."""
    from repro.core import gp_surrogate as gp

    cap, d = 48, 6
    key = jax.random.PRNGKey(4)
    hyper = gp.default_hyper(0.7, 1e-4)
    traj = gp.traj_init(cap, d)
    factor = gp.factor_init(traj, hyper)
    for i in range(10):
        xs = jax.random.uniform(jax.random.fold_in(key, i), (4, d))
        traj, factor = gp.traj_extend(traj, factor, xs, jnp.sin(xs.sum(-1)), hyper)
    xq = jax.random.uniform(jax.random.fold_in(key, 99), (9, d))

    u_direct = gp.grad_uncertainty_batch(traj, hyper, xq)
    u_fast = gp.grad_uncertainty_batch_cached(traj, factor, hyper, xq)
    np.testing.assert_allclose(np.asarray(u_fast), np.asarray(u_direct), atol=2e-3)

    alpha = gp.gp_alpha_cached(traj, factor, hyper)
    g_direct = jax.vmap(lambda x: gp.grad_mean_cached(traj, factor, hyper, x))(xq)
    g_fast = ops.grad_mean_batch(xq, traj.xs, alpha, lengthscale=0.7)
    np.testing.assert_allclose(np.asarray(g_fast), np.asarray(g_direct), atol=1e-5)


CLIENT_SHAPES = [
    (1, 4, 3, 16),     # single client (the per-device distributed shape)
    (3, 64, 8, 64),    # block-aligned candidates
    (8, 100, 20, 128), # the paper's active-query shape, 8 clients
    (5, 130, 5, 96),   # misaligned candidate count
]


def _gp_data_clients(nb, n, d, cap, seed=0):
    key = jax.random.PRNGKey(seed)
    cands = jax.random.uniform(jax.random.fold_in(key, 0), (nb, n, d))
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (nb, cap, d))
    a = jax.random.normal(jax.random.fold_in(key, 2), (nb, cap, cap)) / np.sqrt(cap)
    binv = jnp.einsum("bij,bkj->bik", a, a) + 0.1 * jnp.eye(cap)
    pmat = binv * jnp.einsum("bcd,bkd->bck", xs, xs)
    alpha = jax.random.normal(jax.random.fold_in(key, 3), (nb, cap))
    return cands, xs, binv, pmat, alpha


@pytest.mark.parametrize("nb,n,d,cap", CLIENT_SHAPES)
def test_uncertainty_scores_clients_kernel(nb, n, d, cap):
    """Client-batched kernel == batched oracle == vmap of the single-client
    oracle (the client grid dimension is a pure layout change)."""
    cands, xs, binv, pmat, _ = _gp_data_clients(nb, n, d, cap)
    got = ops.uncertainty_scores_clients(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_n=64, force_pallas=True,
    )
    want = ref.uncertainty_scores_clients(cands, xs, binv, pmat, 0.8, d / 0.64)
    single = jax.vmap(lambda c, x, b, p: ref.uncertainty_scores(c, x, b, p, 0.8, d / 0.64))(
        cands, xs, binv, pmat)
    assert got.shape == want.shape == (nb, n)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)
    np.testing.assert_allclose(np.asarray(want) / scale, np.asarray(single) / scale, atol=5e-5)


@pytest.mark.parametrize("nb,n,d,cap", CLIENT_SHAPES)
def test_grad_mean_clients_kernel(nb, n, d, cap):
    cands, xs, _, _, alpha = _gp_data_clients(nb, n, d, cap)
    got = ops.grad_mean_clients(
        cands, xs, alpha, lengthscale=0.8, block_n=64, force_pallas=True
    )
    want = ref.grad_mean_clients(cands, xs, alpha, 0.8)
    single = jax.vmap(lambda c, x, a: ref.grad_mean_batch(c, x, a, 0.8))(cands, xs, alpha)
    assert got.shape == want.shape == (nb, n, d)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)
    np.testing.assert_allclose(np.asarray(want) / scale, np.asarray(single) / scale, atol=5e-5)


def test_clients_kernels_candidate_padding_invariance():
    """The per-client candidate axis is zero-padded to the block multiple;
    padded rows yield junk that must be sliced away, and the client axis is
    NEVER padded (it is a grid dimension, any N launches)."""
    nb, n, d, cap = 3, 37, 6, 32  # n far from the 64 block
    cands, xs, binv, pmat, alpha = _gp_data_clients(nb, n, d, cap, seed=7)
    got = ops.uncertainty_scores_clients(
        cands, xs, binv, pmat, lengthscale=0.9, prior=d / 0.81,
        block_n=64, force_pallas=True,
    )
    assert got.shape == (nb, n)
    assert bool(jnp.isfinite(got).all())
    want = ref.uncertainty_scores_clients(cands, xs, binv, pmat, 0.9, d / 0.81)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5)
    g_got = ops.grad_mean_clients(cands, xs, alpha, lengthscale=0.9,
                                  block_n=64, force_pallas=True)
    g_want = ref.grad_mean_clients(cands, xs, alpha, 0.9)
    gs = max(float(jnp.abs(g_want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(g_got) / gs, np.asarray(g_want) / gs, atol=5e-5)


def test_clients_kernels_traced_hyper_fall_back_to_oracle():
    cands, xs, binv, pmat, _ = _gp_data_clients(2, 16, 4, 32)

    @jax.jit
    def scores(ls):
        return ops.uncertainty_scores_clients(
            cands, xs, binv, pmat, lengthscale=ls, prior=4.0 / ls**2,
            force_pallas=True,
        )

    got = scores(jnp.asarray(0.8))
    want = ref.uncertainty_scores_clients(cands, xs, binv, pmat, 0.8, 4.0 / 0.64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gp_kernels_traced_hyper_fall_back_to_oracle():
    """Traced lengthscale (e.g. inside the jitted round loop) must not
    attempt to bake a tracer into the Pallas program."""
    cands, xs, binv, pmat, alpha = _gp_data(16, 4, 32)

    @jax.jit
    def scores(ls):
        return ops.uncertainty_scores(
            cands, xs, binv, pmat, lengthscale=ls, prior=4.0 / ls**2,
            force_pallas=True,
        )

    got = scores(jnp.asarray(0.8))
    want = ref.uncertainty_scores(cands, xs, binv, pmat, 0.8, 4.0 / 0.64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# ops padding paths: zero-row padding invariants (see ops.py docstrings)
# ---------------------------------------------------------------------------

ODD_SHAPES = [
    (1, 1, 1),        # degenerate: everything padded
    (5, 3, 17),       # tiny odd
    (129, 7, 257),    # one past a block boundary on both axes
    (63, 2189, 999),  # one short of a block boundary, paper-sized d
]


@pytest.mark.parametrize("n,d,m", ODD_SHAPES)
def test_padding_invariance_rff_features(n, d, m):
    x, v, b, _ = _data(n, d, m, jnp.float32, seed=3)
    got = ops.rff_features(x, v, b, force_pallas=True)  # default 128/256 blocks
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.rff_features(x, v, b)), atol=2e-5
    )


@pytest.mark.parametrize("n,d,m", ODD_SHAPES)
def test_padding_invariance_rff_grad(n, d, m):
    """Padded feature slots carry v == 0 AND w == 0: exactly zero
    contribution, so the sliced result equals the unpadded oracle."""
    x, v, b, w = _data(n, d, m, jnp.float32, seed=4)
    got = ops.rff_grad(x, v, b, w, force_pallas=True)
    want = ref.rff_grad(x, v, b, w)
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(
        np.asarray(got) / scale, np.asarray(want) / scale, atol=5e-5
    )
    # The invariant itself, at the kernel level: zero-padded feature slots
    # (v == 0 AND w == 0) contribute nothing PROVIDED n_features still names
    # the live count -- the sqrt(2/M) normalization is part of phi's
    # definition, so padding without pinning n_features is NOT a no-op.
    from repro.kernels.rff_grad import rff_grad_kernel

    pad = 128 - (m % 128) if m % 128 else 128
    npad = 64 - (n % 64) if n % 64 else 0
    got_k = rff_grad_kernel(
        jnp.pad(x, ((0, npad), (0, 0))), jnp.pad(v, ((0, pad), (0, 0))),
        jnp.pad(b, (0, pad)), jnp.pad(w, (0, pad)),
        n_features=m, block_n=64, block_m=128, interpret=True,
    )[:n]
    np.testing.assert_allclose(
        np.asarray(got_k) / scale, np.asarray(want) / scale, atol=5e-5
    )


@pytest.mark.parametrize("n,d,m", ODD_SHAPES)
def test_padding_invariance_sqexp(n, d, m):
    """Padded rows produce exp(-||x||^2/2l^2) junk INSIDE the kernel; the
    wrapper must slice it away (padding is zeros, never NaN)."""
    x, v, _, _ = _data(n, d, m, jnp.float32, seed=5)
    got = ops.sqexp(x, v, 0.9, force_pallas=True)
    assert got.shape == (n, m)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.sqexp(x, v, 0.9)), atol=2e-6)


# ---------------------------------------------------------------------------
# Cap-axis tiling (gp_score / gp_grad tiled kernels) + block autotuner
# ---------------------------------------------------------------------------

def _norm_close(got, want, atol):
    scale = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale, atol=atol)


@pytest.mark.parametrize("cap,block_cap", [(256, 128), (512, 128), (1024, 256)])
def test_tiled_scores_match_oracle(cap, block_cap):
    """Cap-tiled scoring == oracle at caps the resident kernel cannot hold."""
    n, d = 32, 8
    cands, xs, binv, pmat, _ = _gp_data(n, d, cap)
    got = ops.uncertainty_scores(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_n=32, block_cap=block_cap, force_pallas=True,
    )
    want = ref.uncertainty_scores(cands, xs, binv, pmat, 0.8, d / 0.64)
    _norm_close(got, want, 5e-5)


@pytest.mark.parametrize("cap,block_cap", [(512, 128), (1024, 512)])
def test_tiled_grad_mean_match_oracle(cap, block_cap):
    n, d = 32, 8
    cands, xs, _, _, alpha = _gp_data(n, d, cap)
    got = ops.grad_mean_batch(
        cands, xs, alpha, lengthscale=0.8,
        block_n=32, block_cap=block_cap, force_pallas=True,
    )
    want = ref.grad_mean_batch(cands, xs, alpha, 0.8)
    _norm_close(got, want, 5e-5)


def test_tiled_clients_match_oracle_cap1024():
    """Interpret-mode parity at the scale-out target cap=1024, both families."""
    nb, n, d, cap = 2, 16, 6, 1024
    cands, xs, binv, pmat, alpha = _gp_data_clients(nb, n, d, cap)
    got_s = ops.uncertainty_scores_clients(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_n=16, block_cap=512, force_pallas=True,
    )
    _norm_close(got_s, ref.uncertainty_scores_clients(cands, xs, binv, pmat, 0.8, d / 0.64), 5e-5)
    got_g = ops.grad_mean_clients(
        cands, xs, alpha, lengthscale=0.8,
        block_n=16, block_cap=512, force_pallas=True,
    )
    _norm_close(got_g, ref.grad_mean_clients(cands, xs, alpha, 0.8), 5e-5)


def test_tiled_cap_padding_exact_zero_invariance():
    """cap NOT a multiple of block_cap: the wrapper zero-pads the trajectory
    axis.  Padded slots must contribute EXACTLY zero (zero B/P rows+columns
    for scores, zero alpha for the grad mean), so padding to 256 vs manually
    padding further to 384 is BITWISE identical -- extra zero tiles only add
    exact zeros to the f32 accumulators."""
    n, d, cap = 32, 8, 200  # 200 % 128 != 0 -> wrapper pads to 256
    cands, xs, binv, pmat, alpha = _gp_data(n, d, cap)

    s_auto = ops.uncertainty_scores(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_n=32, block_cap=128, force_pallas=True,
    )
    xs384 = jnp.pad(xs, ((0, 384 - cap), (0, 0)))
    b384 = jnp.pad(binv, ((0, 384 - cap), (0, 384 - cap)))
    p384 = jnp.pad(pmat, ((0, 384 - cap), (0, 384 - cap)))
    s_manual = ops.uncertainty_scores(
        cands, xs384, b384, p384, lengthscale=0.8, prior=d / 0.64,
        block_n=32, block_cap=128, force_pallas=True,
    )
    np.testing.assert_array_equal(np.asarray(s_auto), np.asarray(s_manual))
    _norm_close(s_auto, ref.uncertainty_scores(cands, xs, binv, pmat, 0.8, d / 0.64), 5e-5)

    g_auto = ops.grad_mean_batch(
        cands, xs, alpha, lengthscale=0.8, block_n=32, block_cap=128, force_pallas=True
    )
    g_manual = ops.grad_mean_batch(
        cands, xs384, jnp.pad(alpha, (0, 384 - cap)), lengthscale=0.8,
        block_n=32, block_cap=128, force_pallas=True,
    )
    np.testing.assert_array_equal(np.asarray(g_auto), np.asarray(g_manual))
    _norm_close(g_auto, ref.grad_mean_batch(cands, xs, alpha, 0.8), 5e-5)


def test_tiled_clients_vs_per_client_bit_parity():
    """The client grid dimension is a pure layout change: the batched tiled
    kernel must be BITWISE identical to running the single-client tiled
    kernel once per client with the same blocks."""
    nb, n, d, cap = 3, 32, 6, 256
    cands, xs, binv, pmat, alpha = _gp_data_clients(nb, n, d, cap, seed=11)
    kw = dict(block_n=32, block_cap=128, force_pallas=True)
    s_batched = ops.uncertainty_scores_clients(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64, **kw
    )
    g_batched = ops.grad_mean_clients(cands, xs, alpha, lengthscale=0.8, **kw)
    for b in range(nb):
        s_one = ops.uncertainty_scores(
            cands[b], xs[b], binv[b], pmat[b], lengthscale=0.8, prior=d / 0.64, **kw
        )
        np.testing.assert_array_equal(np.asarray(s_batched[b]), np.asarray(s_one))
        g_one = ops.grad_mean_batch(cands[b], xs[b], alpha[b], lengthscale=0.8, **kw)
        np.testing.assert_array_equal(np.asarray(g_batched[b]), np.asarray(g_one))


def test_fused_epilogue_ref_matches_textbook():
    """ref.uncertainty_scores_clients_fused (the CPU execution path and the
    Pallas epilogue) is algebraically identical to the textbook oracle."""
    for seed, (nb, n, d, cap) in enumerate([(2, 64, 8, 64), (4, 100, 20, 128), (1, 37, 5, 96)]):
        cands, xs, binv, pmat, _ = _gp_data_clients(nb, n, d, cap, seed=seed)
        want = ref.uncertainty_scores_clients(cands, xs, binv, pmat, 0.8, d / 0.64)
        got = ref.uncertainty_scores_clients_fused(cands, xs, binv, pmat, 0.8, d / 0.64)
        _norm_close(got, want, 2e-5)


def test_autotune_deterministic_and_feasible():
    from repro.kernels import autotune

    autotune.clear_cache()
    picks = [
        autotune.select_blocks("score", n=100, cap=1024, d=20, n_clients=64, backend=b)
        for b in ("tpu", "cpu", "tpu")
    ]
    assert picks[0] == picks[2]  # deterministic (and cached)
    for bn, bc in picks:
        assert bn in autotune._BLOCK_N_CANDIDATES
        assert bc in autotune._BLOCK_CAP_CANDIDATES
    # The scale-out premise: cap=1024 does NOT fit resident on tpu VMEM.
    assert picks[0][1] < 1024
    # Small shapes stay resident (no tiling overhead when everything fits).
    bn, bc = autotune.select_blocks("score", n=100, cap=128, d=20, n_clients=8, backend="tpu")
    assert bc >= 128


def test_autotune_dtype_keys_cache_and_default_is_f32():
    """The tuner cache distinguishes dtypes; omitting dtype == explicit f32
    (bitwise-identical picks for every pre-dtype caller)."""
    from repro.kernels import autotune

    autotune.clear_cache()
    shape = dict(n=64, cap=2048, d=16, n_clients=4, backend="tpu")
    default = autotune.select_blocks("score", **shape)
    explicit = autotune.select_blocks("score", **shape, dtype=jnp.float32)
    assert default == explicit
    # Distinct key components per dtype, f32 key == no-dtype key.
    kf = autotune.cache_key("score", "tpu", 4, 64, 2048, 16)
    assert kf == autotune.cache_key("score", "tpu", 4, 64, 2048, 16, jnp.float32)
    kb = autotune.cache_key("score", "tpu", 4, 64, 2048, 16, jnp.bfloat16)
    assert kf != kb
    # Both entries coexist in the cache; the bf16 feasibility set is at
    # least as large (halved working set), so its pick is independent.
    bf16 = autotune.select_blocks("score", **shape, dtype=jnp.bfloat16)
    assert kf in autotune._CACHE and kb in autotune._CACHE
    assert bf16[0] in autotune._BLOCK_N_CANDIDATES
    assert bf16[1] in autotune._BLOCK_CAP_CANDIDATES


def test_autotune_explicit_blocks_override():
    """AlgoConfig-pinned blocks must bypass the tuner entirely."""
    n, d, cap = 32, 8, 256
    cands, xs, binv, pmat, _ = _gp_data(n, d, cap)
    want = ops.uncertainty_scores(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_n=32, block_cap=128, force_pallas=True,
    )
    # Pin only one of the two: the other comes from the tuner.
    got = ops.uncertainty_scores(
        cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
        block_cap=128, force_pallas=True,
    )
    _norm_close(got, ref.uncertainty_scores(cands, xs, binv, pmat, 0.8, d / 0.64), 5e-5)
    assert want.shape == got.shape


def test_validate_blocks_rejects_over_budget_pins():
    """An AlgoConfig block pin the VMEM budget cannot hold must fail loudly
    (naming the blocks and the budget), not as an opaque Mosaic error."""
    from repro.kernels import autotune

    with pytest.raises(ValueError) as e:
        autotune.validate_blocks("score", block_n=256, block_cap=1024,
                                 cap=2048, d=256, backend="tpu")
    msg = str(e.value)
    assert "block_n=256" in msg and "block_cap=1024" in msg
    assert "budget" in msg and "bytes" in msg
    # an in-budget pin passes through untouched
    assert autotune.validate_blocks(
        "score", block_n=32, block_cap=128, cap=2048, d=256, backend="tpu"
    ) == (32, 128)
    # block_cap >= cap routes resident: the pin is judged at the REAL
    # working set (lane-padded cap), so a nominal huge block_cap is fine
    # when the trajectory itself fits
    assert autotune.validate_blocks(
        "score", block_n=32, block_cap=1 << 20, cap=256, d=16, backend="tpu"
    ) == (32, 1 << 20)


def test_ops_reject_over_budget_pins_before_launch():
    n, d, cap = 8, 256, 2048
    cands, xs, binv, pmat, _ = _gp_data(n, d, cap, seed=3)
    with pytest.raises(ValueError, match="VMEM"):
        ops.uncertainty_scores(
            cands, xs, binv, pmat, lengthscale=0.8, prior=d / 0.64,
            block_n=256, block_cap=1024, force_pallas=True,
        )
    # grad's working set is lighter (no (bc, bc) Gram tiles): it takes
    # d=2048 for the same pin to genuinely blow the budget
    with pytest.raises(ValueError, match="VMEM"):
        ops.grad_mean_batch(
            jnp.zeros((8, 2048)), jnp.zeros((2048, 2048)), jnp.zeros((2048,)),
            lengthscale=0.8, block_n=256, block_cap=1024, force_pallas=True,
        )


# ---------------------------------------------------------------------------
# bf16 inputs + f32 scratch: tiled-kernel interpret-mode parity
# ---------------------------------------------------------------------------


def _to_bf16(*arrays):
    return tuple(a.astype(jnp.bfloat16) for a in arrays)


def test_tiled_scores_bf16_inputs_f32_scratch_parity():
    """bf16 inputs through the cap-tiled scoring kernel: the f32 scratch
    accumulator keeps the error at input-quantization level (~bf16 eps),
    NOT at sum-length level -- compared against the f32 oracle."""
    from repro.kernels.gp_score import score_tiled_spec

    n, d, cap = 32, 8, 256
    spec = score_tiled_spec(n, cap, d, jnp.bfloat16, block_n=32, block_cap=128)
    assert all(jnp.dtype(s.dtype) == jnp.float32 for s in spec.scratch)
    cands, xs, binv, pmat, _ = _gp_data(n, d, cap)
    got = ops.uncertainty_scores(
        *_to_bf16(cands, xs, binv, pmat), lengthscale=0.8, prior=d / 0.64,
        block_n=32, block_cap=128, force_pallas=True,
    )
    assert got.dtype == jnp.bfloat16
    want = ref.uncertainty_scores(cands, xs, binv, pmat, 0.8, d / 0.64)
    _norm_close(got.astype(jnp.float32), want, 4e-2)


def test_tiled_grad_mean_bf16_inputs_f32_scratch_parity():
    from repro.kernels.gp_grad import grad_tiled_spec

    n, d, cap = 32, 8, 256
    spec = grad_tiled_spec(n, cap, d, jnp.bfloat16, block_n=32, block_cap=128)
    assert all(jnp.dtype(s.dtype) == jnp.float32 for s in spec.scratch)
    cands, xs, _, _, alpha = _gp_data(n, d, cap)
    got = ops.grad_mean_batch(
        *_to_bf16(cands, xs, alpha), lengthscale=0.8,
        block_n=32, block_cap=128, force_pallas=True,
    )
    assert got.dtype == jnp.bfloat16
    want = ref.grad_mean_batch(cands, xs, alpha, 0.8)
    _norm_close(got.astype(jnp.float32), want, 4e-2)


def test_algo_config_block_overrides_thread_through():
    """score_block_*/grad_block_* reach the kernels via gp_surrogate without
    changing results (tiling is value-preserving)."""
    from repro.core import gp_surrogate as gp

    nb, d, cap = 2, 4, 64
    key = jax.random.PRNGKey(3)
    hyper = gp.default_hyper(0.7, 1e-4)
    trajs = jax.vmap(lambda _: gp.traj_init(cap, d))(jnp.arange(nb))
    factors = jax.vmap(gp.factor_init, in_axes=(0, None))(trajs, hyper)
    xs = jax.random.uniform(jax.random.fold_in(key, 0), (nb, 6, d))
    ys = jnp.sin(xs.sum(-1))
    trajs, factors = gp.traj_extend_clients(trajs, factors, xs, ys, hyper)
    xq = jax.random.uniform(jax.random.fold_in(key, 1), (nb, 8, d))

    u_default = gp.grad_uncertainty_batch_cached_clients(trajs, factors, hyper, xq)
    u_pinned = gp.grad_uncertainty_batch_cached_clients(
        trajs, factors, hyper, xq, block_n=8, block_cap=128
    )
    np.testing.assert_allclose(np.asarray(u_pinned), np.asarray(u_default), atol=1e-5)

    g_default = gp.grad_mean_cached_clients(trajs, factors, hyper, xq[:, 0, :])
    g_pinned = gp.grad_mean_cached_clients(
        trajs, factors, hyper, xq[:, 0, :], block_n=8, block_cap=128
    )
    np.testing.assert_allclose(np.asarray(g_pinned), np.asarray(g_default), atol=1e-5)
