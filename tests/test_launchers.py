"""Launcher regression tests: launch/train.py resume-at-end and the
launch/fedzoo.py CLI driven end-to-end on the quadratic objective."""

import sys

import pytest

from repro.launch import fedzoo as fedzoo_launch
from repro.launch import train as train_launch


def _run_main(monkeypatch, module, argv):
    monkeypatch.setattr(sys, "argv", [f"{module.__name__}"] + argv)
    module.main()


TRAIN_ARGS = ["--arch", "qwen1_5_0_5b", "--variant", "smoke", "--steps", "2",
              "--batch-size", "1", "--seq-len", "16", "--ckpt-every", "1"]


def test_train_resume_at_end_regression(monkeypatch, tmp_path, capsys):
    """A restored checkpoint with start >= --steps used to leave `metrics`
    unbound at the trailing save_train_state (NameError)."""
    ckpt = str(tmp_path / "train_ckpt")
    _run_main(monkeypatch, train_launch, TRAIN_ARGS + ["--ckpt-dir", ckpt])
    out = capsys.readouterr().out
    assert "done." in out

    # second invocation restores step 2 >= steps 2: loop body never runs
    _run_main(monkeypatch, train_launch, TRAIN_ARGS + ["--ckpt-dir", ckpt])
    out = capsys.readouterr().out
    assert "restored step 2" in out
    assert "nothing to do" in out


@pytest.mark.parametrize("extra", [
    ["--algo", "fzoos", "--chunk", "5"],
    ["--algo", "fedzo", "--chunk", "0"],
])
def test_fedzoo_cli_smoke_quadratic(monkeypatch, capsys, extra):
    """fedzoo.main() runs end-to-end on the quadratic and the progress table
    includes the FINAL round even when rounds % stride != 0 (seed bug:
    --rounds 7 with stride 1..10 never printed round 7 for e.g. 25/10)."""
    argv = ["--objective", "quadratic", "--dim", "6", "--clients", "4",
            "--rounds", "7", "--local-steps", "2", "--features", "16",
            "--traj-cap", "16", "--lengthscale", "0.5", "--gp-noise", "1e-5",
            "--gamma-mode", "inv_t"] + extra
    _run_main(monkeypatch, fedzoo_launch, argv)
    out = capsys.readouterr().out
    assert "F(x_0)" in out
    assert "round    7" in out  # final round always shown


def test_fedzoo_cli_final_round_not_on_stride(monkeypatch, capsys):
    """rounds=25 -> stride 2: the seed table stopped at 24."""
    argv = ["--objective", "quadratic", "--dim", "4", "--clients", "2",
            "--rounds", "25", "--local-steps", "1", "--algo", "fedzo",
            "--q", "2", "--chunk", "25"]
    _run_main(monkeypatch, fedzoo_launch, argv)
    out = capsys.readouterr().out
    assert "round   24" in out
    assert "round   25" in out
