"""Launcher regression tests: launch/train.py resume-at-end and the
launch/fedzoo.py CLI driven end-to-end on the quadratic objective."""

import sys

import pytest

from repro.launch import fedzoo as fedzoo_launch
from repro.launch import train as train_launch


def _run_main(monkeypatch, module, argv):
    monkeypatch.setattr(sys, "argv", [f"{module.__name__}"] + argv)
    module.main()


TRAIN_ARGS = ["--arch", "qwen1_5_0_5b", "--variant", "smoke", "--steps", "2",
              "--batch-size", "1", "--seq-len", "16", "--ckpt-every", "1"]


def test_train_resume_at_end_regression(monkeypatch, tmp_path, capsys):
    """A restored checkpoint with start >= --steps used to leave `metrics`
    unbound at the trailing save_train_state (NameError)."""
    ckpt = str(tmp_path / "train_ckpt")
    _run_main(monkeypatch, train_launch, TRAIN_ARGS + ["--ckpt-dir", ckpt])
    out = capsys.readouterr().out
    assert "done." in out

    # second invocation restores step 2 >= steps 2: loop body never runs
    _run_main(monkeypatch, train_launch, TRAIN_ARGS + ["--ckpt-dir", ckpt])
    out = capsys.readouterr().out
    assert "restored step 2" in out
    assert "nothing to do" in out


@pytest.mark.parametrize("extra", [
    ["--algo", "fzoos", "--chunk", "5"],
    ["--algo", "fedzo", "--chunk", "0"],
])
def test_fedzoo_cli_smoke_quadratic(monkeypatch, capsys, extra):
    """fedzoo.main() runs end-to-end on the quadratic and the progress table
    includes the FINAL round even when rounds % stride != 0 (seed bug:
    --rounds 7 with stride 1..10 never printed round 7 for e.g. 25/10)."""
    argv = ["--objective", "quadratic", "--dim", "6", "--clients", "4",
            "--rounds", "7", "--local-steps", "2", "--features", "16",
            "--traj-cap", "16", "--lengthscale", "0.5", "--gp-noise", "1e-5",
            "--gamma-mode", "inv_t"] + extra
    _run_main(monkeypatch, fedzoo_launch, argv)
    out = capsys.readouterr().out
    assert "F(x_0)" in out
    assert "round    7" in out  # final round always shown


def test_launch_common_config_from_args_round_trip():
    """The shared flag builder (launch/common.py) maps every flag onto its
    AlgoConfig field -- the single source the launcher AND the benchmark
    configs go through, so there is no drift surface left."""
    import argparse

    from repro.launch import common

    ap = argparse.ArgumentParser()
    common.add_algo_flags(ap)
    common.add_engine_flags(ap)
    args = ap.parse_args([
        "--algo", "fzoos", "--local-steps", "3", "--eta", "0.02", "--q", "4",
        "--features", "32", "--traj-cap", "24", "--lengthscale", "0.7",
        "--gp-noise", "1e-4", "--gamma-mode", "const", "--gamma-const", "0.5",
        "--no-defer-repair", "--eval-every", "4",
    ])
    cfg = common.config_from_args(args, dim=6, n_clients=3)
    assert cfg.name == "fzoos" and cfg.dim == 6 and cfg.n_clients == 3
    assert cfg.local_steps == 3 and cfg.eta == 0.02 and cfg.q == 4
    assert cfg.n_features == 32 and cfg.traj_capacity == 24
    assert cfg.lengthscale == 0.7 and cfg.noise == 1e-4
    assert cfg.gamma_mode == "const" and cfg.gamma_const == 0.5
    assert cfg.defer_repair is False and cfg.use_factor_cache is True
    assert args.eval_every == 4

    # defaults keep the deferred engine on
    cfg2 = common.config_from_args(ap.parse_args([]), dim=4, n_clients=2)
    assert cfg2.defer_repair is True and cfg2.deferred

    # programmatic twin rejects drifted keys loudly
    with pytest.raises(TypeError):
        common.make_config("fzoos", dim=4, n_clients=2, not_a_field=1)


def test_fedzoo_cli_eval_every(monkeypatch, capsys):
    """--eval-every skips the global eval on off-rounds (NaN in the table)
    but always evaluates the final round."""
    argv = ["--objective", "quadratic", "--dim", "4", "--clients", "2",
            "--rounds", "5", "--local-steps", "1", "--features", "8",
            "--traj-cap", "8", "--eval-every", "5", "--chunk", "5"]
    _run_main(monkeypatch, fedzoo_launch, argv)
    out = capsys.readouterr().out
    assert "round    5" in out
    assert "nan" in out  # skipped rounds visible as NaN rows


def test_fedzoo_cli_final_round_not_on_stride(monkeypatch, capsys):
    """rounds=25 -> stride 2: the seed table stopped at 24."""
    argv = ["--objective", "quadratic", "--dim", "4", "--clients", "2",
            "--rounds", "25", "--local-steps", "1", "--algo", "fedzo",
            "--q", "2", "--chunk", "25"]
    _run_main(monkeypatch, fedzoo_launch, argv)
    out = capsys.readouterr().out
    assert "round   24" in out
    assert "round   25" in out


def test_fedzoo_cli_ckpt_flags(monkeypatch, capsys, tmp_path):
    """--ckpt-dir/--ckpt-every/--sync-ckpt wire through to the scan engine
    and the run leaves a complete final checkpoint behind."""
    from repro.checkpoint import latest_step

    ckpt = str(tmp_path / "cli_ckpt")
    argv = ["--objective", "quadratic", "--dim", "4", "--clients", "2",
            "--rounds", "4", "--local-steps", "1", "--algo", "fedzo",
            "--q", "2", "--chunk", "2", "--ckpt-dir", ckpt, "--ckpt-every",
            "2", "--sync-ckpt"]
    _run_main(monkeypatch, fedzoo_launch, argv)
    out = capsys.readouterr().out
    assert "F(x_0)" in out
    assert latest_step(ckpt) == 4


def test_serve_prefill_respects_temperature(monkeypatch):
    """Regression: the FIRST generated token was hard-wired to greedy argmax
    over the prefill logits even with --temperature > 0, so every sampled
    run opened identically.  The prefill step must use the same
    temperature/categorical rule as decode steps."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch import serve

    batch = 2
    prefill_logits = jnp.asarray(
        [[2.0, 1.8, 1.6, 1.4], [1.0, 2.0, 1.7, 1.5]], jnp.float32)
    decode_logits = jnp.zeros((batch, 4), jnp.float32).at[:, 3].set(5.0)

    monkeypatch.setattr(
        serve, "prefill",
        lambda p, cfg, b, policy, cache_len: (prefill_logits, jnp.zeros((1,))))
    monkeypatch.setattr(
        serve, "decode_step",
        lambda p, cfg, c, t, policy: (decode_logits, c))

    # pick a key whose categorical draw differs from argmax -- on the old
    # greedy-prefill code this test then fails deterministically
    temp = 2.0
    for seed in range(64):
        key = jax.random.PRNGKey(seed)
        _, sub = jax.random.split(key)
        expect = np.asarray(
            jax.random.categorical(sub, prefill_logits / temp))
        if (expect != np.asarray(jnp.argmax(prefill_logits, -1))).any():
            break
    else:  # pragma: no cover - 64 straight argmax draws is ~impossible
        pytest.fail("no differing seed found")

    out, _ = serve.generate(None, None, {}, None, gen_len=3, cache_len=8,
                            temperature=temp, key=key)
    assert out.shape == (batch, 3)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), expect)

    # greedy contract unchanged at temperature 0
    out0, _ = serve.generate(None, None, {}, None, gen_len=2, cache_len=8,
                             temperature=0.0, key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(
        np.asarray(out0[:, 0]), np.asarray(jnp.argmax(prefill_logits, -1)))
    np.testing.assert_array_equal(np.asarray(out0[:, 1]), [3, 3])

    # the sampling helper itself: argmax at 0, categorical otherwise
    k = jax.random.PRNGKey(7)
    np.testing.assert_array_equal(
        np.asarray(serve.sample_token(prefill_logits, 0.0, k)[:, 0]),
        np.asarray(jnp.argmax(prefill_logits, -1)))
    np.testing.assert_array_equal(
        np.asarray(serve.sample_token(prefill_logits, temp, k)[:, 0]),
        np.asarray(jax.random.categorical(k, prefill_logits / temp)))
