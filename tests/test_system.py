"""End-to-end behaviour tests: the paper's algorithm on its objectives, the
LM training substrate, and serving -- the whole stack wired together."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import model_objectives as mobj
from repro.core import objectives as obj


def test_fzoos_converges_on_paper_quadratic():
    """Sec. 6.1 protocol (scaled down): FZooS drives F toward F* on the
    heterogeneous quadratic."""
    key = jax.random.PRNGKey(0)
    d, n = 20, 5
    cobjs = obj.make_quadratic(key, n, d, 5.0, 0.001)
    cfg = alg.AlgoConfig(
        name="fzoos", dim=d, n_clients=n, local_steps=10, eta=0.005,
        n_features=256, traj_capacity=128, active_per_iter=5,
        active_candidates=50, active_round_end=5, lengthscale=0.5, noise=1e-5,
    )
    res = alg.simulate(cfg, jax.random.PRNGKey(1), cobjs, obj.quadratic_query,
                       obj.quadratic_global_value, rounds=15)
    f0 = float(res.f_values[0])
    fbest = float(jnp.min(res.f_values))
    fstar = obj.quadratic_fstar(d)
    assert fbest < f0  # improved
    assert fbest - fstar < 0.4 * (f0 - fstar)  # closed >60% of the gap


def test_gamma_zero_equals_no_correction():
    """FZooS with gamma == 0 must ignore the correction entirely (reduces to
    pure surrogate descent) -- eq. (2) consistency."""
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 3, 8, 5.0, 0.001)
    base = dict(dim=8, n_clients=3, local_steps=4, n_features=64,
                traj_capacity=48, active_per_iter=1, active_candidates=8,
                active_round_end=1, lengthscale=0.5)
    c0 = alg.AlgoConfig(name="fzoos", gamma_mode="const", gamma_const=0.0, **base)
    r0 = alg.simulate(c0, jax.random.PRNGKey(1), cobjs, obj.quadratic_query,
                      obj.quadratic_global_value, rounds=3)
    # w aggregation happens but with gamma=0 it cannot influence x
    c1 = alg.AlgoConfig(name="fzoos", gamma_mode="const", gamma_const=1.0, **base)
    r1 = alg.simulate(c1, jax.random.PRNGKey(1), cobjs, obj.quadratic_query,
                      obj.quadratic_global_value, rounds=3)
    # round 1 trajectories agree (no w yet), later rounds diverge
    np.testing.assert_allclose(np.asarray(r0.xs[1]), np.asarray(r1.xs[1]), atol=1e-6)
    assert float(jnp.abs(r0.xs[-1] - r1.xs[-1]).max()) > 1e-6


def test_federated_attack_improves_margin():
    """Sec. 6.2 (scaled down): FZooS pushes the averaged margin down."""
    key = jax.random.PRNGKey(1)
    cobjs, _ = mobj.make_attack_objective(key, n_clients=4, p_shared=0.6,
                                          side=8, train_per_client=128)
    d = int(cobjs.z.shape[-1])
    cfg = alg.AlgoConfig(
        name="fzoos", dim=d, n_clients=4, local_steps=5, eta=0.02,
        n_features=128, traj_capacity=96, active_per_iter=3,
        active_candidates=30, active_round_end=3, lengthscale=0.5, noise=1e-5,
    )
    res = alg.simulate(cfg, jax.random.PRNGKey(2), cobjs, mobj.attack_query,
                       mobj.attack_global_value, rounds=8)
    assert float(jnp.min(res.f_values)) < float(res.f_values[0]) - 1e-3


def test_lm_substrate_loss_decreases():
    """The training driver's contract: loss drops on the synthetic pipeline."""
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticTextConfig, synthetic_batch
    from repro.models.model import init_train_state, train_step
    from repro.sharding.rules import ShardingPolicy

    cfg = get_config("qwen1_5_0_5b", "smoke")
    policy = ShardingPolicy(remat=False)
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    dcfg = SyntheticTextConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8, seed=0)
    step = jax.jit(lambda p, o, b: train_step(p, o, cfg, b, policy, 3e-3))
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, synthetic_batch(dcfg, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15, losses[::6]


def test_generation_loop_runs():
    from repro.configs import get_config
    from repro.launch.serve import generate
    from repro.models.model import init_train_state
    from repro.sharding.rules import ShardingPolicy

    cfg = get_config("qwen1_5_0_5b", "smoke")
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
    out, cache = generate(cfg, params, batch, ShardingPolicy(remat=False),
                          gen_len=5, cache_len=20, temperature=0.0,
                          key=jax.random.PRNGKey(2))
    assert out.shape == (2, 5)
    assert int(cache.pos) == 12 + 5
    assert int(out.max()) < cfg.vocab_size


def test_metric_optimization_improves_precision():
    """Sec. 6.3 (scaled down): ZOO fine-tuning reduces 1 - precision."""
    key = jax.random.PRNGKey(5)
    cobjs, d = mobj.make_metric_objective(key, n_clients=3, p_shared=0.8, n_eval=128)
    cfg = alg.AlgoConfig(
        name="fzoos", dim=d, n_clients=3, local_steps=5, eta=0.02,
        n_features=256, traj_capacity=96, active_per_iter=3,
        active_candidates=30, active_round_end=3, lengthscale=0.5, noise=1e-5,
    )
    res = alg.simulate(cfg, jax.random.PRNGKey(6), cobjs, mobj.metric_query,
                       mobj.metric_global_value, rounds=8)
    assert float(jnp.min(res.f_values)) <= float(res.f_values[0]) + 1e-6
