"""Per-shard round-state checkpoint tests (checkpoint/io.py sharded layout)
plus the restore dtype-validation regression.

The sharded contract under test: saving with a mesh writes one
``shard_<p>/arrays.npz`` per process from process-local addressable data
(no full ClientState gather), the ``meta.json`` manifest pins
{n_shards, mesh} so mismatched topologies fail loudly, restore places each
block straight onto this process's devices, and the round-trip is BITWISE
against both the original state and the legacy gathered layout.
"""

import json
import os
import shutil
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core import algorithms as alg
from repro.core import rounds as rounds_mod
from repro.core.federated import shard_clients

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def _fzoos_cfg(**kw):
    base = dict(name="fzoos", dim=8, n_clients=4, local_steps=2,
                n_features=32, traj_capacity=32, active_per_iter=1,
                active_candidates=8, active_round_end=1, lengthscale=0.5)
    base.update(kw)
    return alg.AlgoConfig(**base)


def _state_and_hist(mesh=None):
    cfg = _fzoos_cfg()
    x0 = jnp.full((8,), 0.5, jnp.float32)
    states = alg.init_states(cfg, jax.random.PRNGKey(1), x0)
    # make the state non-trivial: distinct flags, counters, keys per client
    states = states._replace(
        factor=states.factor._replace(
            needs_repair=jnp.asarray([True, False, False, True]),
            n_updates=jnp.arange(4, dtype=jnp.int32),
        ),
        queries=jnp.asarray([3, 1, 4, 1], jnp.int32),
    )
    if mesh is not None:
        states = shard_clients(mesh, states)
    hist = rounds_mod.history_init(6, x0, jnp.asarray(0.25, jnp.float32))
    return states, hist


def _assert_trees_equal(got, want):
    for g, w in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)):
        assert str(jnp.asarray(g).dtype) == str(jnp.asarray(w).dtype)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# restore() dtype validation (regression: docstring promised it, code didn't)
# ---------------------------------------------------------------------------


def test_restore_validates_dtype(tmp_path):
    """A leaf saved as bf16 must NOT silently restore into an f32 template
    (and vice versa) -- the docstring always promised dtype validation."""
    root = str(tmp_path / "dt")
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16), "b": jnp.zeros((2,), jnp.float32)}
    ckpt_io.save(root, tree, step=0)
    # matching template round-trips (bf16 through the uint16 view)
    got = ckpt_io.restore(root, tree, step=0)
    _assert_trees_equal(got, tree)
    # f32 template for the bf16 leaf: loud error, not a silent cast
    bad = {"a": jnp.zeros((6,), jnp.float32), "b": tree["b"]}
    with pytest.raises(ValueError, match="dtype mismatch"):
        ckpt_io.restore(root, bad, step=0)
    # and the transpose direction: bf16 template for an f32 leaf
    bad2 = {"a": tree["a"], "b": jnp.zeros((2,), jnp.bfloat16)}
    with pytest.raises(ValueError, match="dtype mismatch"):
        ckpt_io.restore(root, bad2, step=0)


# ---------------------------------------------------------------------------
# Sharded layout: round-trip, manifest validation, tmp recovery
# ---------------------------------------------------------------------------


def test_sharded_roundtrip_bitwise_vs_gathered(tmp_path):
    """mesh save/restore == the original state == the legacy gathered
    layout, leaf for leaf, bit for bit (incl. the bool repair flags and
    int32 counters)."""
    mesh = jax.make_mesh((1,), ("data",))
    states, hist = _state_and_hist(mesh)

    shard_root = str(tmp_path / "sharded")
    legacy_root = str(tmp_path / "legacy")
    ckpt_io.save_round_state(shard_root, 4, states, hist, mesh=mesh,
                             extra_meta={"rounds": 6})
    ckpt_io.save_round_state(legacy_root, 4, states, hist)

    step_dir = os.path.join(shard_root, "step_00000004")
    assert os.path.isfile(os.path.join(step_dir, "meta.json"))
    assert os.path.isfile(os.path.join(step_dir, "shard_00000", "arrays.npz"))
    # the manifest is the step's meta.json: load_meta (resume identity) works
    meta = ckpt_io.load_meta(shard_root, 4)
    assert meta["layout"] == "sharded-v1"
    assert meta["n_shards"] == jax.process_count()
    assert meta["extra"] == {"rounds": 6}

    s_like, h_like = _state_and_hist(mesh)
    got_s, got_h, step = ckpt_io.restore_round_state(
        shard_root, s_like, h_like, mesh=mesh)
    assert step == 4
    _assert_trees_equal(got_s, states)
    _assert_trees_equal(got_h, hist)
    # restored leaves are already placed client-sharded on the mesh
    assert all(
        d in got_s.x.sharding.device_set for d in mesh.devices.flat
    )

    leg_s, leg_h, _ = ckpt_io.restore_round_state(legacy_root, s_like, h_like)
    _assert_trees_equal(got_s, leg_s)
    _assert_trees_equal(got_h, leg_h)


def test_sharded_manifest_rejects_mismatched_topology(tmp_path):
    """{n_shards, mesh} in the manifest are validated loudly; a sharded
    checkpoint also refuses to restore without a mesh at all."""
    mesh = jax.make_mesh((1,), ("data",))
    states, hist = _state_and_hist(mesh)
    root = str(tmp_path / "m")
    ckpt_io.save_round_state(root, 2, states, hist, mesh=mesh)
    meta_path = os.path.join(root, "step_00000002", "meta.json")

    with pytest.raises(ValueError, match="requires the device mesh"):
        ckpt_io.restore_round_state(root, states, hist)

    with open(meta_path) as f:
        meta = json.load(f)
    meta["n_shards"] = 16
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="16 process"):
        ckpt_io.restore_round_state(root, states, hist, mesh=mesh)

    meta["n_shards"] = jax.process_count()
    meta["mesh"] = {"axis_names": ["data", "model"], "shape": [8, 2]}
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="mesh"):
        ckpt_io.restore_round_state(root, states, hist, mesh=mesh)


def test_sharded_dtype_and_shape_validated(tmp_path):
    """The per-leaf shape/dtype contract holds on the sharded path too."""
    mesh = jax.make_mesh((1,), ("data",))
    states, hist = _state_and_hist(mesh)
    root = str(tmp_path / "v")
    ckpt_io.save_round_state(root, 2, states, hist, mesh=mesh)
    bad_states = states._replace(x=states.x.astype(jnp.bfloat16))
    with pytest.raises(ValueError, match="dtype mismatch"):
        ckpt_io.restore_round_state(root, bad_states, hist, mesh=mesh)
    bad_hist = hist._replace(xs=jnp.zeros((99, 8), jnp.float32))
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt_io.restore_round_state(root, states, bad_hist, mesh=mesh)


def test_sharded_tmp_recovery(tmp_path):
    """A preemption mid-sharded-write leaves only ``step_*.tmp``; resume
    must fall back to the last COMPLETE checkpoint."""
    mesh = jax.make_mesh((1,), ("data",))
    states, hist = _state_and_hist(mesh)
    root = str(tmp_path / "t")
    ckpt_io.save_round_state(root, 4, states, hist, mesh=mesh)
    # fake a crash mid-write of step 8: shard written, manifest missing
    tmp = os.path.join(root, "step_00000008.tmp")
    os.makedirs(os.path.join(tmp, "shard_00000"))
    with open(os.path.join(tmp, "shard_00000", "arrays.npz"), "wb") as f:
        f.write(b"truncated")
    assert ckpt_io.latest_step(root) == 4
    got_s, _, step = ckpt_io.restore_round_state(root, states, hist, mesh=mesh)
    assert step == 4
    _assert_trees_equal(got_s, states)
    # the next save of step 8 clears the stale tmp and completes
    ckpt_io.save_round_state(root, 8, states, hist, mesh=mesh)
    assert ckpt_io.latest_step(root) == 8


def test_async_writer_reraises_background_error(tmp_path):
    """A failing background write must fail the run on the next submit/wait,
    not vanish inside a daemon thread."""
    w = ckpt_io.AsyncCheckpointWriter()
    hits = []
    w.submit(lambda: hits.append(1))
    w.wait()
    assert hits == [1]

    def boom():
        raise OSError("disk full")

    w.submit(boom)
    with pytest.raises(OSError, match="disk full"):
        w.submit(lambda: hits.append(2))
    # the queue is usable again after the error surfaced
    w.submit(lambda: hits.append(3))
    w.wait()
    assert hits == [1, 3]


def test_async_writer_never_issues_collective_off_main_thread(monkeypatch):
    """REGRESSION: the sharded layout's _sync barrier is a collective; on a
    multi-process mesh it must never run on the async writer thread (it
    would race the main thread's round collectives and deadlock the pod).
    _sync itself enforces this with a RuntimeError, which the writer
    re-raises on wait()."""
    monkeypatch.setattr(ckpt_io.jax, "process_count", lambda: 2)
    # On the main thread the guard passes (and would proceed to the barrier,
    # which we stub out -- single-process CI has no multihost runtime).
    import repro.checkpoint.io as io_mod

    w = ckpt_io.AsyncCheckpointWriter()
    w.submit(lambda: io_mod._sync("round-1"))
    with pytest.raises(RuntimeError, match="off the main thread"):
        w.wait()


def test_run_rounds_forces_blocking_writes_multiprocess(monkeypatch, capsys):
    """async_checkpoint=True on a >1-process mesh must be downgraded to the
    blocking path with a loud log, not silently honored."""
    monkeypatch.setattr(rounds_mod.jax, "process_count", lambda: 2)
    seen = {}

    class NoWriter:
        def __init__(self):
            raise AssertionError("async writer must not be constructed on a pod")

    monkeypatch.setattr(rounds_mod.ckpt_io, "AsyncCheckpointWriter", NoWriter)

    # Drive just the writer-selection logic by running one chunk through
    # alg.simulate (scan driver): checkpoint_dir set, async requested, and a
    # faked 2-process count.  Checkpoints still land (blocking path).
    from repro.core import objectives as obj

    cfg = _fzoos_cfg()
    cobjs = obj.make_quadratic(jax.random.PRNGKey(0), 4, 8, 1.0, 0.0)
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        res = alg.simulate(
            cfg, jax.random.PRNGKey(2), cobjs, obj.quadratic_query,
            obj.quadratic_global_value, rounds=1, chunk=1,
            checkpoint_dir=td, checkpoint_every=1, async_checkpoint=True,
        )
        assert res is not None
        seen["files"] = sorted(os.listdir(td))
    out = capsys.readouterr().out
    assert "FORCING blocking" in out
    assert any(f.startswith("step_") for f in seen["files"])


def test_run_rounds_sharded_resume_bitwise(tmp_path):
    """End-to-end through run_rounds on a mesh: per-shard checkpoints +
    preemption + resume == the uninterrupted run, exactly (same contract as
    the legacy layout's test in test_rounds.py)."""
    from repro.core import objectives as obj
    from repro.core.federated import run_distributed

    mesh = jax.make_mesh((1,), ("data",))
    quad = obj.make_quadratic(jax.random.PRNGKey(0), 4, 8, 2.0, 0.001)
    cfg = _fzoos_cfg()
    k = jax.random.PRNGKey(5)
    args = (cfg, mesh, k, quad, obj.quadratic_query, obj.quadratic_global_value, 9)
    ckpt = str(tmp_path / "dist_ckpt")

    r_full = run_distributed(*args, chunk=3)
    run_distributed(*args, chunk=3, checkpoint_dir=ckpt)
    assert ckpt_io.latest_step(ckpt) == 9
    assert os.path.isdir(os.path.join(ckpt, "step_00000009", "shard_00000"))
    for d in os.listdir(ckpt):
        if int(d.split("_")[1]) > 6:
            shutil.rmtree(os.path.join(ckpt, d))
    r_res = run_distributed(*args, chunk=3, checkpoint_dir=ckpt)
    np.testing.assert_array_equal(np.asarray(r_full.xs), np.asarray(r_res.xs))
    np.testing.assert_array_equal(np.asarray(r_full.f_values),
                                  np.asarray(r_res.f_values))
    np.testing.assert_array_equal(np.asarray(r_full.queries),
                                  np.asarray(r_res.queries))


MULTIDEV_SHARD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"  # skip the (slow) accelerator probe
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.checkpoint import io as ckpt_io
    from repro.core.federated import shard_clients

    # A synthetic client-stacked pytree: the io layer only sees leaves with a
    # leading client axis, so a real ClientState (whose init compiles for
    # minutes on 4 host devices) adds nothing here.
    mesh = jax.make_mesh((4,), ("data",))
    states = shard_clients(mesh, {
        "x": jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6),
        "flags": jnp.asarray([0, 1, 0, 0, 1, 0, 1, 0], bool),
        "count": jnp.arange(8, dtype=jnp.int32),
        "wide": jnp.ones((8, 3, 4), jnp.bfloat16) * 1.5,
    })
    hist = {"f": jnp.linspace(0.0, 1.0, 5), "q": jnp.arange(4, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as td:
        ckpt_io.save_round_state(td, 2, states, hist, mesh=mesh)
        got_s, got_h, step = ckpt_io.restore_round_state(td, states, hist, mesh=mesh)
    assert step == 2
    for g, w in zip(jax.tree_util.tree_leaves(got_s), jax.tree_util.tree_leaves(states)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        assert str(g.dtype) == str(w.dtype)
    for g, w in zip(jax.tree_util.tree_leaves(got_h), jax.tree_util.tree_leaves(hist)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # restored leaves are placed with the client axis sharded over 4 devices
    assert len(got_s["x"].sharding.device_set) == 4
    print("SHARD_MULTIDEV_OK")
    """
)


@pytest.mark.slow
def test_sharded_roundtrip_four_devices_subprocess():
    """The block-extraction and direct-placement paths with REAL multi-device
    sharding (4 host devices, 2 clients per device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SHARD_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARD_MULTIDEV_OK" in out.stdout
