import os

# Keep CPU smoke tests single-device: the dry-run (and only the dry-run)
# forces 512 host devices in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# hypothesis shim
#
# The container image has no `hypothesis` wheel and installs are not allowed,
# which made every property-test module fail at COLLECTION.  The tests only
# use a tiny slice of the API (given / settings / st.integers / st.floats),
# so when the real package is absent we install a deterministic stand-in that
# runs each property test over `max_examples` seeded pseudo-random samples.
# With the real package installed this block is inert.
# ---------------------------------------------------------------------------
try:  # pragma: no cover - exercised only when hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - the container path

    import random
    import sys
    import types
    import zlib

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    def _given(**strategies):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_stub_max_examples", 10)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    try:
                        fn(**drawn)
                    except _hyp.UnsatisfiedAssumption:
                        continue

            # Do NOT functools.wraps: pytest would follow __wrapped__ and
            # treat the property arguments as missing fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__qualname__ = fn.__qualname__
            wrapper._stub_max_examples = getattr(fn, "_stub_max_examples", 10)
            if hasattr(fn, "pytestmark"):
                wrapper.pytestmark = fn.pytestmark
            return wrapper

        return deco

    def _settings(max_examples=10, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def _assume(condition):
        if not condition:
            raise _hyp.UnsatisfiedAssumption()

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = _assume
    _hyp.strategies = _st
    _hyp.UnsatisfiedAssumption = type("UnsatisfiedAssumption", (Exception,), {})
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    _hyp.__is_repro_stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
