"""Scan-engine tests (core/rounds.py).

Equivalence vs the Python-loop oracle is ALGORITHMIC, not bitwise, for the
same reason as the vmap/shard_map contract in test_federated.py: the scanned
round body lowers differently from the per-round jit, and the near-singular
GP solves amplify single-ULP reassociation by the system conditioning.  The
FD baseline has no ill-conditioned solve, so it is held to a tight bound.
Checkpoint/resume, by contrast, replays the SAME executables on bitwise
restored state, so the round-trip is exact.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import objectives as obj
from repro.core import rounds as rounds_mod
from repro.core.federated import run_distributed
from repro.checkpoint import latest_step

ROUNDS = 20


@pytest.fixture(scope="module")
def quad():
    key = jax.random.PRNGKey(0)
    return obj.make_quadratic(key, 4, 8, 2.0, 0.001)


def _fzoos_cfg(**kw):
    base = dict(name="fzoos", dim=8, n_clients=4, local_steps=3,
                n_features=32, traj_capacity=32, active_per_iter=1,
                active_candidates=8, active_round_end=1, lengthscale=0.5)
    base.update(kw)
    return alg.AlgoConfig(**base)


@pytest.fixture(scope="module")
def fzoos_oracle(quad):
    cfg = _fzoos_cfg()
    return alg.simulate(cfg, jax.random.PRNGKey(5), quad, obj.quadratic_query,
                        obj.quadratic_global_value, ROUNDS, chunk=0)


def _assert_bounded(r_ref, r_new):
    np.testing.assert_allclose(np.asarray(r_ref.xs[1]), np.asarray(r_new.xs[1]),
                               atol=5e-2)
    np.testing.assert_allclose(np.asarray(r_ref.xs), np.asarray(r_new.xs), atol=0.1)
    np.testing.assert_allclose(np.asarray(r_ref.f_values),
                               np.asarray(r_new.f_values), atol=5e-2)
    # query accounting is integer-deterministic: must agree exactly
    np.testing.assert_array_equal(np.asarray(r_ref.queries),
                                  np.asarray(r_new.queries))
    assert np.isfinite(np.asarray(r_new.f_values)).all()


def test_scan_matches_loop_fzoos_sim(quad, fzoos_oracle):
    """Chunked scan vs per-round loop, chunk not dividing rounds (8 | 20)."""
    cfg = _fzoos_cfg()
    r_new = alg.simulate(cfg, jax.random.PRNGKey(5), quad, obj.quadratic_query,
                         obj.quadratic_global_value, ROUNDS, chunk=8)
    _assert_bounded(fzoos_oracle, r_new)
    assert r_new.refactor_rate.shape == (ROUNDS,)


def test_scan_matches_loop_fzoos_distributed(quad, fzoos_oracle):
    """The shard_map engine scanning INSIDE shard_map vs the loop oracle."""
    mesh = jax.make_mesh((1,), ("data",))
    r_new = run_distributed(_fzoos_cfg(), mesh, jax.random.PRNGKey(5), quad,
                            obj.quadratic_query, obj.quadratic_global_value,
                            ROUNDS, chunk=8)
    _assert_bounded(fzoos_oracle, r_new)


def test_scan_matches_loop_fedzo(quad):
    """FD baseline: no ill-conditioned solve, so the bound is tight."""
    cfg = alg.AlgoConfig(name="fedzo", dim=8, n_clients=4, local_steps=3, q=8)
    k = jax.random.PRNGKey(5)
    r_old = alg.simulate(cfg, k, quad, obj.quadratic_query,
                         obj.quadratic_global_value, ROUNDS, chunk=0)
    r_new = alg.simulate(cfg, k, quad, obj.quadratic_query,
                         obj.quadratic_global_value, ROUNDS, chunk=7)
    np.testing.assert_allclose(np.asarray(r_old.xs), np.asarray(r_new.xs),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_old.f_values),
                               np.asarray(r_new.f_values), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(r_old.queries),
                                  np.asarray(r_new.queries))


def test_checkpoint_resume_roundtrip(quad, tmp_path):
    """Chunk-boundary checkpoint -> preempt -> resume == uninterrupted run,
    EXACTLY (resume replays the same executables on bitwise-restored state)."""
    import shutil

    cfg = _fzoos_cfg(local_steps=2)
    k = jax.random.PRNGKey(5)
    args = (cfg, k, quad, obj.quadratic_query, obj.quadratic_global_value, 12)
    ckpt = str(tmp_path / "rounds_ckpt")

    r_full = alg.simulate(*args, chunk=4)
    alg.simulate(*args, chunk=4, checkpoint_dir=ckpt)
    assert latest_step(ckpt) == 12
    # fake preemption after round 8: drop the later checkpoints
    for d in os.listdir(ckpt):
        if int(d.split("_")[1]) > 8:
            shutil.rmtree(os.path.join(ckpt, d))
    assert latest_step(ckpt) == 8
    r_res = alg.simulate(*args, chunk=4, checkpoint_dir=ckpt)
    np.testing.assert_array_equal(np.asarray(r_full.xs), np.asarray(r_res.xs))
    np.testing.assert_array_equal(np.asarray(r_full.f_values),
                                  np.asarray(r_res.f_values))
    np.testing.assert_array_equal(np.asarray(r_full.queries),
                                  np.asarray(r_res.queries))


def test_run_rounds_rejects_bad_chunk(quad):
    cfg = _fzoos_cfg()
    states = alg.init_states(cfg, jax.random.PRNGKey(1), jnp.full((8,), 0.5))
    with pytest.raises(ValueError, match="chunk"):
        rounds_mod.run_rounds(cfg, None, obj.quadratic_query, quad, states,
                              jnp.full((8,), 0.5), obj.quadratic_global_value,
                              rounds=4, chunk=0)
    # negative chunk must not silently fall through to the loop oracle
    with pytest.raises(ValueError, match="chunk"):
        alg.simulate(cfg, jax.random.PRNGKey(1), quad, obj.quadratic_query,
                     obj.quadratic_global_value, 2, chunk=-8)


def test_resume_rejects_mismatched_rounds(quad, tmp_path):
    """A checkpoint dir from a run with different `rounds` must fail loudly,
    not resume the wrong run or die with an opaque shape error."""
    cfg = alg.AlgoConfig(name="fedzo", dim=8, n_clients=4, local_steps=1, q=2)
    k = jax.random.PRNGKey(5)
    ckpt = str(tmp_path / "mismatch_ckpt")
    alg.simulate(cfg, k, quad, obj.quadratic_query, obj.quadratic_global_value,
                 4, chunk=2, checkpoint_dir=ckpt)
    with pytest.raises(ValueError, match="rounds=4"):
        alg.simulate(cfg, k, quad, obj.quadratic_query,
                     obj.quadratic_global_value, 6, chunk=2, checkpoint_dir=ckpt)


def test_resume_rejects_mismatched_eval_every(quad, tmp_path):
    """Regression: `eval_every` is part of the resume identity.  Resuming
    with a different value used to splice two NaN patterns into one
    f_values history; now it fails loudly like rounds/cfg.  `chunk` stays
    excluded by design (dispatch granularity only), so a resume with a
    different chunk length succeeds."""
    cfg = alg.AlgoConfig(name="fedzo", dim=8, n_clients=4, local_steps=1, q=2)
    k = jax.random.PRNGKey(5)
    args = (cfg, k, quad, obj.quadratic_query, obj.quadratic_global_value, 6)
    ckpt = str(tmp_path / "ee_ckpt")
    alg.simulate(*args, chunk=2, eval_every=2, checkpoint_dir=ckpt)
    with pytest.raises(ValueError, match="eval_every=2"):
        alg.simulate(*args, chunk=2, eval_every=3, checkpoint_dir=ckpt)
    # different chunk is a legitimate resume (validated fields only)
    res = alg.simulate(*args, chunk=3, eval_every=2, checkpoint_dir=ckpt)
    assert np.isfinite(np.asarray(res.f_values)[[0, 2, 4, 6]]).all()


def test_checkpoint_sync_mode_roundtrip(quad, tmp_path):
    """`async_checkpoint=False` (the legacy blocking write) produces the
    same checkpoints and the same resume behavior as the async writer."""
    cfg = alg.AlgoConfig(name="fedzo", dim=8, n_clients=4, local_steps=1, q=2)
    k = jax.random.PRNGKey(5)
    args = (cfg, k, quad, obj.quadratic_query, obj.quadratic_global_value, 4)
    a_dir, s_dir = str(tmp_path / "a"), str(tmp_path / "s")
    r_a = alg.simulate(*args, chunk=2, checkpoint_dir=a_dir)
    r_s = alg.simulate(*args, chunk=2, checkpoint_dir=s_dir,
                       async_checkpoint=False)
    assert latest_step(a_dir) == latest_step(s_dir) == 4
    np.testing.assert_array_equal(np.asarray(r_a.xs), np.asarray(r_s.xs))
    from repro.checkpoint import io as ckpt_io
    ta = ckpt_io.load_meta(a_dir, 4)
    ts = ckpt_io.load_meta(s_dir, 4)
    assert ta["extra"] == ts["extra"]
    assert ta["dtypes"] == ts["dtypes"]


def test_eval_every_nan_contract(quad):
    """eval_every=k: F evaluated at rounds k, 2k, ... plus ALWAYS the final
    round; skipped rows hold NaN; everything else (xs, queries) unaffected."""
    cfg = alg.AlgoConfig(name="fedzo", dim=8, n_clients=4, local_steps=1, q=2)
    k = jax.random.PRNGKey(3)
    args = (cfg, k, quad, obj.quadratic_query, obj.quadratic_global_value, 7)
    r_all = alg.simulate(*args, chunk=3)
    r_skip = alg.simulate(*args, chunk=3, eval_every=3)

    f = np.asarray(r_skip.f_values)
    evaluated = {0, 3, 6, 7}  # round 0, multiples of 3, and the final round
    for r in range(8):
        if r in evaluated:
            assert np.isfinite(f[r]), r
            np.testing.assert_allclose(f[r], np.asarray(r_all.f_values)[r],
                                       atol=1e-6)
        else:
            assert np.isnan(f[r]), r
    # the trajectory itself must be untouched by skipping evals
    np.testing.assert_array_equal(np.asarray(r_all.xs), np.asarray(r_skip.xs))
    np.testing.assert_array_equal(np.asarray(r_all.queries),
                                  np.asarray(r_skip.queries))


def test_eval_every_matches_loop_oracle(quad):
    """Scan-engine eval_every == the Python-loop oracle's NaN pattern."""
    cfg = alg.AlgoConfig(name="fedzo", dim=8, n_clients=4, local_steps=1, q=2)
    k = jax.random.PRNGKey(3)
    args = (cfg, k, quad, obj.quadratic_query, obj.quadratic_global_value, 5)
    r_loop = alg.simulate(*args, chunk=0, eval_every=2)
    r_scan = alg.simulate(*args, chunk=2, eval_every=2)
    np.testing.assert_array_equal(np.isnan(np.asarray(r_loop.f_values)),
                                  np.isnan(np.asarray(r_scan.f_values)))
    np.testing.assert_allclose(np.asarray(r_loop.f_values),
                               np.asarray(r_scan.f_values), atol=1e-5)


def test_eval_every_distributed(quad):
    """eval_every through shard_map: the pmean inside the eval cond must
    lower and the NaN pattern must match the sim engine."""
    mesh = jax.make_mesh((1,), ("data",))
    cfg = _fzoos_cfg(local_steps=2)
    k = jax.random.PRNGKey(5)
    r = run_distributed(cfg, mesh, k, quad, obj.quadratic_query,
                        obj.quadratic_global_value, 5, chunk=2, eval_every=2)
    f = np.asarray(r.f_values)
    assert np.isnan(f[[1, 3]]).all()
    assert np.isfinite(f[[0, 2, 4, 5]]).all()


def test_eval_every_rejected_when_invalid(quad):
    cfg = _fzoos_cfg()
    with pytest.raises(ValueError, match="eval_every"):
        alg.simulate(cfg, jax.random.PRNGKey(1), quad, obj.quadratic_query,
                     obj.quadratic_global_value, 2, eval_every=0)


def test_history_shapes_and_initial_row(quad):
    """xs[0]/f_values[0] hold the initial point; per-round rows line up."""
    cfg = alg.AlgoConfig(name="fedzo", dim=8, n_clients=4, local_steps=2, q=4)
    x0 = jnp.full((8,), 0.5)
    res = alg.simulate(cfg, jax.random.PRNGKey(3), quad, obj.quadratic_query,
                       obj.quadratic_global_value, 5, x0=x0, chunk=2)
    assert res.xs.shape == (6, 8) and res.f_values.shape == (6,)
    assert res.queries.shape == (5,) and res.refactor_rate.shape == (5,)
    np.testing.assert_array_equal(np.asarray(res.xs[0]), np.asarray(x0))
    f0 = float(obj.quadratic_global_value(quad, x0))
    assert float(res.f_values[0]) == pytest.approx(f0, abs=1e-6)
    # cumulative query counter is strictly increasing by the static rate
    per_round = cfg.queries_per_round()
    np.testing.assert_array_equal(
        np.asarray(res.queries), per_round * np.arange(1, 6, dtype=np.float32))


def test_engine_contracts_clean():
    """The scan engine's structural invariants -- eigh-free deferred body,
    the declared psum census, chunk-step donation -- are DECLARED in
    ``repro.analysis.contracts`` and linted there; the tier-1 suite routes
    the engine-level ones through that registry instead of keeping ad-hoc
    jaxpr/HLO assertions here."""
    import io

    from repro.analysis import check_all

    results = check_all(
        [
            "fzoos-deferred/simulate",
            "fzoos-deferred/distributed",
            "chunk-step-donation/simulate",
            "chunk-step-donation/distributed",
        ],
        out=io.StringIO(),
    )
    bad = {k: v for k, v in results.items() if v}
    assert not bad, bad
