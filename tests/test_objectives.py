"""Objective tests: the paper's synthetic quadratics (Appx. E.1) and the
model-backed attack / metric / LM objectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import model_objectives as mobj
from repro.core import objectives as obj


@settings(max_examples=10, deadline=None)
@given(c=st.floats(0.0, 50.0), seed=st.integers(0, 1000))
def test_global_quadratic_independent_of_heterogeneity(c, seed):
    """F(x) = mean_i f_i(x) must NOT depend on C (Dirichlet weights sum to 1)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(jax.random.fold_in(key, 1), (12,))
    f_c = obj.quadratic_global_value(obj.make_quadratic(key, 5, 12, c), x)
    f_0 = obj.quadratic_global_value(obj.make_quadratic(key, 5, 12, 0.0), x)
    assert float(jnp.abs(f_c - f_0)) < 1e-4


def test_quadratic_optimum():
    key = jax.random.PRNGKey(0)
    d = 16
    cobjs = obj.make_quadratic(key, 4, d, 5.0)
    xstar = obj.quadratic_optimum_unit(d)
    fstar = obj.quadratic_fstar(d)
    assert float(obj.quadratic_global_value(cobjs, xstar)) == pytest.approx(fstar, abs=1e-5)
    g = obj.quadratic_global_grad(cobjs, xstar)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-4)
    # any other point is worse
    other = jnp.clip(xstar + 0.1, 0, 1)
    assert float(obj.quadratic_global_value(cobjs, other)) > fstar


def test_heterogeneity_grows_with_c():
    key = jax.random.PRNGKey(1)
    d = 10
    probes = jax.random.uniform(jax.random.fold_in(key, 2), (8, d))
    gs = [
        float(obj.heterogeneity_g(obj.quadratic_grad, obj.make_quadratic(key, 5, d, c), probes))
        for c in (0.5, 5.0, 50.0)
    ]
    assert gs[0] < gs[1] < gs[2]


def test_quadratic_grad_matches_autodiff():
    key = jax.random.PRNGKey(2)
    cobjs = obj.make_quadratic(key, 3, 8, 5.0)
    cp = jax.tree_util.tree_map(lambda a: a[1], cobjs)
    x = jax.random.uniform(jax.random.fold_in(key, 1), (8,))
    g1 = obj.quadratic_grad(cp, x)
    g2 = jax.grad(lambda x: obj.quadratic_value(cp, x))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-6)


def test_attack_objective_end_to_end():
    key = jax.random.PRNGKey(3)
    cobjs, img = mobj.make_attack_objective(key, n_clients=4, p_shared=0.6,
                                            side=8, train_per_client=128)
    d = img.shape[-1]
    x0 = jnp.full((d,), 0.5)  # zero perturbation
    # unperturbed: the target is correctly classified by construction
    margin0 = float(mobj.attack_global_value(cobjs, x0))
    assert margin0 > 0
    assert float(mobj.attack_success(cobjs, x0)) == 0.0
    # the margin is queryable and noisy
    cp = jax.tree_util.tree_map(lambda a: a[0], cobjs)
    y1 = mobj.attack_query(cp, x0, jax.random.PRNGKey(0))
    y2 = mobj.attack_query(cp, x0, jax.random.PRNGKey(1))
    assert float(jnp.abs(y1 - y2)) > 0
    # a large adversarial-ish perturbation changes the margin
    xr = jax.random.uniform(jax.random.fold_in(key, 9), (d,))
    assert float(mobj.attack_global_value(cobjs, xr)) != pytest.approx(margin0, abs=1e-6)


def test_metric_objective_end_to_end():
    key = jax.random.PRNGKey(4)
    cobjs, d = mobj.make_metric_objective(key, n_clients=3, p_shared=0.8, n_eval=128)
    x0 = jnp.full((d,), 0.5)  # zero perturbation -> theta*
    v0 = float(mobj.metric_global_value(cobjs, x0))
    assert 0.0 <= v0 <= 1.0
    # theta* is trained: its precision should beat a heavy random perturbation
    xr = jnp.zeros((d,))  # extreme corner = large perturbation
    vr = float(mobj.metric_global_value(cobjs, xr))
    assert v0 < vr + 0.05


def test_lm_objective_runs_on_zoo_archs():
    from repro.configs import get_config
    from repro.models.model import init_train_state

    for arch in ("qwen1_5_0_5b", "mamba2_370m"):
        cfg = get_config(arch, "smoke")
        key = jax.random.PRNGKey(0)
        params, _ = init_train_state(key, cfg)
        cobjs = mobj.make_lm_objective(key, cfg, n_clients=3, batch=1, seq=16)
        query, global_value, d, value = mobj.make_lm_query(cfg, params)
        assert d == cfg.d_model
        x0 = jnp.full((d,), 0.5)
        v = float(global_value(cobjs, x0))
        assert np.isfinite(v) and v > 0
        cp = jax.tree_util.tree_map(lambda a: a[0], cobjs)
        y = float(query(cp, x0, jax.random.PRNGKey(1)))
        assert np.isfinite(y)
        # perturbing the norm gains changes the loss
        x1 = jnp.clip(x0 + 0.4, 0, 1)
        assert float(global_value(cobjs, x1)) != pytest.approx(v, abs=1e-7)
