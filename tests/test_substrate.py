"""Substrate tests: optimizers, schedules, data pipeline, partitioners,
checkpointing, sharding rules."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, restore, save
from repro.data.partition import dirichlet_partition, label_subset_partition
from repro.data.pipeline import SyntheticTextConfig, synthetic_batch
from repro.optim import (
    adam_init,
    adam_update,
    adamw_init,
    adamw_update,
    warmup_cosine_schedule,
)


def test_adam_converges_on_quadratic():
    p = {"x": jnp.array([3.0, -2.0])}
    opt = adam_init(p)
    for _ in range(300):
        g = {"x": 2 * p["x"]}
        p, opt = adam_update(opt, g, p, 0.05)
    assert float(jnp.abs(p["x"]).max()) < 1e-2


def test_adamw_decays_unused_weights():
    p = {"x": jnp.array([1.0])}
    opt = adamw_init(p)
    for _ in range(50):
        p, opt = adamw_update(opt, {"x": jnp.array([0.0])}, p, 1e-2, weight_decay=0.5)
    assert float(p["x"][0]) < 1.0


def test_optimizer_updates_preserve_param_dtype():
    """Regression: ``p - lr * (...)`` with an f32 lr promoted bf16 params to
    f32 on the first step, so trained LM params drifted precision and
    checkpoints failed the restored-vs-init dtype validation."""
    from repro.optim import sgd_init, sgd_update

    p = {"w": jnp.ones((2, 3), jnp.bfloat16), "b": jnp.zeros((3,), jnp.float32)}
    g = {"w": jnp.full((2, 3), 0.1, jnp.bfloat16), "b": jnp.full((3,), 0.1)}
    lr = jnp.asarray(0.05, jnp.float32)  # large enough to move a bf16 ULP
    for init, update in ((adam_init, adam_update), (adamw_init, adamw_update),
                         (sgd_init, sgd_update)):
        opt = init(p)
        new_p, _ = update(opt, g, p, lr)
        assert new_p["w"].dtype == jnp.bfloat16, update.__name__
        assert new_p["b"].dtype == jnp.float32, update.__name__
        assert float(jnp.abs(new_p["w"].astype(jnp.float32) - 1.0).max()) > 0


def test_warmup_cosine_schedule_shape():
    s = warmup_cosine_schedule(1.0, 10, 110)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=1e-6)
    assert float(s(110)) == pytest.approx(0.0, abs=1e-2)
    assert float(s(5)) == pytest.approx(0.5, abs=1e-6)


def test_pipeline_deterministic_and_in_range():
    cfg = SyntheticTextConfig(vocab_size=97, seq_len=32, batch_size=4, seed=7)
    b1 = synthetic_batch(cfg, 3)
    b2 = synthetic_batch(cfg, 3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert int(b1["tokens"].max()) < 97 and int(b1["tokens"].min()) >= 0
    # labels are next tokens
    b_next = synthetic_batch(cfg, 4)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b_next["tokens"]))
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]), np.asarray(b1["tokens"][:, 1:]))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(40, 200),
    n_clients=st.integers(2, 8),
    p=st.floats(0.2, 1.0),
    seed=st.integers(0, 1000),
)
def test_label_subset_partition_properties(n, n_clients, p, seed):
    labels = np.random.default_rng(seed).integers(0, 10, size=n)
    parts = label_subset_partition(labels, n_clients, p, seed=seed)
    assert len(parts) == n_clients
    for idx in parts:
        assert len(idx) > 0
        assert len(np.unique(idx)) == len(idx)  # no duplicates within client
        assert idx.min() >= 0 and idx.max() < n
    if p == 1.0:
        for idx in parts:
            assert len(idx) == n  # everyone sees everything


def test_label_subset_degenerate_pad_no_duplicates():
    """Regression: the degenerate-draw pad used to sample from ALL points,
    so it could duplicate an index already in the client's set.  One point
    per class forces every client through the pad path (1 chosen point +
    min_per_client-1 padded); the pad must draw from the complement."""
    labels = np.arange(8)  # 8 classes x 1 point each
    for seed in range(16):
        parts = label_subset_partition(labels, n_clients=4, p_shared=0.1,
                                       seed=seed, min_per_client=8)
        for idx in parts:
            assert len(np.unique(idx)) == len(idx) == 8, (seed, idx)

    # the pad never over-asks when the complement is smaller than the deficit
    parts = label_subset_partition(np.arange(4), n_clients=2, p_shared=0.3,
                                   seed=0, min_per_client=10)
    for idx in parts:
        assert len(np.unique(idx)) == len(idx) == 4


@settings(max_examples=15, deadline=None)
@given(n_clients=st.integers(2, 6), alpha=st.floats(0.1, 10.0), seed=st.integers(0, 1000))
def test_dirichlet_partition_is_disjoint_and_exhaustive(n_clients, alpha, seed):
    labels = np.random.default_rng(seed).integers(0, 5, size=300)
    parts = dirichlet_partition(labels, n_clients, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == 300
    assert len(np.unique(allidx)) == 300


def test_label_subset_partition_validates_hyperparameters():
    """Regression: p_shared > 1 used to crash deep inside rng.choice with an
    opaque 'cannot take a larger sample' error, and p_shared <= 0 silently
    degenerated to 1 class per client."""
    labels = np.arange(20) % 5
    for bad_p in (0.0, -0.3, 1.5, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="p_shared"):
            label_subset_partition(labels, 4, bad_p)
    for bad_n in (0, -2, 2.5):
        with pytest.raises(ValueError, match="n_clients"):
            label_subset_partition(labels, bad_n, 0.5)


def test_dirichlet_partition_validates_hyperparameters():
    """Regression: alpha <= 0 is outside the Dirichlet domain but numpy
    'accepts' it, returning NaN proportions that silently empty clients."""
    labels = np.arange(20) % 5
    for bad_a in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="alpha"):
            dirichlet_partition(labels, 4, bad_a)
    for bad_n in (0, -2, 2.5):
        with pytest.raises(ValueError, match="n_clients"):
            dirichlet_partition(labels, bad_n, 1.0)


def test_checkpoint_roundtrip_with_bf16(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.float32), "step": jnp.asarray(3, jnp.int32)},
    }
    save(str(tmp_path), tree, step=7)
    assert latest_step(str(tmp_path)) == 7
    back = restore(str(tmp_path), tree, step=7)
    for x, y in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), {"a": jnp.zeros((2, 2))}, step=1)
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"a": jnp.zeros((3,))}, step=1)


def test_spec_with_fallback_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import spec_with_fallback, zero1_extend

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
        axis_names = ("pod", "data", "model")

    m = FakeMesh()
    assert spec_with_fallback(m, (64, 160), (None, "model")) == P(None, "model")
    assert spec_with_fallback(m, (64, 100), (None, "model")) == P(None, None)  # 100 % 16 != 0
    assert spec_with_fallback(m, (32,), (("pod", "data"),)) == P(("pod", "data"))
    assert spec_with_fallback(m, (33,), (("pod", "data"),)) == P(None)

    # zero1 extends the largest replicated divisible dim with 'data'
    got = zero1_extend(m, (48, 6400, 160), P(None, None, "model"))
    assert got == P(None, "data", "model")
    # nothing divisible -> unchanged
    got = zero1_extend(m, (3, 5), P(None, None))
    assert got == P(None, None)


def test_spec_with_fallback_absent_axes_degrade_to_replicated():
    """Logical axes naming mesh-absent axes (e.g. 'model' on the data-only
    host mesh) replicate instead of KeyError / emitting invalid specs."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import spec_with_fallback

    class HostMesh:  # what make_host_mesh() builds on CPU
        shape = {"data": 8}
        axis_names = ("data",)

    m = HostMesh()
    assert spec_with_fallback(m, (64, 160), (None, "model")) == P(None, None)
    # tuple mixing absent+present axes keeps only the PRESENT name
    assert spec_with_fallback(m, (32, 64), (("model", "data"), None)) == P("data", None)
    assert spec_with_fallback(m, (33,), (("model", "data"),)) == P(None)  # 33 % 8
    assert spec_with_fallback(m, (32,), (("model", "pod"),)) == P(None)  # all absent
