"""RFF approximation tests (paper Sec. 4.2.1, Appx. B, Lemma C.3/C.4)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import gp_surrogate as gp
from repro.core import rff as rfflib


def test_rff_approximates_se_kernel():
    key = jax.random.PRNGKey(0)
    d, l = 5, 0.8
    params = rfflib.make_rff(key, 4096, d, l)
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (20, d))
    k_true = gp.sqexp(xs, xs, l)
    k_approx = rfflib.approx_kernel(params, xs, xs)
    assert float(jnp.abs(k_true - k_approx).max()) < 0.08


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rff_error_decreases_with_m(seed):
    """Lemma C.3: |phi phi' - k| = O(1/sqrt(M)) -- 16x features should cut the
    error decisively (allow slack for randomness)."""
    key = jax.random.PRNGKey(seed)
    d, l = 4, 1.0
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (16, d))
    k_true = gp.sqexp(xs, xs, l)

    def err(m, salt):
        p = rfflib.make_rff(jax.random.fold_in(key, salt), m, d, l)
        return float(jnp.sqrt(jnp.mean((rfflib.approx_kernel(p, xs, xs) - k_true) ** 2)))

    e_small = np.mean([err(64, s) for s in range(3)])
    e_big = np.mean([err(1024, s + 10) for s in range(3)])
    assert e_big < e_small


def test_grad_features_matches_autodiff():
    key = jax.random.PRNGKey(2)
    d, m = 6, 256
    params = rfflib.make_rff(key, m, d, 0.9)
    w = jax.random.normal(jax.random.fold_in(key, 1), (m,))
    x = jax.random.uniform(jax.random.fold_in(key, 2), (d,))
    g1 = rfflib.grad_features_t_w(params, x, w)
    g2 = jax.grad(lambda x: rfflib.features(params, x[None, :])[0] @ w)(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)
    g3 = rfflib.grad_features_t_w_batch(params, x[None, :], w)[0]
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g3), atol=1e-6)


def test_fit_w_padding_invariance():
    key = jax.random.PRNGKey(3)
    d, m, n = 3, 128, 12
    params = rfflib.make_rff(key, m, d, 0.8)
    hyper = gp.default_hyper(0.8, 1e-4)
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (n, d))
    ys = jnp.sin(xs.sum(-1))
    t1 = gp.traj_append_batch(gp.traj_init(n, d), xs, ys)
    t2 = gp.traj_append_batch(gp.traj_init(n + 30, d), xs, ys)
    w1 = rfflib.fit_w(params, t1, hyper)
    w2 = rfflib.fit_w(params, t2, hyper)
    # The invariance is exact in real arithmetic, but fit_w's clamped-eigh
    # pseudo-solve sits at the jitter floor (the RFF Gram of n=12 points is
    # rank-deficient), where f32 eigenvalue rounding differs between the
    # n x n and the padded (n+30) x (n+30) factorization: the near-null
    # modes it amplifies by 1/jitter carry ~1e-7 * 1/1e-4 ~ 1e-3 of wobble.
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-3)


def test_rff_surrogate_gradient_tracks_gp_gradient():
    """grad_muhat (RFF) should approximate grad_mu (exact GP) -- Lemma C.4."""
    key = jax.random.PRNGKey(4)
    d, l = 4, 0.7
    f = lambda x: jnp.sum(x**2) - jnp.sum(jnp.sin(x))
    xs = jax.random.uniform(jax.random.fold_in(key, 1), (60, d))
    ys = jax.vmap(f)(xs)
    traj = gp.traj_append_batch(gp.traj_init(64, d), xs, ys)
    hyper = gp.default_hyper(l, 1e-4)
    params = rfflib.make_rff(key, 4096, d, l)
    w = rfflib.fit_w(params, traj, hyper)
    xq = jnp.full((d,), 0.45)
    g_gp = gp.grad_mean(traj, hyper, xq)
    g_rff = rfflib.grad_features_t_w(params, xq, w)
    assert float(jnp.linalg.norm(g_gp - g_rff)) < 0.3 * float(jnp.linalg.norm(g_gp)) + 0.1


def test_server_aggregation_is_linear():
    """w_global = mean(w_i) -> global surrogate = mean of local surrogates
    (eq. 7): exact linearity, no approximation."""
    key = jax.random.PRNGKey(5)
    d, m, n_clients = 3, 64, 4
    params = rfflib.make_rff(key, m, d, 1.0)
    ws = jax.random.normal(jax.random.fold_in(key, 1), (n_clients, m))
    xq = jax.random.uniform(jax.random.fold_in(key, 2), (d,))
    per_client = jnp.stack([rfflib.grad_features_t_w(params, xq, w) for w in ws])
    agg = rfflib.grad_features_t_w(params, xq, ws.mean(0))
    np.testing.assert_allclose(np.asarray(agg), np.asarray(per_client.mean(0)), atol=1e-6)
