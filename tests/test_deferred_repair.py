"""Deferred-repair engine tests (DESIGN.md Sec. 2.6).

The contract under test: the branch-free scanned round body contains NO
eigh; an unhealthy factor update flags the client and FREEZES its factors
(solves stay finite through the last-good factors) until the chunk-boundary
repair pass runs one batched clamped-eigh over exactly the flagged clients;
and end-to-end the deferred engine tracks the inline-cond oracle
(``defer_repair=False``, i.e. the PR 2 engine) within the repo's
bounded-divergence equivalence contract -- the same scale as the
vmap/shard_map and scan/loop contracts, because the deferred engine lowers
the same math through batched kernels and a different (Cholesky) solver for
the round-end RFF fit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core import algorithms as alg
from repro.core import gp_surrogate as gp
from repro.core import objectives as obj
from repro.core import rounds as rounds_mod


def _fzoos_cfg(**kw):
    base = dict(name="fzoos", dim=8, n_clients=4, local_steps=3,
                n_features=32, traj_capacity=32, active_per_iter=1,
                active_candidates=8, active_round_end=1, lengthscale=0.5)
    base.update(kw)
    return alg.AlgoConfig(**base)


@pytest.fixture(scope="module")
def quad():
    return obj.make_quadratic(jax.random.PRNGKey(0), 4, 8, 2.0, 0.001)


# ---------------------------------------------------------------------------
# Factor-level: branch-free update vs the inline-cond oracle
# ---------------------------------------------------------------------------


def _drive(key, cap, d, n_events, batch, deferred, clustered=False):
    hyper = gp.default_hyper(0.7, 1e-4)
    traj = gp.traj_init(cap, d)
    factor = gp.factor_init(traj, hyper)
    for i in range(n_events):
        k = jax.random.fold_in(key, i)
        if clustered:
            xs = 0.4 + 0.005 * jax.random.uniform(k, (batch, d))
        else:
            xs = jax.random.uniform(k, (batch, d))
        traj, factor = gp.traj_extend(traj, factor, xs, jnp.sin(3.0 * xs.sum(-1)),
                                      hyper, deferred=deferred)
    return traj, factor, hyper


@pytest.mark.parametrize("clustered", [False, True],
                         ids=["well_posed", "clustered_near_singular"])
def test_deferred_update_matches_inline_while_healthy(clustered):
    """While every update is healthy (the measured-rate-~0 regime, incl. the
    clustered near-singular one from test_factor_cache) the deferred path
    adopts EXACTLY the factors the inline path adopts."""
    cap, d = 48, 5
    key = jax.random.PRNGKey(3)
    traj_i, fac_i, hyper = _drive(key, cap, d, 25, 3, deferred=False,
                                  clustered=clustered)
    traj_d, fac_d, _ = _drive(key, cap, d, 25, 3, deferred=True,
                              clustered=clustered)
    assert int(fac_i.n_refactors) == 0  # healthy: inline never fell back
    assert not bool(fac_d.needs_repair)
    np.testing.assert_array_equal(np.asarray(traj_i.xs), np.asarray(traj_d.xs))
    np.testing.assert_array_equal(np.asarray(fac_i.gram), np.asarray(fac_d.gram))
    np.testing.assert_array_equal(np.asarray(fac_i.chol), np.asarray(fac_d.chol))
    assert bool(fac_d.exact)


def test_poisoned_gram_flags_and_freezes():
    """The poisoned-Gram regime of test_factor_cache under the deferred
    path: no inline eigh -- the flag raises, the factors freeze, and every
    solve through the frozen factors stays finite."""
    cap, d = 12, 3
    key = jax.random.PRNGKey(5)
    traj, factor, hyper = _drive(key, cap, d, 4, 2, deferred=True)

    bad = factor._replace(gram=factor.gram.at[0, 1].set(5.0).at[1, 0].set(5.0),
                          exact=jnp.asarray(False))
    xs = jax.random.uniform(jax.random.fold_in(key, 99), (1, d))
    traj2 = gp.traj_append_batch(traj, xs, xs.sum(-1))
    fac2 = gp.factor_update_deferred(bad, traj2, hyper, 1, traj.count)

    assert bool(fac2.needs_repair)
    assert int(fac2.n_refactors) == int(bad.n_refactors)  # counted at repair
    np.testing.assert_array_equal(np.asarray(fac2.chol), np.asarray(bad.chol))
    np.testing.assert_array_equal(np.asarray(fac2.eigvecs), np.asarray(bad.eigvecs))
    assert bool(jnp.isfinite(gp.factor_solve(fac2, traj2.ys)).all())

    # flagged clients adopt NOTHING, even if a later candidate would be
    # healthy -- the freeze holds until the repair pass
    xs3 = jax.random.uniform(jax.random.fold_in(key, 100), (1, d))
    traj3 = gp.traj_append_batch(traj2, xs3, xs3.sum(-1))
    fac3 = gp.factor_update_deferred(fac2, traj3, hyper, 1, traj2.count)
    assert bool(fac3.needs_repair)
    np.testing.assert_array_equal(np.asarray(fac3.chol), np.asarray(fac2.chol))
    # ... but the cached Gram keeps its exact incremental updates
    gram_true, _ = gp._padded_gram(traj3, hyper)
    want = gram_true.at[0, 1].set(5.0).at[1, 0].set(5.0)
    np.testing.assert_allclose(np.asarray(fac3.gram), np.asarray(want), atol=1e-6)


def test_repair_matches_clamped_eigh_oracle():
    """The boundary repair == the inline fallback's clamped-eigh pseudo-solve
    (the NaN-robustness guarantee survives deferral)."""
    cap, d = 12, 3
    key = jax.random.PRNGKey(5)
    traj, factor, hyper = _drive(key, cap, d, 4, 2, deferred=True)
    jitter = gp._jitter_of(hyper)
    bad = factor._replace(gram=factor.gram.at[0, 1].set(5.0).at[1, 0].set(5.0),
                          needs_repair=jnp.asarray(True))

    healthy = factor  # second client: unflagged, must be untouched by repair
    stacked = jax.tree_util.tree_map(lambda a, b: jnp.stack([a, b]), bad, healthy)
    rep = gp.factor_repair_masked(stacked, jitter)

    assert not bool(rep.needs_repair[0]) and not bool(rep.needs_repair[1])
    assert int(rep.n_refactors[0]) == int(bad.n_refactors) + 1
    assert int(rep.n_refactors[1]) == int(healthy.n_refactors)
    np.testing.assert_array_equal(np.asarray(rep.chol[1]), np.asarray(healthy.chol))
    assert bool(rep.exact[1]) == bool(healthy.exact)

    # flagged client: repaired solves equal the from-scratch clamped eigh
    rep0 = jax.tree_util.tree_map(lambda a: a[0], rep)
    assert not bool(rep0.exact)  # routes through the repaired eigh factors
    v, w = gp._clamped_eigh(bad.gram, jitter)
    b = traj.ys * traj.valid_mask()
    np.testing.assert_allclose(
        np.asarray(gp.factor_solve(rep0, b)),
        np.asarray(gp._gram_solve((v, w), b)),
        rtol=1e-4, atol=1e-5,
    )


def test_update_after_repair_refreshes_to_exact():
    """Inexact factors never compound: the first healthy update after a
    repair refactorizes the exact cached Gram and returns to the Cholesky
    route (same contract as the inline fallback)."""
    cap, d = 16, 3
    key = jax.random.PRNGKey(8)
    traj, factor, hyper = _drive(key, cap, d, 8, 3, deferred=True)
    flagged = factor._replace(needs_repair=jnp.asarray(True))
    stacked = jax.tree_util.tree_map(lambda a: a[None], flagged)
    rep = jax.tree_util.tree_map(
        lambda a: a[0], gp.factor_repair_masked(stacked, gp._jitter_of(hyper)))
    xs = jax.random.uniform(jax.random.fold_in(key, 77), (2, d))
    traj2, fac2 = gp.traj_extend(traj, rep, xs, xs.sum(-1), hyper, deferred=True)
    assert bool(fac2.exact) and not bool(fac2.needs_repair)
    gram, _ = gp._padded_gram(traj2, hyper)
    np.testing.assert_allclose(np.asarray(fac2.chol),
                               np.asarray(jnp.linalg.cholesky(gram)), atol=2e-5)


# ---------------------------------------------------------------------------
# Engine-level: deferred vs inline-cond oracle, HLO, history threading
# ---------------------------------------------------------------------------


def _assert_bounded(r_ref, r_new):
    np.testing.assert_allclose(np.asarray(r_ref.xs[1]), np.asarray(r_new.xs[1]),
                               atol=5e-2)
    np.testing.assert_allclose(np.asarray(r_ref.xs), np.asarray(r_new.xs), atol=0.1)
    np.testing.assert_allclose(np.asarray(r_ref.f_values),
                               np.asarray(r_new.f_values), atol=5e-2)
    np.testing.assert_array_equal(np.asarray(r_ref.queries),
                                  np.asarray(r_new.queries))
    assert np.isfinite(np.asarray(r_new.f_values)).all()


def test_deferred_engine_matches_inline_oracle(quad):
    """End-to-end: scanned deferred engine vs the PR 2 inline-cond engine,
    bounded divergence + exact integer query accounting."""
    k = jax.random.PRNGKey(5)
    args = (k, quad, obj.quadratic_query, obj.quadratic_global_value, 10)
    r_inline = alg.simulate(_fzoos_cfg(defer_repair=False), *args, chunk=4)
    r_defer = alg.simulate(_fzoos_cfg(defer_repair=True), *args, chunk=4)
    _assert_bounded(r_inline, r_defer)
    # healthy regime: nothing was ever flagged, nothing repaired
    assert float(np.abs(np.asarray(r_defer.repair_rate)).max()) == 0.0
    assert float(np.abs(np.asarray(r_defer.refactor_rate)).max()) == 0.0


def test_deferred_engine_matches_inline_oracle_distributed(quad):
    """Same oracle contract through shard_map (per-shard repair path)."""
    from repro.core.federated import run_distributed

    mesh = jax.make_mesh((1,), ("data",))
    k = jax.random.PRNGKey(5)
    args = (k, quad, obj.quadratic_query, obj.quadratic_global_value, 6)
    r_inline = run_distributed(_fzoos_cfg(defer_repair=False), mesh, *args, chunk=3)
    r_defer = run_distributed(_fzoos_cfg(defer_repair=True), mesh, *args, chunk=3)
    _assert_bounded(r_inline, r_defer)


def test_deferred_engine_clustered_near_singular_regime():
    """The clustered active-query regime (radius-0.01 balls, cond ~ 1e6
    padded Gram): the engine must stay finite and track the inline oracle --
    this is the regime the inline eigh fallback existed for."""
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 2, 6, 2.0, 0.001)
    cfg_kw = dict(dim=6, n_clients=2, local_steps=4, traj_capacity=16,
                  n_features=16, active_per_iter=3, active_candidates=16,
                  active_round_end=2, noise=1e-5)
    k = jax.random.PRNGKey(9)
    args = (k, cobjs, obj.quadratic_query, obj.quadratic_global_value, 8)
    r_inline = alg.simulate(_fzoos_cfg(defer_repair=False, **cfg_kw), *args, chunk=4)
    r_defer = alg.simulate(_fzoos_cfg(defer_repair=True, **cfg_kw), *args, chunk=4)
    _assert_bounded(r_inline, r_defer)


def test_hlo_of_scanned_round_body_contains_no_eigh(quad):
    """THE acceptance criterion: the deferred scanned round body lowers with
    no eigh anywhere; the inline-cond oracle body (both-branches under the
    client vmap) demonstrably does.  Fingerprints come from
    ``analysis.hlo_audit`` -- no inline custom_call_target regex here."""
    from repro.analysis import hlo_audit
    from repro.core import rff as rfflib

    x0 = jnp.full((8,), 0.5, jnp.float32)

    def lower_body(cfg):
        rff = rfflib.make_rff(jax.random.PRNGKey(1), cfg.n_features, cfg.dim,
                              cfg.lengthscale)
        states = alg.init_states(cfg, jax.random.PRNGKey(2), x0)
        cf = rounds_mod.sim_chunk_fn(cfg, rff, obj.quadratic_query,
                                     obj.quadratic_global_value, None, 2, 1, 4)
        return jax.jit(cf).lower(states, quad, x0, jnp.int32(0)).as_text()

    deferred = lower_body(_fzoos_cfg(defer_repair=True))
    inline = lower_body(_fzoos_cfg(defer_repair=False))
    assert hlo_audit.check_no_eigh(deferred, "deferred body") == []
    assert hlo_audit.contains_eigh(inline), hlo_audit.eigh_fingerprints()


def test_repair_rate_threaded_through_history(quad):
    cfg = _fzoos_cfg()
    res = alg.simulate(cfg, jax.random.PRNGKey(5), quad, obj.quadratic_query,
                       obj.quadratic_global_value, 5, chunk=2)
    assert res.repair_rate.shape == (5,)
    assert np.isfinite(np.asarray(res.repair_rate)).all()


def test_checkpoint_roundtrips_needs_repair_bitwise(quad, tmp_path):
    """The needs_repair flag rides in ClientState: a checkpoint taken with
    clients flagged must restore the flag (and the frozen factors) bitwise."""
    cfg = _fzoos_cfg()
    x0 = jnp.full((8,), 0.5, jnp.float32)
    states = alg.init_states(cfg, jax.random.PRNGKey(1), x0)
    flags = jnp.asarray([True, False, True, False])
    states = states._replace(factor=states.factor._replace(needs_repair=flags))
    hist = rounds_mod.history_init(4, x0, jnp.zeros((), jnp.float32))

    ckpt = str(tmp_path / "repair_ckpt")
    ckpt_io.save_round_state(ckpt, 2, states, hist)
    restored, _, step = ckpt_io.restore_round_state(ckpt, states, hist)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored.factor.needs_repair),
                                  np.asarray(flags))
    for got, want in zip(jax.tree_util.tree_leaves(restored),
                         jax.tree_util.tree_leaves(states)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_repair_pass_noop_when_unflagged(quad):
    cfg = _fzoos_cfg()
    states = alg.init_states(cfg, jax.random.PRNGKey(1), jnp.full((8,), 0.5))
    repaired, n = rounds_mod.repair_flagged_clients(states, cfg)
    assert n == 0 and repaired is states


def test_repair_pass_repairs_only_flagged(quad):
    cfg = _fzoos_cfg()
    states = alg.init_states(cfg, jax.random.PRNGKey(1), jnp.full((8,), 0.5))
    flags = jnp.asarray([False, True, False, False])
    states = states._replace(factor=states.factor._replace(needs_repair=flags))
    repaired, n = rounds_mod.repair_flagged_clients(states, cfg)
    assert n == 1
    assert not bool(repaired.factor.needs_repair.any())
    np.testing.assert_array_equal(np.asarray(repaired.factor.n_refactors),
                                  np.asarray(flags, np.int32))
    assert not bool(repaired.factor.exact[1])  # repaired -> eigh route
    assert bool(repaired.factor.exact[0])  # untouched


# ---------------------------------------------------------------------------
# Zero-sync boundary: device-side repair decision (DESIGN.md Sec. 3)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_mesh", [False, True], ids=["vmap", "shard_map"])
def test_device_repair_matches_host_oracle(quad, use_mesh):
    """The device-decided boundary (`boundary_repair_on_device`) == the
    host-read oracle (`repair_flagged_clients`), leaf for leaf, on both
    engines -- including n_refactors accounting and flag clearing."""
    mesh = jax.make_mesh((1,), ("data",)) if use_mesh else None
    cfg = _fzoos_cfg()
    states = alg.init_states(cfg, jax.random.PRNGKey(1), jnp.full((8,), 0.5))
    flags = jnp.asarray([True, False, False, True])
    states = states._replace(factor=states.factor._replace(needs_repair=flags))
    if mesh is not None:
        from repro.core.federated import shard_clients
        states = shard_clients(mesh, states)

    want, n = rounds_mod.repair_flagged_clients(states, cfg, mesh=mesh)
    assert n == 2
    got = rounds_mod.boundary_repair_on_device(states, cfg, mesh=mesh)
    for g, w in zip(jax.tree_util.tree_leaves(got.factor),
                    jax.tree_util.tree_leaves(want.factor)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert not bool(got.factor.needs_repair.any())


def test_device_repair_noop_when_clear(quad):
    """All-healthy boundary: the gated branch is untaken and the factors come
    back bitwise unchanged (and non-deferred configs skip the pass whole)."""
    cfg = _fzoos_cfg()
    states = alg.init_states(cfg, jax.random.PRNGKey(1), jnp.full((8,), 0.5))
    # snapshot first: the boundary donates the factor buffers (in-place)
    want = [np.asarray(a) for a in jax.tree_util.tree_leaves(states.factor)]
    got = rounds_mod.boundary_repair_on_device(states, cfg)
    for g, w in zip(jax.tree_util.tree_leaves(got.factor), want):
        np.testing.assert_array_equal(np.asarray(g), w)

    inline_cfg = _fzoos_cfg(defer_repair=False)
    st2 = alg.init_states(inline_cfg, jax.random.PRNGKey(1), jnp.full((8,), 0.5))
    assert rounds_mod.boundary_repair_on_device(st2, inline_cfg) is st2


def test_boundary_executable_gates_eigh_behind_cond(quad):
    """The fused boundary executable carries the repair eigh BEHIND a
    conditional (so the all-healthy steady state never executes it), while
    the scanned chunk body stays eigh-free (asserted separately above).
    The jaxpr-level half of this lives in the ``boundary-repair`` contract;
    here the lowered text is checked through the shared auditor."""
    import re

    from repro.analysis import hlo_audit
    from repro.analysis.contracts import check_contract

    cfg = _fzoos_cfg()
    states = alg.init_states(cfg, jax.random.PRNGKey(1), jnp.full((8,), 0.5))
    txt = jax.jit(gp.factor_repair_gated).lower(
        states.factor, jnp.float32(1e-4)).as_text()
    assert hlo_audit.contains_eigh(txt)  # the repair branch is there...
    assert re.search(r"\bcase\b|\bconditional\b", txt)  # ...but gated
    assert check_contract("boundary-repair") == []


def test_steady_state_boundary_issues_no_device_get(quad):
    """THE tentpole acceptance: a steady-state deferred distributed run
    performs ZERO host syncs at chunk boundaries -- no ``device_get`` of the
    flag vector (or anything else) between the initial eval and the final
    history return."""
    from repro.core import rff as rfflib

    mesh = jax.make_mesh((1,), ("data",))
    cfg = _fzoos_cfg()
    x0 = jnp.full((8,), 0.5, jnp.float32)
    rff = rfflib.make_rff(jax.random.PRNGKey(1), cfg.n_features, cfg.dim,
                          cfg.lengthscale)
    from repro.analysis import steady_state_guard
    from repro.core.federated import shard_clients
    states = shard_clients(mesh, alg.init_states(cfg, jax.random.PRNGKey(2), x0))

    # allow_compiles=None: first-call compiles are expected here; the guard
    # raises SteadyStateViolation on any device_get between entry and exit.
    with steady_state_guard(allow_compiles=None, allow_device_gets=0):
        _, res = rounds_mod.run_rounds(
            cfg, rff, obj.quadratic_query, quad, states, x0,
            obj.quadratic_global_value, rounds=6, chunk=2, mesh=mesh,
        )
    assert np.isfinite(np.asarray(res.f_values)).all()


# ---------------------------------------------------------------------------
# Client-batched phase vs the per-client vmapped phase
# ---------------------------------------------------------------------------


def test_fit_w_chol_tracks_fit_w():
    """The eigh-free round-end fit == eq. 6 within solver roundoff of the
    same (cond-limited) RFF Gram system, in function space."""
    from repro.core import rff as rfflib

    cap, d, m = 32, 4, 128
    key = jax.random.PRNGKey(8)
    traj, factor, hyper = _drive(key, cap, d, 10, 3, deferred=True)
    params = rfflib.make_rff(jax.random.fold_in(key, 1), m, d, float(hyper.lengthscale))
    w_eigh = rfflib.fit_w(params, traj, hyper)
    w_chol = rfflib.fit_w_chol(params, traj, hyper, factor)
    xq = jax.random.uniform(jax.random.fold_in(key, 2), (16, d))
    g1 = rfflib.grad_features_t_w_batch(params, xq, w_eigh)
    g2 = rfflib.grad_features_t_w_batch(params, xq, w_chol)
    scale = max(float(jnp.abs(g1).max()), 1.0)
    assert float(jnp.abs(g1 - g2).max()) / scale < 5e-2


def test_client_batched_surrogate_matches_per_client():
    """The client-batched cached scoring/grad helpers == vmap of the
    per-client ones (identical math, batched contraction order)."""
    cap, d, n_clients, nc = 24, 5, 3, 12
    hyper = gp.default_hyper(0.7, 1e-4)
    key = jax.random.PRNGKey(4)

    trajs, factors = [], []
    for c in range(n_clients):
        tr, fa, _ = _drive(jax.random.fold_in(key, c), cap, d, 6, 3, deferred=True)
        trajs.append(tr)
        factors.append(fa)
    trajs = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *trajs)
    factors = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *factors)
    xq = jax.random.uniform(jax.random.fold_in(key, 99), (n_clients, nc, d))

    got = gp.grad_uncertainty_batch_cached_clients(trajs, factors, hyper, xq)
    want = jax.vmap(
        lambda tr, fa, q: gp.grad_uncertainty_batch_cached(tr, fa, hyper, q)
    )(trajs, factors, xq)
    prior = d / float(hyper.lengthscale) ** 2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4 * prior)

    x1 = jax.random.uniform(jax.random.fold_in(key, 98), (n_clients, d))
    g_got = gp.grad_mean_cached_clients(trajs, factors, hyper, x1)
    g_want = jax.vmap(
        lambda tr, fa, x: gp.grad_mean_cached(tr, fa, hyper, x)
    )(trajs, factors, x1)
    scale = max(float(jnp.abs(g_want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(g_got) / scale,
                               np.asarray(g_want) / scale, atol=1e-5)
