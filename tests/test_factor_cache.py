"""Property tests for the incremental Gram-factor cache (DESIGN.md Sec. 2).

The contract: every cached quantity (gp_alpha / grad_mean /
grad_uncertainty_*) matches the seed's eigh-from-scratch oracle over
randomized append/overwrite sequences that wrap the ring buffer.  In the
well-posed regime the match is strict (<= 1e-4).  In the clustered-query
NEAR-SINGULAR regime the padded Gram's f32 eigenvalues sit at the jitter
floor and BOTH factorizations are only determined up to the system's
conditioning (cond ~ cap/jitter ~ 1e6, so f32 solves of the same matrix by
any two algorithms disagree by O(cond * eps) along near-null modes); there
the equality that is numerically meaningful -- and asserted strictly -- is
the backward one: both alphas reproduce the same GP fit K @ alpha to 1e-4,
while the consumed functionals agree to conditioning-scaled tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import gp_surrogate as gp


def _random_walk_traj(key, cap, d, n_events, batch, clustered=False):
    """Build (traj, factor) via traj_extend and a plain traj via append_batch."""
    hyper = gp.default_hyper(0.7, 1e-4)
    traj = gp.traj_init(cap, d)
    factor = gp.factor_init(traj, hyper)
    for i in range(n_events):
        k = jax.random.fold_in(key, i)
        if clustered:
            xs = 0.4 + 0.005 * jax.random.uniform(k, (batch, d))
        else:
            xs = jax.random.uniform(k, (batch, d))
        ys = jnp.sin(3.0 * xs.sum(-1))
        traj, factor = gp.traj_extend(traj, factor, xs, ys, hyper)
    return traj, factor, hyper


def _f64_truth(traj, hyper, xq):
    """Ground-truth alpha / grad_mean / uncertainty via float64 numpy.

    The padded Gram at the default jitter reaches cond ~ 1e5-1e6 once the
    ring fills (SE spectra decay exponentially), so comparing two f32
    algorithms directly bounds nothing: along near-null modes ANY two
    backward-stable solvers disagree by O(cond * eps).  The meaningful
    contract -- asserted below -- is that the cached path is at least as
    close to the true answer as the eigh oracle, and that both agree to
    1e-4 whenever the system is well-posed enough for that to be decidable.
    """
    g = np.asarray(gp._padded_gram(traj, hyper)[0], np.float64)
    mask = np.asarray(traj.valid_mask(), np.float64)
    xs = np.asarray(traj.xs, np.float64)
    ys = np.asarray(traj.ys, np.float64) * mask
    l = float(hyper.lengthscale)
    a = np.linalg.solve(g, ys)
    d = xs.shape[1]
    gs, us = [], []
    for x in np.asarray(xq, np.float64):
        diff = x[None] - xs
        k = np.exp(-0.5 * (diff**2).sum(-1) / l**2)
        jac = (-diff / l**2) * (k * mask)[:, None]
        gs.append(jac.T @ a)
        us.append(max(d / l**2 - (jac * np.linalg.solve(g, jac)).sum(), 0.0))
    return a, np.stack(gs), np.array(us)


def _assert_no_less_accurate(got, oracle, truth, scale, slack=3.0, floor=1e-4):
    """cached error <= slack * oracle error, up to a 1e-4 * scale floor."""
    err_c = np.abs(np.asarray(got) - truth).max()
    err_o = np.abs(np.asarray(oracle) - truth).max()
    assert err_c <= max(slack * err_o, floor * scale), (err_c, err_o, scale)


@settings(max_examples=8, deadline=None)
@given(
    cap=st.integers(8, 48),
    batch=st.integers(1, 6),
    n_events=st.integers(3, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_cached_matches_oracle_random_sequences(cap, batch, n_events, seed):
    """Randomized append/overwrite sequences wrapping the ring buffer."""
    d = 4
    key = jax.random.PRNGKey(seed)
    traj, factor, hyper = _random_walk_traj(key, cap, d, n_events, batch)
    xq = jax.random.uniform(jax.random.fold_in(key, 777), (5, d))
    a64, g64, u64 = _f64_truth(traj, hyper, xq)

    a_o = gp.gp_alpha(traj, hyper)
    a_c = gp.gp_alpha_cached(traj, factor, hyper)
    _assert_no_less_accurate(a_c, a_o, a64, 1.0 + np.abs(a64).max())

    g_o = gp.grad_mean_batch(traj, hyper, xq)
    g_c = jax.vmap(lambda x: gp.grad_mean_cached(traj, factor, hyper, x))(xq)
    _assert_no_less_accurate(g_c, g_o, g64, 1.0 + np.abs(g64).max())

    u_o = gp.grad_uncertainty_batch(traj, hyper, xq)
    u_c = gp.grad_uncertainty_batch_cached(traj, factor, hyper, xq)
    prior = d / float(hyper.lengthscale) ** 2
    # The fused-contraction scores carry a larger (centroid-shift-mitigated)
    # f32 constant than the direct J-solve form; they only RANK candidates.
    _assert_no_less_accurate(u_c, u_o, u64, prior, slack=3.0, floor=5e-4)

    # In the well-posed regime the two f32 paths must also agree DIRECTLY
    # to <= 1e-4 (scaled): that is the regime where the comparison is
    # determined beyond solver roundoff (cond <~ 1e3, i.e. eps*cond < 1e-4;
    # ||gram||_2 <= n_valid + jitter for the SE kernel).
    lam_min = float(jnp.linalg.eigvalsh(gp._padded_gram(traj, hyper)[0])[0])
    if lam_min > 1e-3 * float(traj.n_valid()):
        np.testing.assert_allclose(
            np.asarray(a_c), np.asarray(a_o), atol=1e-4 * (1.0 + np.abs(a64).max())
        )
        np.testing.assert_allclose(
            np.asarray(g_c), np.asarray(g_o), atol=1e-4 * (1.0 + np.abs(g64).max())
        )
        np.testing.assert_allclose(np.asarray(u_c), np.asarray(u_o), atol=1e-4 * prior)


def test_cached_matches_oracle_clustered_near_singular():
    """The clustered active-query regime (cond ~ 1e6 padded Gram)."""
    cap, d = 64, 6
    key = jax.random.PRNGKey(3)
    traj, factor, hyper = _random_walk_traj(key, cap, d, 40, 4, clustered=True)
    gram, mask = gp._padded_gram(traj, hyper)
    xq = 0.4 + 0.005 * jax.random.uniform(jax.random.fold_in(key, 9), (5, d))
    a64, g64, u64 = _f64_truth(traj, hyper, xq)

    a_o = gp.gp_alpha(traj, hyper)
    a_c = gp.gp_alpha_cached(traj, factor, hyper)
    # Both alphas must induce the SAME GP fit: K (a_c - a_o) ~ 0, i.e. the
    # backward-error statement of gp_alpha equality, which IS well-posed.
    ys_m = traj.ys * mask
    res_c = float(jnp.abs(gram @ a_c - ys_m).max())
    res_o = float(jnp.abs(gram @ a_o - ys_m).max())
    assert res_c <= max(2.0 * res_o, 1e-4)
    _assert_no_less_accurate(a_c, a_o, a64, 1.0 + np.abs(a64).max())

    g_o = gp.grad_mean_batch(traj, hyper, xq)
    g_c = jax.vmap(lambda x: gp.grad_mean_cached(traj, factor, hyper, x))(xq)
    _assert_no_less_accurate(g_c, g_o, g64, 1.0 + np.abs(g64).max())

    u_o = gp.grad_uncertainty_batch(traj, hyper, xq)
    u_c = gp.grad_uncertainty_batch_cached(traj, factor, hyper, xq)
    prior = d / float(hyper.lengthscale) ** 2
    _assert_no_less_accurate(u_c, u_o, u64, prior, slack=3.0, floor=5e-4)


@settings(max_examples=10, deadline=None)
@given(
    cap=st.integers(4, 40),
    k=st.integers(1, 90),
    pre=st.integers(0, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_traj_append_batch_matches_scan_of_appends(cap, k, pre, seed):
    """The masked-scatter batch append == folding traj_append over rows."""
    d = 3
    key = jax.random.PRNGKey(seed)
    traj_a = gp.traj_init(cap, d)
    traj_b = gp.traj_init(cap, d)
    # arbitrary starting count (possibly wrapped)
    xs0 = jax.random.uniform(jax.random.fold_in(key, 0), (pre, d))
    ys0 = xs0.sum(-1)
    for i in range(pre):
        traj_a = gp.traj_append(traj_a, xs0[i], ys0[i])
    traj_b = gp.traj_append_batch(traj_b, xs0, ys0) if pre else traj_b

    xs = jax.random.uniform(jax.random.fold_in(key, 1), (k, d))
    ys = xs.sum(-1) * 2.0
    for i in range(k):
        traj_a = gp.traj_append(traj_a, xs[i], ys[i])
    traj_b = gp.traj_append_batch(traj_b, xs, ys)

    assert int(traj_a.count) == int(traj_b.count)
    np.testing.assert_array_equal(np.asarray(traj_a.xs), np.asarray(traj_b.xs))
    np.testing.assert_array_equal(np.asarray(traj_a.ys), np.asarray(traj_b.ys))


def test_border_extension_matches_blocked_refresh():
    """Pre-wrap bordered appends == potrf of the full padded Gram."""
    cap, d = 32, 5
    hyper = gp.default_hyper(0.8, 1e-4)
    key = jax.random.PRNGKey(11)
    traj = gp.traj_init(cap, d)
    factor = gp.factor_init(traj, hyper)
    for i in range(6):  # 6 * 5 = 30 < cap: all bordered, no wrap
        xs = jax.random.uniform(jax.random.fold_in(key, i), (5, d))
        traj, factor = gp.traj_extend(traj, factor, xs, xs.sum(-1), hyper)
    assert bool(factor.exact)
    assert int(factor.n_refactors) == 0
    gram, _ = gp._padded_gram(traj, hyper)
    np.testing.assert_allclose(
        np.asarray(factor.chol), np.asarray(jnp.linalg.cholesky(gram)), atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(factor.gram), np.asarray(gram), atol=1e-6)


def test_incremental_gram_rows_exact_after_wrap():
    """The cached Gram matrix tracks the true padded Gram bit-tight."""
    cap, d = 16, 3
    hyper = gp.default_hyper(0.6, 1e-4)
    key = jax.random.PRNGKey(2)
    traj = gp.traj_init(cap, d)
    factor = gp.factor_init(traj, hyper)
    for i in range(20):  # wraps the ring several times
        xs = jax.random.uniform(jax.random.fold_in(key, i), (3, d))
        traj, factor = gp.traj_extend(traj, factor, xs, xs.sum(-1), hyper)
    gram, _ = gp._padded_gram(traj, hyper)
    np.testing.assert_allclose(np.asarray(factor.gram), np.asarray(gram), atol=1e-6)


def test_chol_rank1_update_matches_refactorization():
    key = jax.random.PRNGKey(7)
    n = 24
    a = jax.random.normal(key, (n, n)) / np.sqrt(n)
    spd = a @ a.T + 0.5 * jnp.eye(n)
    L = jnp.linalg.cholesky(spd)
    x = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (n,))
    floor = jnp.asarray(1e-6)

    up, ok = gp.chol_rank1_update(L, x, 1.0, floor)
    assert bool(ok)
    np.testing.assert_allclose(
        np.asarray(up), np.asarray(jnp.linalg.cholesky(spd + jnp.outer(x, x))), atol=5e-5
    )
    down, ok = gp.chol_rank1_update(up, x, -1.0, floor)
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(down), np.asarray(L), atol=5e-5)


def test_chol_rank1_downdate_detects_pivot_floor():
    """A downdate that destroys positive-definiteness must flag ok=False.
    (The returned factor is unusable by contract -- callers refactor.)"""
    n = 8
    L = jnp.linalg.cholesky(jnp.eye(n) * 0.01)
    x = jnp.full((n,), 0.2)  # ||x||^2 >> trace: definitely breaks PD
    _, ok = gp.chol_rank1_update(L, x, -1.0, jnp.asarray(1e-3))
    assert not bool(ok)


def test_fallback_engages_on_indefinite_gram_and_matches_clamped_eigh():
    """Poisoned (non-PD) Gram: potrf fails -> clamped-eigh fallback, whose
    solves equal the from-scratch clamped pseudo-solve EXACTLY.  This is the
    NaN-robustness guarantee the seed's eigh path provided."""
    cap, d = 12, 3
    hyper = gp.default_hyper(1.0, 1e-4)
    key = jax.random.PRNGKey(5)
    traj = gp.traj_init(cap, d)
    factor = gp.factor_init(traj, hyper)
    for i in range(4):
        xs = jax.random.uniform(jax.random.fold_in(key, i), (2, d))
        traj, factor = gp.traj_extend(traj, factor, xs, xs.sum(-1), hyper)

    # Poison an off-diagonal pair beyond any PSD bound; the next append's
    # blocked refresh sees an indefinite matrix and must take the fallback.
    bad_gram = factor.gram.at[0, 1].set(5.0).at[1, 0].set(5.0)
    poisoned = factor._replace(gram=bad_gram, exact=jnp.asarray(False))
    xs = jax.random.uniform(jax.random.fold_in(key, 99), (1, d))
    old_count = traj.count
    traj2 = gp.traj_append_batch(traj, xs, xs.sum(-1))
    fac2 = gp.factor_update(poisoned, traj2, hyper, 1, old_count)

    assert not bool(fac2.exact)
    assert int(fac2.n_refactors) == int(poisoned.n_refactors) + 1
    assert bool(jnp.isfinite(gp.factor_solve(fac2, traj2.ys)).all())

    jitter = gp._jitter_of(hyper)
    v, w = gp._clamped_eigh(fac2.gram, jitter)
    b = traj2.ys * traj2.valid_mask()
    # Same clamped-eigh pseudo-solve; rtol covers eager-vs-cond-traced eigh
    # lowering roundoff on the O(1/jitter)-amplified entries.
    np.testing.assert_allclose(
        np.asarray(gp.factor_solve(fac2, b)),
        np.asarray(gp._gram_solve((v, w), b)),
        rtol=1e-4, atol=1e-5,
    )


def test_simulate_cached_equivalent_to_seed_path():
    """use_factor_cache is a pure perf refactor: same-key simulations track
    each other within f32 conditioning noise and converge identically."""
    from repro.core import algorithms as alg
    from repro.core import objectives as obj

    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 4, 8, 2.0, 0.001)
    base = dict(name="fzoos", dim=8, n_clients=4, local_steps=3,
                n_features=32, traj_capacity=32, active_per_iter=2,
                active_candidates=16, active_round_end=2, lengthscale=0.5)
    k = jax.random.PRNGKey(5)
    r_new = alg.simulate(alg.AlgoConfig(**base, use_factor_cache=True), k, cobjs,
                         obj.quadratic_query, obj.quadratic_global_value, 6)
    r_old = alg.simulate(alg.AlgoConfig(**base, use_factor_cache=False), k, cobjs,
                         obj.quadratic_query, obj.quadratic_global_value, 6)
    # Same scale as the repo's sim-vs-distributed contract: tight early, then
    # f32 reduction-order noise amplified by the chaotic optimizer loop.
    assert float(np.abs(np.asarray(r_new.xs[1]) - np.asarray(r_old.xs[1])).max()) < 2e-2
    assert float(np.abs(np.asarray(r_new.xs) - np.asarray(r_old.xs)).max()) < 0.1
    assert float(np.abs(np.asarray(r_new.f_values) - np.asarray(r_old.f_values)).max()) < 0.05
    assert np.isfinite(np.asarray(r_new.f_values)).all()


def test_refactor_rate_reported_and_zero_in_healthy_regime():
    from functools import partial

    from repro.core import algorithms as alg
    from repro.core import objectives as obj
    from repro.core import rff as rfflib

    key = jax.random.PRNGKey(0)
    cfg = alg.AlgoConfig(name="fzoos", dim=6, n_clients=2, local_steps=2,
                         n_features=16, traj_capacity=16, active_per_iter=1,
                         active_candidates=8, active_round_end=1)
    cobjs = obj.make_quadratic(key, 2, 6, 2.0, 0.001)
    rff = rfflib.make_rff(jax.random.PRNGKey(1), 16, 6, cfg.lengthscale)
    states = alg.init_states(cfg, key, jnp.full((6,), 0.5))
    mean_fn = lambda t: jax.tree_util.tree_map(partial(jnp.mean, axis=0), t)
    states, stats = alg.run_round(
        cfg, rff, obj.quadratic_query, cobjs, states, jnp.full((6,), 0.5), mean_fn
    )
    assert float(stats.refactor_rate) == 0.0
    assert int(states.factor.n_updates[0]) > 0


def test_fit_w_from_factor_tracks_fit_w():
    """The exact-factor round-end fit differs from eq. 6 only by the RFF
    feature-approximation error, which shrinks with M."""
    from repro.core import rff as rfflib

    cap, d = 48, 4
    key = jax.random.PRNGKey(8)
    traj, factor, hyper = _random_walk_traj(key, cap, d, 12, 4)

    def gap(m):
        params = rfflib.make_rff(jax.random.fold_in(key, m), m, d, float(hyper.lengthscale))
        w_eq6 = rfflib.fit_w(params, traj, hyper)
        w_fac = rfflib.fit_w_from_factor(params, traj, factor)
        # compare in function space at probe points (w lives in feature space)
        xq = jax.random.uniform(jax.random.fold_in(key, 123), (16, d))
        g1 = rfflib.grad_features_t_w_batch(params, xq, w_eq6)
        g2 = rfflib.grad_features_t_w_batch(params, xq, w_fac)
        return float(jnp.abs(g1 - g2).max())

    assert gap(4096) < 0.25 * gap(64) + 1e-3
