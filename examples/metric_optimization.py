"""Federated non-differentiable metric optimization (paper Sec. 6.3,
CPU-scaled): fine-tune a trained MLP's output layer to optimize macro
precision using only metric queries, across 7 heterogeneous clients.

    PYTHONPATH=src python examples/metric_optimization.py
"""

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import model_objectives as mobj


def main():
    key = jax.random.PRNGKey(0)
    n_clients, p_shared = 7, 0.7
    cobjs, d = mobj.make_metric_objective(key, n_clients=n_clients, p_shared=p_shared)
    x0 = jnp.full((d,), 0.5)
    base = float(mobj.metric_global_value(cobjs, x0))
    print(f"metric opt: d={d} (output layer), N={n_clients}, P={p_shared}")
    print(f"1 - precision at theta*: {base:.4f}\n")

    for name in ("fzoos", "fedzo"):
        cfg = alg.AlgoConfig(
            name=name, dim=d, n_clients=n_clients, local_steps=5, eta=0.02,
            q=20, fd_lambda=5e-3, n_features=256, traj_capacity=96,
            active_per_iter=3, active_candidates=30, active_round_end=3,
            lengthscale=0.5, noise=1e-5,
        )
        res = alg.simulate(cfg, jax.random.PRNGKey(1), cobjs,
                           mobj.metric_query, mobj.metric_global_value, rounds=10)
        print(f"== {name} ==  best 1-precision = {float(jnp.min(res.f_values)):.4f} "
              f"({int(res.queries[-1])} queries/client)")


if __name__ == "__main__":
    main()
