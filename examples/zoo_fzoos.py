"""FZooS x architecture zoo: zeroth-order federated fine-tuning of a slice
of ANY assigned architecture (here mamba2 + qwen), where each query is a
real forward pass of the model (DESIGN.md Sec. 4).

    PYTHONPATH=src python examples/zoo_fzoos.py --arch mamba2-370m
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import algorithms as alg
from repro.core import model_objectives as mobj
from repro.models.model import init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m",
                    choices=[a.replace("_", "-") for a in ARCH_IDS] + list(ARCH_IDS))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch.replace("-", "_"), "smoke")
    key = jax.random.PRNGKey(0)
    params, _ = init_train_state(key, cfg)
    cobjs = mobj.make_lm_objective(key, cfg, n_clients=args.clients, batch=1, seq=24)
    query, global_value, d, _ = mobj.make_lm_query(cfg, params)
    print(f"arch={cfg.name}  ZOO dim={d} (final-norm gains)  clients={args.clients}")

    acfg = alg.AlgoConfig(
        name="fzoos", dim=d, n_clients=args.clients, local_steps=4, eta=0.02,
        n_features=128, traj_capacity=64, active_per_iter=2,
        active_candidates=16, active_round_end=2, lengthscale=0.5, noise=1e-5,
    )
    res = alg.simulate(acfg, jax.random.PRNGKey(1), cobjs, query, global_value,
                       rounds=args.rounds)
    for r in range(args.rounds + 1):
        print(f"  round {r}: scaled global loss = {float(res.f_values[r]):.5f}")
    print(f"best = {float(jnp.min(res.f_values)):.5f} "
          f"(init {float(res.f_values[0]):.5f})")


if __name__ == "__main__":
    main()
