"""Federated black-box adversarial attack (paper Sec. 6.2, CPU-scaled).

Ten clients hold private classifiers trained on P-controlled label subsets;
FZooS finds a single perturbation that flips the AVERAGED prediction using
only function queries of the margins.

    PYTHONPATH=src python examples/adversarial_attack.py
"""

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import model_objectives as mobj


def main():
    key = jax.random.PRNGKey(0)
    n_clients, p_shared = 6, 0.5
    cobjs, img = mobj.make_attack_objective(
        key, n_clients=n_clients, p_shared=p_shared, side=8, train_per_client=256,
    )
    d = int(img.shape[-1])
    print(f"attack: d={d} (8x8 image), N={n_clients}, P={p_shared}")
    x0 = jnp.full((d,), 0.5)
    print(f"initial averaged margin: {float(mobj.attack_global_value(cobjs, x0)):+.4f} "
          f"(success = {bool(mobj.attack_success(cobjs, x0))})\n")

    cfg = alg.AlgoConfig(
        name="fzoos", dim=d, n_clients=n_clients, local_steps=5, eta=0.02,
        n_features=128, traj_capacity=96, active_per_iter=3,
        active_candidates=30, active_round_end=3, lengthscale=0.5, noise=1e-5,
    )
    res = alg.simulate(cfg, jax.random.PRNGKey(1), cobjs,
                       mobj.attack_query, mobj.attack_global_value, rounds=12)
    for r in range(0, 13, 2):
        m = float(res.f_values[r])
        print(f"  round {r:3d}  averaged margin = {m:+.4f}  "
              f"{'ATTACK SUCCEEDS' if m < 0 else ''}")
    best = float(jnp.min(res.f_values))
    print(f"\nbest margin {best:+.4f} -> success = {best < 0} "
          f"with {int(res.queries[-1])} queries/client")


if __name__ == "__main__":
    main()
