"""End-to-end driver (deliverable b): train a ~100M-param dense LM on the
synthetic token pipeline for a few hundred steps, checkpointing as it goes.

This instantiates a REAL mid-size config (qwen1.5-family geometry at ~100M:
12L, d=640, vocab 32k) rather than a toy, and shows the full substrate:
config -> init -> sharded train loop -> checkpoint -> restore.

    PYTHONPATH=src python examples/train_lm.py --steps 200
(CPU: ~1-2 s/step; pass --steps 20 for a smoke run.)
"""

import argparse
import dataclasses
import time

import jax

from repro.checkpoint import restore_train_state, save_train_state
from repro.configs import get_config
from repro.data.pipeline import SyntheticTextConfig, synthetic_batch
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_train_state, train_step
from repro.models.params import count_params
from repro.optim import warmup_cosine_schedule
from repro.sharding.rules import ShardingPolicy, mesh_context


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen1_5_0_5b", "full"),
        name="qwen-100m", n_layers=12, d_model=640, n_heads=10, n_kv_heads=10,
        head_dim=64, d_ff=1792, vocab_size=32768,
    )
    print(f"model: {cfg.name}  params = {count_params(cfg) / 1e6:.1f}M")

    policy = ShardingPolicy(remat=False)
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    sched = warmup_cosine_schedule(3e-4, 20, args.steps)
    dcfg = SyntheticTextConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                               batch_size=args.batch_size, seed=0)
    step_fn = jax.jit(lambda p, o, b, lr: train_step(p, o, cfg, b, policy, lr))

    with mesh_context(make_host_mesh()):
        t0 = time.time()
        for step in range(args.steps):
            params, opt, m = step_fn(params, opt, synthetic_batch(dcfg, step), sched(step))
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                      f"grad_norm {float(m['grad_norm']):.2f}  "
                      f"{(time.time() - t0):.0f}s", flush=True)
        save_train_state(args.ckpt_dir, args.steps, params, opt,
                         {"loss": float(m["loss"])})
    print(f"checkpointed to {args.ckpt_dir}")

    # prove restore round-trips
    p2, o2, s = restore_train_state(args.ckpt_dir, params, opt)
    print(f"restored step {s}; params identical:",
          all((a == b).all() for a, b in zip(
              jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))))


if __name__ == "__main__":
    main()
