"""Quickstart: FZooS vs FedZO on the paper's heterogeneous quadratic
(Sec. 6.1, CPU-scaled).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import objectives as obj


def main():
    d, n_clients, c_het = 30, 5, 5.0
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, n_clients, d, c_het, noise_std=0.001)
    fstar = obj.quadratic_fstar(d)
    print(f"federated quadratic: d={d}, N={n_clients}, C={c_het}, F* = {fstar:+.4f}\n")

    for name in ("fzoos", "fedzo"):
        cfg = alg.AlgoConfig(
            name=name, dim=d, n_clients=n_clients, local_steps=10, eta=0.005,
            q=20, fd_lambda=5e-3, n_features=256, traj_capacity=128,
            active_per_iter=5, active_candidates=50, active_round_end=5,
            lengthscale=0.5, noise=1e-5,
        )
        res = alg.simulate(cfg, jax.random.PRNGKey(1), cobjs,
                           obj.quadratic_query, obj.quadratic_global_value, rounds=15)
        print(f"== {name} ==   (uplink {cfg.comm_floats_per_round()} floats/round)")
        for r in range(0, 16, 3):
            q = int(res.queries[r - 1]) if r else 0
            print(f"  round {r:3d}   F = {float(res.f_values[r]):+.5f}   queries/client = {q}")
        print(f"  best F = {float(jnp.min(res.f_values)):+.5f}\n")


if __name__ == "__main__":
    main()
