"""Block-size autotuner for the tiled GP kernels (DESIGN.md Sec. 4).

``select_blocks(kind, ...)`` picks ``(block_n, block_cap)`` for the
cap-tiled scoring / grad-mean kernels from a VMEM-footprint +
arithmetic-intensity model keyed on the per-backend roofline constants in
``repro.launch.mesh.BACKEND_ROOFLINE`` (the same table
``benchmarks/roofline.py`` reports against).  The choice is a pure function
of ``(backend, kind, n_clients, n, cap, d)`` -- deterministic and therefore
reproducible -- and is memoized in a process-level cache under exactly that
key.  Callers that need a specific tiling (tests, `AlgoConfig` overrides)
bypass the tuner by passing explicit block sizes to the ops wrappers.

The model is intentionally small:

* **feasibility** -- the per-grid-cell VMEM working set (input tiles,
  intermediate (bn, bc) tiles, accumulators, x2 for double buffering) must
  fit the backend's ``vmem_bytes`` budget;
* **cost** -- per-cell ``max(flops/peak, hbm_bytes/bw)`` summed over the
  padded grid, so oversized blocks pay their padding waste and undersized
  ones pay the re-streamed (bc, bc) Gram tiles and recomputed h tiles.

For backends missing from the table the ``_default`` entry keeps the choice
deterministic; ``measure_blocks`` is the measured-sweep fallback that times
real kernel calls over the feasible candidate grid and caches the argmin
under the same key (an explicit API: it blocks on device results, so it
cannot run under a jit trace the way ``select_blocks`` can).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro.launch.mesh import BACKEND_ROOFLINE

#: f32 tile alignment of the TPU vector unit: (sublane, lane).
_SUBLANE = 8
_LANE = 128

#: Candidate grids.  block_cap candidates are lane-aligned (the cap axis is
#: the minor axis of the (bn, bc) h tiles and both axes of the Gram tiles);
#: block_n candidates are sublane-aligned.
_BLOCK_N_CANDIDATES = (8, 16, 32, 64, 128, 256)
_BLOCK_CAP_CANDIDATES = (128, 256, 512, 1024)

_CACHE: dict[tuple, tuple[int, int]] = {}


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _dtype_name(dtype: Any) -> str:
    """Canonical dtype tag for the cache key / footprint model (f32 default).

    The VMEM-footprint model used to assume f32 implicitly, so a bf16
    caller would silently reuse f32 block picks under the same key; the
    dtype is now an explicit key component and feeds the per-word byte
    width of the model.
    """
    return np.dtype(jax.dtypes.canonicalize_dtype(dtype or np.float32)).name


def cache_key(kind: str, backend: str, n_clients: int, n: int, cap: int,
              d: int, dtype: Any = None):
    return (backend, kind, n_clients, n, cap, d, _dtype_name(dtype))


def clear_cache() -> None:
    _CACHE.clear()


def _vmem_cell_bytes(kind: str, bn: int, bc: int, d: int,
                     itemsize: int = 4) -> int:
    """Per-grid-cell VMEM working set, x2 for double buffering.

    score: c tile + two x tiles + two (bc, bc) Gram tiles + the h / cross /
    g1 / g2 (bn, bc) intermediates + the (bn, 1) accumulator.
    grad:  c tile + x tile + alpha row + the (bn, bc) w tile + the (bn, d)
    accumulator + the (bn, 1) running sum.
    ``itemsize`` is the element byte width of the caller's dtype (4 = the
    historical f32 assumption; the f32 accumulator scratch is charged at
    the same width, a deliberate over-estimate that keeps bf16 feasible
    sets conservative).
    """
    dl = _round_up(d, _LANE)  # minor axes are lane-padded by the compiler
    if kind == "score":
        words = bn * dl + 2 * bc * dl + 2 * bc * bc + 5 * bn * bc + 2 * bn
    elif kind == "grad":
        words = bn * dl + bc * dl + bc + 3 * bn * bc + bn * dl + 2 * bn
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")
    return 2 * itemsize * words


def _cell_cost(kind: str, bn: int, bc: int, d: int, hw: dict,
               itemsize: int = 4) -> float:
    """max(compute, memory) seconds for ONE grid cell."""
    if kind == "score":
        flops = 2 * 2 * bn * bc * d + 2 * 2 * bn * bc * bc + 8 * bn * bc
        bytes_ = itemsize * (bn * d + 2 * bc * d + 2 * bc * bc + bn)
    else:
        flops = 2 * 2 * bn * bc * d + 6 * bn * bc
        bytes_ = itemsize * (bn * d + bc * d + bc + bn * d)
    return max(flops / hw["peak_flops"], bytes_ / hw["hbm_bw"])


def _grid_cells(kind: str, bn: int, bc: int, n: int, cap: int, n_clients: int) -> int:
    caps = _round_up(cap, bc) // bc
    rows = _round_up(n, bn) // bn
    per_client = rows * caps * caps if kind == "score" else rows * caps
    return n_clients * per_client


def _feasible(kind: str, n: int, cap: int, d: int, hw: dict,
              itemsize: int = 4):
    budget = 0.75 * hw["vmem_bytes"]
    for bn in _BLOCK_N_CANDIDATES:
        if bn > _round_up(max(n, 1), _SUBLANE):
            continue  # pure padding beyond the candidate count
        for bc in _BLOCK_CAP_CANDIDATES:
            if bc > _round_up(max(cap, 1), _LANE):
                continue
            if _vmem_cell_bytes(kind, bn, bc, d, itemsize) <= budget:
                yield bn, bc


def validate_blocks(
    kind: str,
    *,
    block_n: int,
    block_cap: int,
    cap: int,
    d: int,
    backend: Optional[str] = None,
    dtype: Any = None,
) -> tuple[int, int]:
    """Validate a USER-PINNED ``(block_n, block_cap)`` pair against the
    backend VMEM budget -- the same footprint model and 0.75 budget the
    tuner's feasibility filter uses -- and raise a loud ``ValueError``
    naming the block and the budget when it cannot fit.  Tuner-chosen
    blocks are feasible by construction; explicit ``AlgoConfig`` pins are
    not, and an infeasible pin would otherwise surface as an opaque
    Mosaic/XLA allocation failure deep inside the round body.
    """
    backend = backend or jax.default_backend()
    hw = BACKEND_ROOFLINE.get(backend, BACKEND_ROOFLINE["_default"])
    budget = int(0.75 * hw["vmem_bytes"])
    itemsize = np.dtype(_dtype_name(dtype)).itemsize
    # block_cap >= cap routes to the VMEM-resident kernel: the working set
    # is the lane-padded cap, not the nominal (possibly huge) pin.
    bc_eff = min(block_cap, _round_up(max(cap, 1), _LANE))
    need = _vmem_cell_bytes(kind, block_n, bc_eff, d, itemsize)
    if need > budget:
        raise ValueError(
            f"pinned {kind} blocks (block_n={block_n}, block_cap={block_cap})"
            f" need {need} bytes of VMEM per grid cell at d={d} "
            f"({_dtype_name(dtype)}), exceeding the {backend!r} budget of "
            f"{budget} bytes (0.75 x vmem_bytes={hw['vmem_bytes']}); pick "
            "smaller AlgoConfig block pins or leave them unset for the "
            "autotuner"
        )
    return block_n, block_cap


def select_blocks(
    kind: str,
    *,
    n: int,
    cap: int,
    d: int,
    n_clients: int = 1,
    backend: Optional[str] = None,
    dtype: Any = None,
) -> tuple[int, int]:
    """Deterministic ``(block_n, block_cap)`` for a kernel ``kind``/shape.

    ``kind`` is ``"score"`` (uncertainty scoring) or ``"grad"`` (grad mean);
    ``n`` is the per-client candidate count, ``cap`` the trajectory ring
    capacity, ``d`` the search dimension, ``n_clients`` the client batch.
    ``dtype`` is the element dtype of the kernel operands (default f32 --
    bitwise-identical picks to the pre-dtype model for every f32 caller);
    narrower dtypes widen the feasible set and shift the roofline balance,
    and are cached under their own key.
    """
    backend = backend or jax.default_backend()
    key = cache_key(kind, backend, n_clients, n, cap, d, dtype)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    itemsize = np.dtype(_dtype_name(dtype)).itemsize
    hw = BACKEND_ROOFLINE.get(backend, BACKEND_ROOFLINE["_default"])
    best: Optional[tuple[float, tuple[int, int]]] = None
    for bn, bc in _feasible(kind, n, cap, d, hw, itemsize):
        cost = _cell_cost(kind, bn, bc, d, hw, itemsize) * _grid_cells(kind, bn, bc, n, cap, n_clients)
        # Deterministic tie-break: prefer LARGER tiles at equal modeled cost
        # (fewer grid cells, less accumulator traffic the model can't see).
        cand = (cost, (bn, bc))
        if best is None or cost < best[0] or (cost == best[0] and cand[1] > best[1]):
            best = cand
    if best is None:  # nothing fits (tiny VMEM budget): minimum legal tile
        best = (0.0, (_SUBLANE, _LANE))
    _CACHE[key] = best[1]
    return best[1]


def measure_blocks(
    kind: str,
    run_fn: Callable[[int, int], jax.Array],
    *,
    n: int,
    cap: int,
    d: int,
    n_clients: int = 1,
    backend: Optional[str] = None,
    dtype: Any = None,
    candidates: Optional[Iterable[tuple[int, int]]] = None,
    reps: int = 3,
) -> tuple[int, int]:
    """Measured-sweep fallback: time ``run_fn(block_n, block_cap)`` over the
    feasible candidate grid, cache the winner under the model's key, and
    return it.  Subsequent ``select_blocks`` calls for the same key return
    the measured choice.  Explicit API only -- it calls
    ``block_until_ready`` and so cannot run under a jit trace.
    """
    backend = backend or jax.default_backend()
    hw = BACKEND_ROOFLINE.get(backend, BACKEND_ROOFLINE["_default"])
    itemsize = np.dtype(_dtype_name(dtype)).itemsize
    cands = list(candidates) if candidates is not None else list(
        _feasible(kind, n, cap, d, hw, itemsize)
    )
    if not cands:
        cands = [(_SUBLANE, _LANE)]
    best: Optional[tuple[float, tuple[int, int]]] = None
    for bn, bc in cands:
        run_fn(bn, bc).block_until_ready()  # compile outside the timing
        dt = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            run_fn(bn, bc).block_until_ready()
            dt = min(dt, time.perf_counter() - t0)
        if best is None or dt < best[0]:
            best = (dt, (bn, bc))
    _CACHE[cache_key(kind, backend, n_clients, n, cap, d, dtype)] = best[1]
    return best[1]
