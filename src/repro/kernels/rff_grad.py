"""Pallas TPU kernel: fused RFF gradient-surrogate contraction

    G = grad phi(X)^T w = -sqrt(2/M) * ( sin(X V^T + b) * w ) @ V     (n, d)

This is the inner loop of FZooS eq. (8): evaluated TWICE per local step per
client (global and local surrogate) at the current iterate.  Done naively it
materializes the (n, M) sine matrix in HBM; the fused kernel keeps each
(bn, bm) sine tile in VMEM and accumulates the (bn, d) output across the M
grid axis, so HBM traffic is O(n*d + M*d) instead of O(n*M).

Tiling: grid (n/bn, M/bm) with the second axis the reduction ("arbitrary"
semantics).  Two MXU matmuls per program: (bn x d x bm) for the projection
and (bn x bm x d) for the back-contraction, cos/sin on the VPU in between.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spec import ArraySpec, BlockDecl, KernelSpec


def _kernel(x_ref, v_ref, b_ref, w_ref, o_ref, *, scale: float):
    j = pl.program_id(1)
    x = x_ref[...]  # (bn, d)
    v = v_ref[...]  # (bm, d)
    b = b_ref[...]  # (1, bm)
    w = w_ref[...]  # (1, bm)
    proj = jax.lax.dot_general(
        x, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, bm)
    s = jnp.sin(proj + b) * w  # (bn, bm)
    contrib = -scale * jax.lax.dot_general(
        s, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, d)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = contrib.astype(o_ref.dtype)

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = (o_ref[...] + contrib).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret", "n_features"))
def rff_grad_kernel(
    x: jax.Array,
    v: jax.Array,
    b: jax.Array,
    w: jax.Array,
    *,
    n_features: int,
    block_n: int = 128,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x (n,d), v (M,d), b (M,), w (M,) -> (n,d).  Block-aligned inputs;
    padded M slots must carry w == 0 and v == 0 (then they contribute 0).
    """
    n, d = x.shape
    m = v.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    b2 = b.reshape(1, m)
    w2 = w.reshape(1, m)
    scale = math.sqrt(2.0 / n_features)
    spec = grad_spec(n, m, d, x.dtype, block_n=block_n, block_m=block_m)
    return spec.pallas_call(
        functools.partial(_kernel, scale=scale), interpret=interpret
    )(x, v, b2, w2)


def grad_spec(n: int, m: int, d: int, dtype, *, block_n: int,
              block_m: int) -> KernelSpec:
    """Launch geometry of the RFF gradient-contraction kernel.  The M grid
    axis is the reduction: each (block_n, d) output block is revisited
    across it and the kernel accumulates IN the output ref (init write at
    j == 0), so the output itself is the accumulator
    (``out_accumulates``)."""
    return KernelSpec(
        name="rff_grad",
        grid=(n // block_n, m // block_m),
        in_shapes=(
            ArraySpec((n, d), dtype),
            ArraySpec((m, d), dtype),
            ArraySpec((1, m), dtype),
            ArraySpec((1, m), dtype),
        ),
        in_specs=(
            BlockDecl((block_n, d), lambda i, j: (i, 0)),
            BlockDecl((block_m, d), lambda i, j: (j, 0)),
            BlockDecl((1, block_m), lambda i, j: (0, j)),
            BlockDecl((1, block_m), lambda i, j: (0, j)),
        ),
        out_shapes=(ArraySpec((n, d), dtype),),
        out_specs=(BlockDecl((block_n, d), lambda i, j: (i, 0)),),
        revisit_axes=(1,),
        init_axes=(1,),
        out_accumulates=True,
    )
