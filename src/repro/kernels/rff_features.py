"""Pallas TPU kernel: fused RFF featurization  phi(X) = sqrt(2/M) cos(X V^T + b).

The paper evaluates phi over the whole trajectory every round on every client
(M up to 10^4 features, d up to ~2.2k in the Covertype experiment), which is a
matmul immediately followed by a transcendental -- exactly the fusion XLA will
not always give us and the MXU+VPU pipeline handles well when tiled for VMEM.

Tiling: grid (n/bn, M/bm).  Each program loads an (bn, d) slab of X and a
(bm, d) slab of V (d kept whole -- the contraction dim must be resident),
issues one MXU matmul (bn x d x bm), adds the phase slab and applies cos on
the VPU, writing an (bn, bm) output tile.  Block sizes default to 128 so the
matmul dims are MXU-aligned; VMEM footprint per program is
(bn*d + bm*d + bn*bm) * 4B  ~=  4.2 MB at d=4096, within the ~16 MB VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.spec import ArraySpec, BlockDecl, KernelSpec


def _kernel(x_ref, v_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[...]  # (bn, d)
    v = v_ref[...]  # (bm, d)
    b = b_ref[...]  # (1, bm)
    proj = jax.lax.dot_general(
        x, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, bm)
    o_ref[...] = (scale * jnp.cos(proj + b)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret", "n_features"))
def rff_features_kernel(
    x: jax.Array,
    v: jax.Array,
    b: jax.Array,
    *,
    n_features: int,
    block_n: int = 128,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x (n,d), v (M,d), b (M,) -> (n, M).  Shapes must be block-aligned
    (ops.py pads); ``n_features`` is the TRUE M for the sqrt(2/M) scale.
    """
    n, d = x.shape
    m = v.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    b2 = b.reshape(1, m)
    scale = math.sqrt(2.0 / n_features)
    spec = features_spec(n, m, d, x.dtype, block_n=block_n, block_m=block_m)
    return spec.pallas_call(
        functools.partial(_kernel, scale=scale), interpret=interpret
    )(x, v, b2)


def features_spec(n: int, m: int, d: int, dtype, *, block_n: int,
                  block_m: int) -> KernelSpec:
    """Launch geometry of the RFF featurization kernel: every grid cell
    writes its own (block_n, block_m) output tile exactly once."""
    return KernelSpec(
        name="rff_features",
        grid=(n // block_n, m // block_m),
        in_shapes=(
            ArraySpec((n, d), dtype),
            ArraySpec((m, d), dtype),
            ArraySpec((1, m), dtype),
        ),
        in_specs=(
            BlockDecl((block_n, d), lambda i, j: (i, 0)),
            BlockDecl((block_m, d), lambda i, j: (j, 0)),
            BlockDecl((1, block_m), lambda i, j: (0, j)),
        ),
        out_shapes=(ArraySpec((n, m), dtype),),
        out_specs=(BlockDecl((block_n, block_m), lambda i, j: (i, j)),),
    )
