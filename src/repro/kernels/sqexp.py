"""Pallas TPU kernel: squared-exponential Gram matrix

    K(X1, X2)_ij = exp( -||x1_i - x2_j||^2 / (2 l^2) )

Built every local iteration from the trajectory buffer (gp_surrogate eq. 5).
Fuses the pairwise-distance matmul with the exp so the distance matrix never
round-trips to HBM.  Tiling: grid (n/bn, m/bm), d resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spec import ArraySpec, BlockDecl, KernelSpec


def _kernel(x1_ref, x2_ref, o_ref, *, inv_two_l2: float):
    x1 = x1_ref[...]  # (bn, d)
    x2 = x2_ref[...]  # (bm, d)
    n1 = jnp.sum(x1 * x1, axis=-1, keepdims=True)  # (bn, 1)
    n2 = jnp.sum(x2 * x2, axis=-1, keepdims=True).T  # (1, bm)
    cross = jax.lax.dot_general(
        x1, x2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-d2 * inv_two_l2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lengthscale", "block_n", "block_m", "interpret"))
def sqexp_kernel(
    x1: jax.Array,
    x2: jax.Array,
    *,
    lengthscale: float,
    block_n: int = 128,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, d = x1.shape
    m = x2.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    spec = sqexp_spec(n, m, d, x1.dtype, block_n=block_n, block_m=block_m)
    return spec.pallas_call(
        functools.partial(_kernel, inv_two_l2=0.5 / (lengthscale**2)),
        interpret=interpret,
    )(x1, x2)


def sqexp_spec(n: int, m: int, d: int, dtype, *, block_n: int,
               block_m: int) -> KernelSpec:
    """Launch geometry of the SE Gram kernel: one writer per output tile."""
    return KernelSpec(
        name="sqexp",
        grid=(n // block_n, m // block_m),
        in_shapes=(
            ArraySpec((n, d), dtype),
            ArraySpec((m, d), dtype),
        ),
        in_specs=(
            BlockDecl((block_n, d), lambda i, j: (i, 0)),
            BlockDecl((block_m, d), lambda i, j: (j, 0)),
        ),
        out_shapes=(ArraySpec((n, m), dtype),),
        out_specs=(BlockDecl((block_n, block_m), lambda i, j: (i, j)),),
    )
