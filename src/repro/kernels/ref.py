"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose against these functions (interpret=True on CPU).
They are also the CPU execution path used by ops.py when no TPU is present.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def rff_features(x: jax.Array, v: jax.Array, b: jax.Array, n_features: int | None = None) -> jax.Array:
    """phi(X) = sqrt(2/M) cos(X V^T + b).   x (n,d), v (M,d), b (M,) -> (n,M)."""
    m = n_features if n_features is not None else v.shape[0]
    proj = x @ v.T + b[None, :]
    return (math.sqrt(2.0 / m) * jnp.cos(proj)).astype(x.dtype)


def rff_grad(x: jax.Array, v: jax.Array, b: jax.Array, w: jax.Array, n_features: int | None = None) -> jax.Array:
    """grad phi(X)^T w = -sqrt(2/M) (sin(X V^T + b) * w) V.

    x (n,d), v (M,d), b (M,), w (M,) -> (n,d).
    """
    m = n_features if n_features is not None else v.shape[0]
    s = jnp.sin(x @ v.T + b[None, :])  # (n, M)
    return (-math.sqrt(2.0 / m) * ((s * w[None, :]) @ v)).astype(x.dtype)


def sqexp(x1: jax.Array, x2: jax.Array, lengthscale: float) -> jax.Array:
    """K(X1, X2) = exp(-||x1-x2||^2 / (2 l^2)).  (n,d),(m,d) -> (n,m)."""
    n1 = jnp.sum(x1 * x1, axis=-1)
    n2 = jnp.sum(x2 * x2, axis=-1)
    d2 = jnp.maximum(n1[:, None] + n2[None, :] - 2.0 * (x1 @ x2.T), 0.0)
    return jnp.exp(-0.5 * d2 / (lengthscale**2)).astype(x1.dtype)
