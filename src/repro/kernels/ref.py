"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose against these functions (interpret=True on CPU).
They are also the CPU execution path used by ops.py when no TPU is present.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def rff_features(x: jax.Array, v: jax.Array, b: jax.Array, n_features: int | None = None) -> jax.Array:
    """phi(X) = sqrt(2/M) cos(X V^T + b).   x (n,d), v (M,d), b (M,) -> (n,M)."""
    m = n_features if n_features is not None else v.shape[0]
    proj = x @ v.T + b[None, :]
    return (math.sqrt(2.0 / m) * jnp.cos(proj)).astype(x.dtype)


def rff_grad(x: jax.Array, v: jax.Array, b: jax.Array, w: jax.Array, n_features: int | None = None) -> jax.Array:
    """grad phi(X)^T w = -sqrt(2/M) (sin(X V^T + b) * w) V.

    x (n,d), v (M,d), b (M,), w (M,) -> (n,d).
    """
    m = n_features if n_features is not None else v.shape[0]
    s = jnp.sin(x @ v.T + b[None, :])  # (n, M)
    return (-math.sqrt(2.0 / m) * ((s * w[None, :]) @ v)).astype(x.dtype)


def sqexp(x1: jax.Array, x2: jax.Array, lengthscale: float) -> jax.Array:
    """K(X1, X2) = exp(-||x1-x2||^2 / (2 l^2)).  (n,d),(m,d) -> (n,m)."""
    n1 = jnp.sum(x1 * x1, axis=-1)
    n2 = jnp.sum(x2 * x2, axis=-1)
    d2 = jnp.maximum(n1[:, None] + n2[None, :] - 2.0 * (x1 @ x2.T), 0.0)
    return jnp.exp(-0.5 * d2 / (lengthscale**2)).astype(x1.dtype)


def uncertainty_scores(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    lengthscale: float,
    prior: float,
) -> jax.Array:
    """Gradient-surrogate uncertainty scores for a candidate batch.

    For the SE kernel the data correction of tr d_sigma2(c) expands through
    the structure of J(c) = d_c k(c, X):

        corr(c) = (1/l^4) [ h^T (B o XX^T) h  -  2 (h o Xc)^T B h
                            + (c.c) h^T B h ],     h_t = k(c, x_t),

    where ``binv`` is the MASKED inverse M (K + s^2 I)^{-1} M and
    ``pmat = binv o (X X^T)`` is precomputed once per trajectory state.  The
    per-candidate cost is O(cap^2) -- one matvec against each cached matrix
    -- instead of the O(cap^2 d) triangular solves of the direct form.

    cands (n, d), xs (cap, d), binv/pmat (cap, cap) -> (n,).
    """
    n1 = jnp.sum(cands * cands, axis=-1)
    n2 = jnp.sum(xs * xs, axis=-1)
    cross = cands @ xs.T  # (n, cap) -- doubles as the c.x_t table
    d2 = jnp.maximum(n1[:, None] + n2[None, :] - 2.0 * cross, 0.0)
    h = jnp.exp(-0.5 * d2 / (lengthscale**2))
    g1 = h @ pmat
    g2 = h @ binv
    t1 = jnp.sum(g1 * h, axis=-1)
    t2 = jnp.sum(h * cross * g2, axis=-1)
    t3 = n1 * jnp.sum(h * g2, axis=-1)
    corr = (t1 - 2.0 * t2 + t3) / (lengthscale**4)
    return jnp.maximum(prior - corr, 0.0).astype(cands.dtype)


def uncertainty_scores_clients(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    lengthscale: float,
    prior: float,
) -> jax.Array:
    """Client-batched ``uncertainty_scores``: one batched contraction pass.

    cands (N, n, d), xs (N, cap, d), binv/pmat (N, cap, cap) -> (N, n).
    Per-client math identical to the unbatched oracle (property-tested);
    mirrors the client grid dimension of the batched Pallas kernel.
    """
    n1 = jnp.sum(cands * cands, axis=-1)  # (N, n)
    n2 = jnp.sum(xs * xs, axis=-1)  # (N, cap)
    cross = jnp.einsum("bnd,bcd->bnc", cands, xs)  # doubles as the c.x_t table
    d2 = jnp.maximum(n1[..., None] + n2[:, None, :] - 2.0 * cross, 0.0)
    h = jnp.exp(-0.5 * d2 / (lengthscale**2))
    g1 = jnp.einsum("bnc,bck->bnk", h, pmat)
    g2 = jnp.einsum("bnc,bck->bnk", h, binv)
    t1 = jnp.sum(g1 * h, axis=-1)
    t2 = jnp.sum(h * cross * g2, axis=-1)
    t3 = n1 * jnp.sum(h * g2, axis=-1)
    corr = (t1 - 2.0 * t2 + t3) / (lengthscale**4)
    return jnp.maximum(prior - corr, 0.0).astype(cands.dtype)


def uncertainty_scores_clients_fused(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    lengthscale: float,
    prior: float,
) -> jax.Array:
    """Fused-epilogue ``uncertainty_scores_clients``: the CPU execution path.

    Identical math through the identity

        t1 - 2 t2 + t3 = sum_k [ g1 - (2 cross - c.c) o g2 ]_k h_k,

    which XLA fuses into one elementwise pass + one reduction over the
    (N, n, cap) intermediates instead of the textbook form's three -- the
    measured batched-over-vmapped scoring win on CPU (BENCH_kernels.json,
    ``client_batched``).  The per-element cancellation before the reduction
    is also the numerically kinder order.  The textbook
    ``uncertainty_scores_clients`` above stays as the ground-truth oracle
    the tests compare against; the Pallas tile kernels use this same
    epilogue (kernels/gp_score.py).
    """
    n1 = jnp.sum(cands * cands, axis=-1)  # (N, n)
    n2 = jnp.sum(xs * xs, axis=-1)  # (N, cap)
    cross = jnp.einsum("bnd,bcd->bnc", cands, xs)
    d2 = jnp.maximum(n1[..., None] + n2[:, None, :] - 2.0 * cross, 0.0)
    h = jnp.exp(-0.5 * d2 / (lengthscale**2))
    g1 = jnp.einsum("bnc,bck->bnk", h, pmat)
    g2 = jnp.einsum("bnc,bck->bnk", h, binv)
    m = g1 - (2.0 * cross - n1[..., None]) * g2
    corr = jnp.sum(m * h, axis=-1) / (lengthscale**4)
    return jnp.maximum(prior - corr, 0.0).astype(cands.dtype)


def grad_mean_clients(
    cands: jax.Array, xs: jax.Array, alpha: jax.Array, lengthscale: float
) -> jax.Array:
    """Client-batched ``grad_mean_batch``.

    cands (N, n, d), xs (N, cap, d), alpha (N, cap) -> (N, n, d).
    """
    n1 = jnp.sum(cands * cands, axis=-1)
    n2 = jnp.sum(xs * xs, axis=-1)
    cross = jnp.einsum("bnd,bcd->bnc", cands, xs)
    d2 = jnp.maximum(n1[..., None] + n2[:, None, :] - 2.0 * cross, 0.0)
    h = jnp.exp(-0.5 * d2 / (lengthscale**2))
    w = h * alpha[:, None, :]
    out = jnp.einsum("bnc,bcd->bnd", w, xs) - jnp.sum(w, axis=-1, keepdims=True) * cands
    return (out / (lengthscale**2)).astype(cands.dtype)


def grad_mean_batch(
    cands: jax.Array, xs: jax.Array, alpha: jax.Array, lengthscale: float
) -> jax.Array:
    """Batched posterior gradient mean  J(c)^T alpha  (eq. 5).

    grad_mu(c) = (1/l^2) [ (h o alpha) @ X  -  (h . alpha) c ],
    h_t = k(c, x_t).  ``alpha`` must already carry the validity mask (solves
    of masked targets leave invalid slots exactly zero).

    cands (n, d), xs (cap, d), alpha (cap,) -> (n, d).
    """
    n1 = jnp.sum(cands * cands, axis=-1)
    n2 = jnp.sum(xs * xs, axis=-1)
    cross = cands @ xs.T
    d2 = jnp.maximum(n1[:, None] + n2[None, :] - 2.0 * cross, 0.0)
    h = jnp.exp(-0.5 * d2 / (lengthscale**2))
    w = h * alpha[None, :]
    out = (w @ xs - jnp.sum(w, axis=-1, keepdims=True) * cands) / (lengthscale**2)
    return out.astype(cands.dtype)
