"""Pallas TPU kernel: fused batched derived-GP gradient mean (eq. 5).

For a block of query points C the posterior gradient mean under the SE
kernel is

    grad_mu(c) = (1/l^2) [ (h o alpha) @ X - (h . alpha) c ],   h_t = k(c, x_t)

where alpha = (K + s^2 I)^{-1} y comes from the cached Gram factor
(core/gp_surrogate ``GramFactor``) with the validity mask already folded in
(masked solves leave invalid slots exactly zero).  The kernel fuses the
kernel-vector generation with both contractions, so neither the (bn, cap)
h-tile nor the explicit (cap, d) dkdx Jacobian ever materializes in HBM --
the seed path built J per query point.

Grid: (n / block_n,); xs and alpha stay resident across programs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, x_ref, a_ref, o_ref, *, inv_two_l2: float, inv_l2: float):
    c = c_ref[...]  # (bn, d)
    x = x_ref[...]  # (cap, d)
    n1 = jnp.sum(c * c, axis=-1, keepdims=True)  # (bn, 1)
    n2 = jnp.sum(x * x, axis=-1, keepdims=True).T  # (1, cap)
    cross = jax.lax.dot_general(
        c, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)
    w = jnp.exp(-d2 * inv_two_l2) * a_ref[...]  # (bn, cap), alpha row-broadcast
    acc = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, d)
    s = jnp.sum(w, axis=-1, keepdims=True)
    o_ref[...] = ((acc - s * c) * inv_l2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lengthscale", "block_n", "interpret"))
def grad_mean_kernel(
    cands: jax.Array,
    xs: jax.Array,
    alpha: jax.Array,  # (1, cap) -- row vector for TPU-friendly layout
    *,
    lengthscale: float,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, d = cands.shape
    cap = xs.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert alpha.shape == (1, cap), alpha.shape
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(
            _kernel, inv_two_l2=0.5 / (lengthscale**2), inv_l2=1.0 / (lengthscale**2)
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), cands.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((cap, d), lambda i: (0, 0)),
            pl.BlockSpec((1, cap), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        interpret=interpret,
    )(cands, xs, alpha)
