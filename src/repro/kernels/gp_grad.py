"""Pallas TPU kernel: fused batched derived-GP gradient mean (eq. 5).

For a block of query points C the posterior gradient mean under the SE
kernel is

    grad_mu(c) = (1/l^2) [ (h o alpha) @ X - (h . alpha) c ],   h_t = k(c, x_t)

where alpha = (K + s^2 I)^{-1} y comes from the cached Gram factor
(core/gp_surrogate ``GramFactor``) with the validity mask already folded in
(masked solves leave invalid slots exactly zero).  The kernel fuses the
kernel-vector generation with both contractions, so neither the (bn, cap)
h-tile nor the explicit (cap, d) dkdx Jacobian ever materializes in HBM --
the seed path built J per query point.

Two kernel families share the tile numerics:

* **resident** (``grad_mean_kernel``): grid (n / block_n,); xs and alpha
  stay fully VMEM-resident across programs.
* **cap-tiled** (``grad_mean_tiled_kernel``): grid
  (n/block_n, cap/block_cap) -- the trailing grid dimension streams
  (block_cap, d) trajectory tiles while a (block_n, d) f32 VMEM scratch
  holds the running ``(h o alpha) @ X`` accumulator and a (block_n, 1)
  scratch the running ``h . alpha``, so VMEM residency is independent of
  cap.  Padded trajectory slots carry alpha == 0 and contribute exactly
  zero (w = h o alpha vanishes there).  The finalize step applies
  ``(acc - s o c) / l^2`` at the last cap tile.

``*_clients_kernel`` variants add a CLIENT grid dimension for the batched
federated engine: one launch computes the gradient mean for the whole
client batch instead of N vmapped launches.

Every launch is constructed from a declarative ``KernelSpec``
(``grad_*_spec`` builders below): the spec both builds the real
``pl.pallas_call`` and feeds the static auditor in
``repro.analysis.kernel_audit`` (DESIGN.md Sec. 7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spec import ArraySpec, BlockDecl, KernelSpec, ScratchDecl


def _grad_block(c, x, alpha, *, inv_two_l2: float, inv_l2: float):
    """Shared VMEM-tile numerics of both kernels.  c (bn, d), x (cap, d),
    alpha (1, cap) -> (bn, d)."""
    n1 = jnp.sum(c * c, axis=-1, keepdims=True)  # (bn, 1)
    n2 = jnp.sum(x * x, axis=-1, keepdims=True).T  # (1, cap)
    cross = jax.lax.dot_general(
        c, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)
    w = jnp.exp(-d2 * inv_two_l2) * alpha  # (bn, cap), alpha row-broadcast
    acc = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, d)
    s = jnp.sum(w, axis=-1, keepdims=True)
    return (acc - s * c) * inv_l2


def _kernel(c_ref, x_ref, a_ref, o_ref, **kw):
    o_ref[...] = _grad_block(c_ref[...], x_ref[...], a_ref[...], **kw).astype(o_ref.dtype)


def grad_resident_spec(n: int, cap: int, d: int, dtype, *,
                       block_n: int) -> KernelSpec:
    """Launch geometry of the VMEM-resident gradient-mean kernel."""
    return KernelSpec(
        name="gp_grad.resident",
        grid=(n // block_n,),
        in_shapes=(
            ArraySpec((n, d), dtype),
            ArraySpec((cap, d), dtype),
            ArraySpec((1, cap), dtype),
        ),
        in_specs=(
            BlockDecl((block_n, d), lambda i: (i, 0)),
            BlockDecl((cap, d), lambda i: (0, 0)),
            BlockDecl((1, cap), lambda i: (0, 0)),
        ),
        out_shapes=(ArraySpec((n, d), dtype),),
        out_specs=(BlockDecl((block_n, d), lambda i: (i, 0)),),
    )


@functools.partial(jax.jit, static_argnames=("lengthscale", "block_n", "interpret"))
def grad_mean_kernel(
    cands: jax.Array,
    xs: jax.Array,
    alpha: jax.Array,  # (1, cap) -- row vector for TPU-friendly layout
    *,
    lengthscale: float,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, d = cands.shape
    cap = xs.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert alpha.shape == (1, cap), alpha.shape
    spec = grad_resident_spec(n, cap, d, cands.dtype, block_n=block_n)
    return spec.pallas_call(
        functools.partial(
            _kernel, inv_two_l2=0.5 / (lengthscale**2), inv_l2=1.0 / (lengthscale**2)
        ),
        interpret=interpret,
    )(cands, xs, alpha)


def _kernel_clients(c_ref, x_ref, a_ref, o_ref, **kw):
    # Leading block dim of every ref is the (size-1) client slot; the tile
    # numerics are shared with the unbatched kernel (_grad_block).
    o_ref[0] = _grad_block(c_ref[0], x_ref[0], a_ref[0], **kw).astype(o_ref.dtype)


def grad_clients_spec(nb: int, n: int, cap: int, d: int, dtype, *,
                      block_n: int) -> KernelSpec:
    """Launch geometry of the client-batched resident gradient-mean kernel."""
    return KernelSpec(
        name="gp_grad.clients",
        grid=(nb, n // block_n),
        in_shapes=(
            ArraySpec((nb, n, d), dtype),
            ArraySpec((nb, cap, d), dtype),
            ArraySpec((nb, 1, cap), dtype),
        ),
        in_specs=(
            BlockDecl((1, block_n, d), lambda b, i: (b, i, 0)),
            BlockDecl((1, cap, d), lambda b, i: (b, 0, 0)),
            BlockDecl((1, 1, cap), lambda b, i: (b, 0, 0)),
        ),
        out_shapes=(ArraySpec((nb, n, d), dtype),),
        out_specs=(BlockDecl((1, block_n, d), lambda b, i: (b, i, 0)),),
    )


@functools.partial(jax.jit, static_argnames=("lengthscale", "block_n", "interpret"))
def grad_mean_clients_kernel(
    cands: jax.Array,  # (N, n, d)
    xs: jax.Array,  # (N, cap, d)
    alpha: jax.Array,  # (N, 1, cap) -- row vectors for TPU-friendly layout
    *,
    lengthscale: float,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Client-batched gradient mean: grid (N, n/block_n) -> (N, n, d)."""
    nb, n, d = cands.shape
    cap = xs.shape[1]
    assert n % block_n == 0, (n, block_n)
    assert xs.shape == (nb, cap, d), (xs.shape, cands.shape)
    assert alpha.shape == (nb, 1, cap), alpha.shape
    spec = grad_clients_spec(nb, n, cap, d, cands.dtype, block_n=block_n)
    return spec.pallas_call(
        functools.partial(
            _kernel_clients, inv_two_l2=0.5 / (lengthscale**2), inv_l2=1.0 / (lengthscale**2)
        ),
        interpret=interpret,
    )(cands, xs, alpha)


# ---------------------------------------------------------------------------
# Cap-tiled kernels: the (cap, d) trajectory / (cap,) alpha stream through
# VMEM one (block_cap, d) tile at a time with a running (bn, d) accumulator.
# ---------------------------------------------------------------------------


def _grad_cell(c, x, alpha, acc_ref, s_ref, *, inv_two_l2: float):
    """Accumulate one cap tile:  acc += (h o alpha) @ x,  s += (h . alpha).

    c (bn, d), x (bc, d), alpha (1, bc).  Padded trajectory slots arrive
    with alpha == 0, so w vanishes there exactly.  Accumulation is f32.
    """
    n1 = jnp.sum(c * c, axis=-1, keepdims=True)  # (bn, 1)
    n2 = jnp.sum(x * x, axis=-1, keepdims=True).T  # (1, bc)
    cross = jax.lax.dot_general(
        c, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)
    w = jnp.exp(-d2 * inv_two_l2) * alpha  # (bn, bc)
    acc_ref[...] += jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(jnp.float32)
    s_ref[...] += jnp.sum(w, axis=-1, keepdims=True).astype(jnp.float32)


def _kernel_tiled(c_ref, x_ref, a_ref, o_ref, acc_ref, s_ref, *,
                  inv_two_l2: float, inv_l2: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

    _grad_cell(c_ref[...], x_ref[...], a_ref[...], acc_ref, s_ref,
               inv_two_l2=inv_two_l2)

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        o_ref[...] = (
            (acc_ref[...] - s_ref[...] * c_ref[...]) * inv_l2
        ).astype(o_ref.dtype)


def grad_tiled_spec(n: int, cap: int, d: int, dtype, *, block_n: int,
                    block_cap: int) -> KernelSpec:
    """Launch geometry of the cap-tiled gradient-mean kernel.  The trailing
    grid axis revisits each (block_n, d) output block while two f32
    scratch buffers hold the running contraction and weight sum."""
    return KernelSpec(
        name="gp_grad.tiled",
        grid=(n // block_n, cap // block_cap),
        in_shapes=(
            ArraySpec((n, d), dtype),
            ArraySpec((cap, d), dtype),
            ArraySpec((1, cap), dtype),
        ),
        in_specs=(
            BlockDecl((block_n, d), lambda i, j: (i, 0)),
            BlockDecl((block_cap, d), lambda i, j: (j, 0)),
            BlockDecl((1, block_cap), lambda i, j: (0, j)),
        ),
        out_shapes=(ArraySpec((n, d), dtype),),
        out_specs=(BlockDecl((block_n, d), lambda i, j: (i, 0)),),
        scratch=(
            ScratchDecl((block_n, d), jnp.float32),
            ScratchDecl((block_n, 1), jnp.float32),
        ),
        revisit_axes=(1,),
        init_axes=(1,),
    )


@functools.partial(
    jax.jit, static_argnames=("lengthscale", "block_n", "block_cap", "interpret")
)
def grad_mean_tiled_kernel(
    cands: jax.Array,
    xs: jax.Array,
    alpha: jax.Array,  # (1, cap)
    *,
    lengthscale: float,
    block_n: int = 128,
    block_cap: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Cap-tiled gradient mean: grid (n/block_n, cap/block_cap)."""
    n, d = cands.shape
    cap = xs.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert cap % block_cap == 0, (cap, block_cap)
    assert alpha.shape == (1, cap), alpha.shape
    spec = grad_tiled_spec(n, cap, d, cands.dtype,
                           block_n=block_n, block_cap=block_cap)
    return spec.pallas_call(
        functools.partial(
            _kernel_tiled, inv_two_l2=0.5 / (lengthscale**2), inv_l2=1.0 / (lengthscale**2)
        ),
        interpret=interpret,
    )(cands, xs, alpha)


def _kernel_tiled_clients(c_ref, x_ref, a_ref, o_ref, acc_ref, s_ref, *,
                          inv_two_l2: float, inv_l2: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

    _grad_cell(c_ref[0], x_ref[0], a_ref[0], acc_ref, s_ref,
               inv_two_l2=inv_two_l2)

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = (
            (acc_ref[...] - s_ref[...] * c_ref[0]) * inv_l2
        ).astype(o_ref.dtype)


def grad_tiled_clients_spec(nb: int, n: int, cap: int, d: int, dtype, *,
                            block_n: int, block_cap: int) -> KernelSpec:
    """Launch geometry of the client-batched cap-tiled gradient-mean kernel."""
    return KernelSpec(
        name="gp_grad.tiled_clients",
        grid=(nb, n // block_n, cap // block_cap),
        in_shapes=(
            ArraySpec((nb, n, d), dtype),
            ArraySpec((nb, cap, d), dtype),
            ArraySpec((nb, 1, cap), dtype),
        ),
        in_specs=(
            BlockDecl((1, block_n, d), lambda b, i, j: (b, i, 0)),
            BlockDecl((1, block_cap, d), lambda b, i, j: (b, j, 0)),
            BlockDecl((1, 1, block_cap), lambda b, i, j: (b, 0, j)),
        ),
        out_shapes=(ArraySpec((nb, n, d), dtype),),
        out_specs=(BlockDecl((1, block_n, d), lambda b, i, j: (b, i, 0)),),
        scratch=(
            ScratchDecl((block_n, d), jnp.float32),
            ScratchDecl((block_n, 1), jnp.float32),
        ),
        revisit_axes=(2,),
        init_axes=(2,),
    )


@functools.partial(
    jax.jit, static_argnames=("lengthscale", "block_n", "block_cap", "interpret")
)
def grad_mean_tiled_clients_kernel(
    cands: jax.Array,  # (N, n, d)
    xs: jax.Array,  # (N, cap, d)
    alpha: jax.Array,  # (N, 1, cap)
    *,
    lengthscale: float,
    block_n: int = 128,
    block_cap: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Client-batched cap-tiled gradient mean:
    grid (N, n/block_n, cap/block_cap) -> (N, n, d)."""
    nb, n, d = cands.shape
    cap = xs.shape[1]
    assert n % block_n == 0, (n, block_n)
    assert cap % block_cap == 0, (cap, block_cap)
    assert xs.shape == (nb, cap, d), (xs.shape, cands.shape)
    assert alpha.shape == (nb, 1, cap), alpha.shape
    spec = grad_tiled_clients_spec(nb, n, cap, d, cands.dtype,
                                   block_n=block_n, block_cap=block_cap)
    return spec.pallas_call(
        functools.partial(
            _kernel_tiled_clients,
            inv_two_l2=0.5 / (lengthscale**2),
            inv_l2=1.0 / (lengthscale**2),
        ),
        interpret=interpret,
    )(cands, xs, alpha)
