"""Declarative Pallas launch geometry: ``KernelSpec`` (DESIGN.md Sec. 4/7).

Every ``pallas_call`` in ``repro.kernels`` is constructed from a
``KernelSpec`` -- a declarative record of the launch geometry (grid, block
shapes, index maps, scratch accumulators, revisit semantics) that serves
two masters:

* ``spec.pallas_call(kernel)`` builds the REAL ``pl.pallas_call`` from the
  declaration, so the geometry the static linter sees is, by construction,
  the geometry the kernel launches with -- there is no parallel
  bookkeeping to drift out of sync;
* ``repro.analysis.kernel_audit`` enumerates the grid through the declared
  index maps and statically proves write-race freedom, accumulator
  init/dtype discipline, in-bounds addressing and VMEM-budget fit without
  executing (or even lowering) anything.

The ``revisit_axes`` / ``init_axes`` fields make the accumulator protocol
of the tiled kernels explicit:

* ``revisit_axes`` are the grid axes over which an output block is visited
  more than once (the reduction axes of a tiled accumulator kernel; TPU
  grids execute sequentially, so revisits of trailing axes are
  consecutive);
* ``init_axes`` are the grid axes whose ``program_id == 0`` conjunction
  guards the accumulator initialization (the ``pl.when`` zero/overwrite at
  the start of each reduction sweep).

A well-formed accumulator kernel has ``init_axes == revisit_axes`` -- a
strict subset means the accumulator is either stale across output blocks
or clobbered mid-sweep.  ``out_accumulates`` marks kernels (rff_grad) that
accumulate IN the output ref instead of a scratch buffer, so the
accumulator-dtype rule knows where the running sum lives.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: f32 tile alignment of the TPU vector unit: (sublane, lane).  Blocks are
#: physically padded up to these in VMEM, so the footprint model rounds the
#: two minor axes accordingly (the f32 figures; narrower dtypes pack denser,
#: making this a conservative over-estimate for bf16).
_SUBLANE = 8
_LANE = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _padded_nbytes(shape: tuple[int, ...], dtype: Any) -> int:
    """VMEM bytes of one block, minor axes tile-padded."""
    shape = tuple(shape)
    if len(shape) >= 2:
        shape = shape[:-2] + (_round_up(shape[-2], _SUBLANE),
                              _round_up(shape[-1], _LANE))
    elif len(shape) == 1:
        shape = (_round_up(shape[0], _LANE),)
    return math.prod(shape) * jnp.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Logical (padded) shape + dtype of one kernel operand."""

    shape: tuple[int, ...]
    dtype: Any


@dataclasses.dataclass(frozen=True)
class BlockDecl:
    """One operand's BlockSpec: block shape + grid-cell -> block-index map."""

    block_shape: tuple[int, ...]
    index_map: Callable[..., tuple[int, ...]]


@dataclasses.dataclass(frozen=True)
class ScratchDecl:
    """One VMEM scratch buffer (accumulators of the tiled kernels)."""

    shape: tuple[int, ...]
    dtype: Any


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Declarative, introspectable geometry of one ``pallas_call``."""

    name: str  # e.g. "gp_score.tiled" -- carried into every violation
    grid: tuple[int, ...]
    in_shapes: tuple[ArraySpec, ...]
    in_specs: tuple[BlockDecl, ...]
    out_shapes: tuple[ArraySpec, ...]
    out_specs: tuple[BlockDecl, ...]
    scratch: tuple[ScratchDecl, ...] = ()
    revisit_axes: tuple[int, ...] = ()
    init_axes: tuple[int, ...] = ()
    out_accumulates: bool = False

    def __post_init__(self):
        assert len(self.in_shapes) == len(self.in_specs), self.name
        assert len(self.out_shapes) == len(self.out_specs), self.name

    # -- launch ------------------------------------------------------------

    def pallas_call(self, kernel: Callable, *, interpret: bool = False):
        """Build the real ``pl.pallas_call`` from this declaration."""
        single = len(self.out_shapes) == 1
        out_shape = [jax.ShapeDtypeStruct(tuple(o.shape), o.dtype)
                     for o in self.out_shapes]
        out_specs = [pl.BlockSpec(tuple(d.block_shape), d.index_map)
                     for d in self.out_specs]
        return pl.pallas_call(
            kernel,
            out_shape=out_shape[0] if single else out_shape,
            grid=tuple(self.grid),
            in_specs=[pl.BlockSpec(tuple(d.block_shape), d.index_map)
                      for d in self.in_specs],
            out_specs=out_specs[0] if single else out_specs,
            scratch_shapes=[pltpu.VMEM(tuple(s.shape), s.dtype)
                            for s in self.scratch],
            interpret=interpret,
        )

    # -- introspection (consumed by repro.analysis.kernel_audit) -----------

    def operands(self) -> Iterator[tuple[str, int, ArraySpec, BlockDecl]]:
        """Yield ``(role, index, ArraySpec, BlockDecl)`` for every operand."""
        for i, (a, b) in enumerate(zip(self.in_shapes, self.in_specs)):
            yield "in", i, a, b
        for i, (a, b) in enumerate(zip(self.out_shapes, self.out_specs)):
            yield "out", i, a, b

    def grid_cells(self) -> Iterator[tuple[int, ...]]:
        return itertools.product(*(range(g) for g in self.grid))

    def n_grid_cells(self) -> int:
        return math.prod(self.grid)

    def vmem_cell_bytes(self) -> int:
        """Modeled per-grid-cell VMEM: block buffers x2 (double buffering)
        + scratch, minor axes tile-padded.  Kernel-internal intermediates
        are not modeled (the autotuner's per-kind cost model covers those);
        this is the geometry floor every launch must clear."""
        blocks = sum(_padded_nbytes(b.block_shape, a.dtype)
                     for _, _, a, b in self.operands())
        scratch = sum(_padded_nbytes(s.shape, s.dtype) for s in self.scratch)
        return 2 * blocks + scratch

    def accumulators(self) -> list[tuple[str, int, Any]]:
        """Where the running partial state lives: ``(kind, index, dtype)``.

        Scratch buffers when declared; otherwise the output refs when the
        kernel accumulates in place (``out_accumulates``)."""
        if self.scratch:
            return [("scratch", i, s.dtype) for i, s in enumerate(self.scratch)]
        if self.out_accumulates:
            return [("out", i, o.dtype) for i, o in enumerate(self.out_shapes)]
        return []
