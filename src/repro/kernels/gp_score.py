"""Pallas TPU kernel: fused active-query uncertainty scoring.

One VMEM-resident pass over a block of candidates computes, per candidate c,

    score(c) = max(prior - corr(c), 0)
    corr(c)  = (1/l^4) [ h^T P h - 2 (h o Xc)^T B h + (c.c) h^T B h ]

with h_t = k(c, x_t) generated IN the kernel (fused with the pairwise
distance matmul, so the (block_n, cap) kernel-vector tile never round-trips
to HBM), B the masked Gram inverse and P = B o XX^T both precomputed once
per trajectory state from the cached Cholesky factor (core/gp_surrogate
``GramFactor``).  This replaces the seed's per-candidate O(cap^2 d)
triangular-solve scoring with O(cap^2) of MXU matmuls per candidate.

Grid: (n / block_n,); xs, B and P stay resident across programs.  The
candidate-cross-trajectory matmul table doubles as the c.x_t table of the
middle term, so the whole score needs three MXU contractions per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, x_ref, b_ref, p_ref, o_ref, *, inv_two_l2: float, inv_l4: float, prior: float):
    c = c_ref[...]  # (bn, d)
    x = x_ref[...]  # (cap, d)
    n1 = jnp.sum(c * c, axis=-1, keepdims=True)  # (bn, 1)
    n2 = jnp.sum(x * x, axis=-1, keepdims=True).T  # (1, cap)
    cross = jax.lax.dot_general(
        c, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, cap) -- both the distance cross-term and the c.x_t table
    d2 = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)
    h = jnp.exp(-d2 * inv_two_l2)
    g1 = jax.lax.dot_general(
        h, p_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    g2 = jax.lax.dot_general(
        h, b_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    t1 = jnp.sum(g1 * h, axis=-1, keepdims=True)
    t2 = jnp.sum(h * cross * g2, axis=-1, keepdims=True)
    t3 = n1 * jnp.sum(h * g2, axis=-1, keepdims=True)
    corr = (t1 - 2.0 * t2 + t3) * inv_l4
    o_ref[...] = jnp.maximum(prior - corr, 0.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("lengthscale", "prior", "block_n", "interpret")
)
def uncertainty_scores_kernel(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    *,
    lengthscale: float,
    prior: float,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, d = cands.shape
    cap = xs.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert binv.shape == pmat.shape == (cap, cap), (binv.shape, pmat.shape, cap)
    grid = (n // block_n,)
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            inv_two_l2=0.5 / (lengthscale**2),
            inv_l4=1.0 / (lengthscale**4),
            prior=prior,
        ),
        out_shape=jax.ShapeDtypeStruct((n, 1), cands.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((cap, d), lambda i: (0, 0)),
            pl.BlockSpec((cap, cap), lambda i: (0, 0)),
            pl.BlockSpec((cap, cap), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(cands, xs, binv, pmat)
    return out[:, 0]
