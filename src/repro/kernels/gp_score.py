"""Pallas TPU kernel: fused active-query uncertainty scoring.

One VMEM-resident pass over a block of candidates computes, per candidate c,

    score(c) = max(prior - corr(c), 0)
    corr(c)  = (1/l^4) [ h^T P h - 2 (h o Xc)^T B h + (c.c) h^T B h ]

with h_t = k(c, x_t) generated IN the kernel (fused with the pairwise
distance matmul, so the (block_n, cap) kernel-vector tile never round-trips
to HBM), B the masked Gram inverse and P = B o XX^T both precomputed once
per trajectory state from the cached Cholesky factor (core/gp_surrogate
``GramFactor``).  This replaces the seed's per-candidate O(cap^2 d)
triangular-solve scoring with O(cap^2) of MXU matmuls per candidate.

All variants evaluate the three expansion terms through ONE fused epilogue,

    corr(c) * l^4 = sum_k [ g1 - (2 cross - c.c) o g2 ]_k h_k,
    g1 = h @ P,  g2 = h @ B,

which is algebraically identical to t1 - 2 t2 + t3 (the per-element
cancellation before the reduction is also the numerically kinder order) and
needs one elementwise pass + one reduction instead of three.

Two kernel families share the tile numerics:

* **resident** (``uncertainty_scores_kernel``): grid (n / block_n,); xs, B
  and P stay fully VMEM-resident across programs.  Cheapest when the whole
  (cap, cap) factor pair fits VMEM (cap <~ 256).
* **cap-tiled** (``uncertainty_scores_tiled_kernel``): grid
  (n/block_n, cap/block_cap, cap/block_cap) -- the trailing two grid
  dimensions sweep (bc, bc) tiles of B/P while a (block_n, 1) f32 VMEM
  scratch accumulates the bilinear form, so VMEM residency is
  O(bn d + bc d + bc^2 + bn bc) INDEPENDENT of cap and the kernel scales to
  cap >= 1024.  The h_j / h_k tiles are recomputed per cell from the x
  tiles (~2d/bc^2 flop overhead vs the GEMMs).  Padded trajectory slots
  (zero rows of xs, zero rows AND columns of B/P) contribute exactly zero:
  every product in the accumulated cell touches a B/P entry.

``*_clients_kernel`` variants add a leading CLIENT grid dimension for the
batched federated engine: one launch scores the whole client batch instead
of N vmapped launches.

Every launch is constructed from a declarative ``KernelSpec``
(``score_*_spec`` builders below): the spec both builds the real
``pl.pallas_call`` and feeds the static auditor in
``repro.analysis.kernel_audit`` (DESIGN.md Sec. 7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spec import ArraySpec, BlockDecl, KernelSpec, ScratchDecl


def _h_tile(c, n1, x, inv_two_l2: float):
    """SE kernel-vector tile h and the c.x_t table.  c (bn, d), n1 (bn, 1),
    x (bc, d) -> (h (bn, bc), cross (bn, bc))."""
    n2 = jnp.sum(x * x, axis=-1, keepdims=True).T  # (1, bc)
    cross = jax.lax.dot_general(
        c, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)
    return jnp.exp(-d2 * inv_two_l2), cross


def _score_block(c, x, binv, pmat, *, inv_two_l2: float, inv_l4: float, prior: float):
    """Shared VMEM-tile numerics of the resident kernels.  c (bn, d),
    x (cap, d), binv/pmat (cap, cap) -> (bn, 1)."""
    n1 = jnp.sum(c * c, axis=-1, keepdims=True)  # (bn, 1)
    h, cross = _h_tile(c, n1, x, inv_two_l2)
    g1 = jax.lax.dot_general(
        h, pmat, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    g2 = jax.lax.dot_general(
        h, binv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    corr = jnp.sum((g1 - (2.0 * cross - n1) * g2) * h, axis=-1, keepdims=True) * inv_l4
    return jnp.maximum(prior - corr, 0.0)


def _kernel(c_ref, x_ref, b_ref, p_ref, o_ref, **kw):
    o_ref[...] = _score_block(
        c_ref[...], x_ref[...], b_ref[...], p_ref[...], **kw
    ).astype(o_ref.dtype)


def score_resident_spec(n: int, cap: int, d: int, dtype, *,
                        block_n: int) -> KernelSpec:
    """Launch geometry of the VMEM-resident scoring kernel."""
    return KernelSpec(
        name="gp_score.resident",
        grid=(n // block_n,),
        in_shapes=(
            ArraySpec((n, d), dtype),
            ArraySpec((cap, d), dtype),
            ArraySpec((cap, cap), dtype),
            ArraySpec((cap, cap), dtype),
        ),
        in_specs=(
            BlockDecl((block_n, d), lambda i: (i, 0)),
            BlockDecl((cap, d), lambda i: (0, 0)),
            BlockDecl((cap, cap), lambda i: (0, 0)),
            BlockDecl((cap, cap), lambda i: (0, 0)),
        ),
        out_shapes=(ArraySpec((n, 1), dtype),),
        out_specs=(BlockDecl((block_n, 1), lambda i: (i, 0)),),
    )


@functools.partial(
    jax.jit, static_argnames=("lengthscale", "prior", "block_n", "interpret")
)
def uncertainty_scores_kernel(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    *,
    lengthscale: float,
    prior: float,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, d = cands.shape
    cap = xs.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert binv.shape == pmat.shape == (cap, cap), (binv.shape, pmat.shape, cap)
    spec = score_resident_spec(n, cap, d, cands.dtype, block_n=block_n)
    out = spec.pallas_call(
        functools.partial(
            _kernel,
            inv_two_l2=0.5 / (lengthscale**2),
            inv_l4=1.0 / (lengthscale**4),
            prior=prior,
        ),
        interpret=interpret,
    )(cands, xs, binv, pmat)
    return out[:, 0]


def _kernel_clients(c_ref, x_ref, b_ref, p_ref, o_ref, **kw):
    # Leading block dim of every ref is the (size-1) client slot; the tile
    # numerics are shared with the unbatched kernel (_score_block).
    o_ref[0] = _score_block(
        c_ref[0], x_ref[0], b_ref[0], p_ref[0], **kw
    ).astype(o_ref.dtype)


def score_clients_spec(nb: int, n: int, cap: int, d: int, dtype, *,
                       block_n: int) -> KernelSpec:
    """Launch geometry of the client-batched resident scoring kernel."""
    return KernelSpec(
        name="gp_score.clients",
        grid=(nb, n // block_n),
        in_shapes=(
            ArraySpec((nb, n, d), dtype),
            ArraySpec((nb, cap, d), dtype),
            ArraySpec((nb, cap, cap), dtype),
            ArraySpec((nb, cap, cap), dtype),
        ),
        in_specs=(
            BlockDecl((1, block_n, d), lambda b, i: (b, i, 0)),
            BlockDecl((1, cap, d), lambda b, i: (b, 0, 0)),
            BlockDecl((1, cap, cap), lambda b, i: (b, 0, 0)),
            BlockDecl((1, cap, cap), lambda b, i: (b, 0, 0)),
        ),
        out_shapes=(ArraySpec((nb, n, 1), dtype),),
        out_specs=(BlockDecl((1, block_n, 1), lambda b, i: (b, i, 0)),),
    )


@functools.partial(
    jax.jit, static_argnames=("lengthscale", "prior", "block_n", "interpret")
)
def uncertainty_scores_clients_kernel(
    cands: jax.Array,  # (N, n, d)
    xs: jax.Array,  # (N, cap, d)
    binv: jax.Array,  # (N, cap, cap)
    pmat: jax.Array,  # (N, cap, cap)
    *,
    lengthscale: float,
    prior: float,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Client-batched scoring: grid (N, n/block_n) -> (N, n)."""
    nb, n, d = cands.shape
    cap = xs.shape[1]
    assert n % block_n == 0, (n, block_n)
    assert xs.shape == (nb, cap, d), (xs.shape, cands.shape)
    assert binv.shape == pmat.shape == (nb, cap, cap), (binv.shape, pmat.shape)
    spec = score_clients_spec(nb, n, cap, d, cands.dtype, block_n=block_n)
    out = spec.pallas_call(
        functools.partial(
            _kernel_clients,
            inv_two_l2=0.5 / (lengthscale**2),
            inv_l4=1.0 / (lengthscale**4),
            prior=prior,
        ),
        interpret=interpret,
    )(cands, xs, binv, pmat)
    return out[:, :, 0]


# ---------------------------------------------------------------------------
# Cap-tiled kernels: the (cap, cap) factors never sit fully in VMEM.
# ---------------------------------------------------------------------------


def _score_cell(c, xj, xk, b, p, acc_ref, *, inv_two_l2: float):
    """Accumulate one (j, k) tile pair of the bilinear form into ``acc_ref``.

    c (bn, d); xj/xk (bc, d) trajectory tiles; b/p (bc, bc) tiles of
    B/P at block (j, k).  The cell's contribution to corr * l^4 is

        rowsum( [ h_j @ P_jk - (2 cross_k - c.c) o (h_j @ B_jk) ] o h_k )

    -- every product carries a B/P entry, so zero-padded trajectory tiles
    (zero B/P rows AND columns) contribute exactly zero even though the
    recomputed h at padded slots is nonzero junk.  Accumulation is f32.
    """
    n1 = jnp.sum(c * c, axis=-1, keepdims=True)  # (bn, 1)
    hj, _ = _h_tile(c, n1, xj, inv_two_l2)
    hk, cross_k = _h_tile(c, n1, xk, inv_two_l2)
    g1 = jax.lax.dot_general(
        hj, p, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    g2 = jax.lax.dot_general(
        hj, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    contrib = jnp.sum((g1 - (2.0 * cross_k - n1) * g2) * hk, axis=-1, keepdims=True)
    acc_ref[...] += contrib.astype(jnp.float32)


def _finalize(acc, *, inv_l4: float, prior: float):
    return jnp.maximum(prior - acc * inv_l4, 0.0)


def _kernel_tiled(c_ref, xj_ref, xk_ref, b_ref, p_ref, o_ref, acc_ref, *,
                  inv_two_l2: float, inv_l4: float, prior: float):
    j, k = pl.program_id(1), pl.program_id(2)
    last_j, last_k = pl.num_programs(1) - 1, pl.num_programs(2) - 1

    @pl.when((j == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _score_cell(c_ref[...], xj_ref[...], xk_ref[...], b_ref[...], p_ref[...],
                acc_ref, inv_two_l2=inv_two_l2)

    @pl.when((j == last_j) & (k == last_k))
    def _done():
        o_ref[...] = _finalize(
            acc_ref[...], inv_l4=inv_l4, prior=prior
        ).astype(o_ref.dtype)


def score_tiled_spec(n: int, cap: int, d: int, dtype, *, block_n: int,
                     block_cap: int) -> KernelSpec:
    """Launch geometry of the cap-tiled scoring kernel.  The trailing two
    grid axes revisit each (block_n, 1) output block while the f32 scratch
    accumulates the bilinear form; xs is passed twice (the j- and k-tile
    views of the same trajectory array)."""
    return KernelSpec(
        name="gp_score.tiled",
        grid=(n // block_n, cap // block_cap, cap // block_cap),
        in_shapes=(
            ArraySpec((n, d), dtype),
            ArraySpec((cap, d), dtype),
            ArraySpec((cap, d), dtype),
            ArraySpec((cap, cap), dtype),
            ArraySpec((cap, cap), dtype),
        ),
        in_specs=(
            BlockDecl((block_n, d), lambda i, j, k: (i, 0)),
            BlockDecl((block_cap, d), lambda i, j, k: (j, 0)),
            BlockDecl((block_cap, d), lambda i, j, k: (k, 0)),
            BlockDecl((block_cap, block_cap), lambda i, j, k: (j, k)),
            BlockDecl((block_cap, block_cap), lambda i, j, k: (j, k)),
        ),
        out_shapes=(ArraySpec((n, 1), dtype),),
        out_specs=(BlockDecl((block_n, 1), lambda i, j, k: (i, 0)),),
        scratch=(ScratchDecl((block_n, 1), jnp.float32),),
        revisit_axes=(1, 2),
        init_axes=(1, 2),
    )


@functools.partial(
    jax.jit,
    static_argnames=("lengthscale", "prior", "block_n", "block_cap", "interpret"),
)
def uncertainty_scores_tiled_kernel(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    *,
    lengthscale: float,
    prior: float,
    block_n: int = 128,
    block_cap: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Cap-tiled scoring: grid (n/block_n, cap/block_cap, cap/block_cap)."""
    n, d = cands.shape
    cap = xs.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert cap % block_cap == 0, (cap, block_cap)
    assert binv.shape == pmat.shape == (cap, cap), (binv.shape, pmat.shape, cap)
    spec = score_tiled_spec(n, cap, d, cands.dtype,
                            block_n=block_n, block_cap=block_cap)
    out = spec.pallas_call(
        functools.partial(
            _kernel_tiled,
            inv_two_l2=0.5 / (lengthscale**2),
            inv_l4=1.0 / (lengthscale**4),
            prior=prior,
        ),
        interpret=interpret,
    )(cands, xs, xs, binv, pmat)
    return out[:, 0]


def _kernel_tiled_clients(c_ref, xj_ref, xk_ref, b_ref, p_ref, o_ref, acc_ref, *,
                          inv_two_l2: float, inv_l4: float, prior: float):
    j, k = pl.program_id(2), pl.program_id(3)
    last_j, last_k = pl.num_programs(2) - 1, pl.num_programs(3) - 1

    @pl.when((j == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _score_cell(c_ref[0], xj_ref[0], xk_ref[0], b_ref[0], p_ref[0],
                acc_ref, inv_two_l2=inv_two_l2)

    @pl.when((j == last_j) & (k == last_k))
    def _done():
        o_ref[0] = _finalize(
            acc_ref[...], inv_l4=inv_l4, prior=prior
        ).astype(o_ref.dtype)


def score_tiled_clients_spec(nb: int, n: int, cap: int, d: int, dtype, *,
                             block_n: int, block_cap: int) -> KernelSpec:
    """Launch geometry of the client-batched cap-tiled scoring kernel."""
    return KernelSpec(
        name="gp_score.tiled_clients",
        grid=(nb, n // block_n, cap // block_cap, cap // block_cap),
        in_shapes=(
            ArraySpec((nb, n, d), dtype),
            ArraySpec((nb, cap, d), dtype),
            ArraySpec((nb, cap, d), dtype),
            ArraySpec((nb, cap, cap), dtype),
            ArraySpec((nb, cap, cap), dtype),
        ),
        in_specs=(
            BlockDecl((1, block_n, d), lambda b, i, j, k: (b, i, 0)),
            BlockDecl((1, block_cap, d), lambda b, i, j, k: (b, j, 0)),
            BlockDecl((1, block_cap, d), lambda b, i, j, k: (b, k, 0)),
            BlockDecl((1, block_cap, block_cap), lambda b, i, j, k: (b, j, k)),
            BlockDecl((1, block_cap, block_cap), lambda b, i, j, k: (b, j, k)),
        ),
        out_shapes=(ArraySpec((nb, n, 1), dtype),),
        out_specs=(BlockDecl((1, block_n, 1), lambda b, i, j, k: (b, i, 0)),),
        scratch=(ScratchDecl((block_n, 1), jnp.float32),),
        revisit_axes=(2, 3),
        init_axes=(2, 3),
    )


@functools.partial(
    jax.jit,
    static_argnames=("lengthscale", "prior", "block_n", "block_cap", "interpret"),
)
def uncertainty_scores_tiled_clients_kernel(
    cands: jax.Array,  # (N, n, d)
    xs: jax.Array,  # (N, cap, d)
    binv: jax.Array,  # (N, cap, cap)
    pmat: jax.Array,  # (N, cap, cap)
    *,
    lengthscale: float,
    prior: float,
    block_n: int = 128,
    block_cap: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Client-batched cap-tiled scoring:
    grid (N, n/block_n, cap/block_cap, cap/block_cap) -> (N, n)."""
    nb, n, d = cands.shape
    cap = xs.shape[1]
    assert n % block_n == 0, (n, block_n)
    assert cap % block_cap == 0, (cap, block_cap)
    assert xs.shape == (nb, cap, d), (xs.shape, cands.shape)
    assert binv.shape == pmat.shape == (nb, cap, cap), (binv.shape, pmat.shape)
    spec = score_tiled_clients_spec(nb, n, cap, d, cands.dtype,
                                    block_n=block_n, block_cap=block_cap)
    out = spec.pallas_call(
        functools.partial(
            _kernel_tiled_clients,
            inv_two_l2=0.5 / (lengthscale**2),
            inv_l4=1.0 / (lengthscale**4),
            prior=prior,
        ),
        interpret=interpret,
    )(cands, xs, xs, binv, pmat)
    return out[:, :, 0]
