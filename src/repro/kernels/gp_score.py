"""Pallas TPU kernel: fused active-query uncertainty scoring.

One VMEM-resident pass over a block of candidates computes, per candidate c,

    score(c) = max(prior - corr(c), 0)
    corr(c)  = (1/l^4) [ h^T P h - 2 (h o Xc)^T B h + (c.c) h^T B h ]

with h_t = k(c, x_t) generated IN the kernel (fused with the pairwise
distance matmul, so the (block_n, cap) kernel-vector tile never round-trips
to HBM), B the masked Gram inverse and P = B o XX^T both precomputed once
per trajectory state from the cached Cholesky factor (core/gp_surrogate
``GramFactor``).  This replaces the seed's per-candidate O(cap^2 d)
triangular-solve scoring with O(cap^2) of MXU matmuls per candidate.

Grid: (n / block_n,); xs, B and P stay resident across programs.  The
candidate-cross-trajectory matmul table doubles as the c.x_t table of the
middle term, so the whole score needs three MXU contractions per block.

``uncertainty_scores_clients_kernel`` adds a CLIENT grid dimension for the
vmapped federated engine: one launch scores the whole client batch (grid
(N, n/block_n), per-client xs/B/P blocks indexed by the client program id)
instead of N vmapped launches with their N sets of resident operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_block(c, x, binv, pmat, *, inv_two_l2: float, inv_l4: float, prior: float):
    """Shared VMEM-tile numerics of both kernels.  c (bn, d), x (cap, d),
    binv/pmat (cap, cap) -> (bn, 1)."""
    n1 = jnp.sum(c * c, axis=-1, keepdims=True)  # (bn, 1)
    n2 = jnp.sum(x * x, axis=-1, keepdims=True).T  # (1, cap)
    cross = jax.lax.dot_general(
        c, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, cap) -- both the distance cross-term and the c.x_t table
    d2 = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)
    h = jnp.exp(-d2 * inv_two_l2)
    g1 = jax.lax.dot_general(
        h, pmat, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    g2 = jax.lax.dot_general(
        h, binv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    t1 = jnp.sum(g1 * h, axis=-1, keepdims=True)
    t2 = jnp.sum(h * cross * g2, axis=-1, keepdims=True)
    t3 = n1 * jnp.sum(h * g2, axis=-1, keepdims=True)
    corr = (t1 - 2.0 * t2 + t3) * inv_l4
    return jnp.maximum(prior - corr, 0.0)


def _kernel(c_ref, x_ref, b_ref, p_ref, o_ref, **kw):
    o_ref[...] = _score_block(
        c_ref[...], x_ref[...], b_ref[...], p_ref[...], **kw
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("lengthscale", "prior", "block_n", "interpret")
)
def uncertainty_scores_kernel(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    *,
    lengthscale: float,
    prior: float,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, d = cands.shape
    cap = xs.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert binv.shape == pmat.shape == (cap, cap), (binv.shape, pmat.shape, cap)
    grid = (n // block_n,)
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            inv_two_l2=0.5 / (lengthscale**2),
            inv_l4=1.0 / (lengthscale**4),
            prior=prior,
        ),
        out_shape=jax.ShapeDtypeStruct((n, 1), cands.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((cap, d), lambda i: (0, 0)),
            pl.BlockSpec((cap, cap), lambda i: (0, 0)),
            pl.BlockSpec((cap, cap), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(cands, xs, binv, pmat)
    return out[:, 0]


def _kernel_clients(c_ref, x_ref, b_ref, p_ref, o_ref, **kw):
    # Leading block dim of every ref is the (size-1) client slot; the tile
    # numerics are shared with the unbatched kernel (_score_block).
    o_ref[0] = _score_block(
        c_ref[0], x_ref[0], b_ref[0], p_ref[0], **kw
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("lengthscale", "prior", "block_n", "interpret")
)
def uncertainty_scores_clients_kernel(
    cands: jax.Array,  # (N, n, d)
    xs: jax.Array,  # (N, cap, d)
    binv: jax.Array,  # (N, cap, cap)
    pmat: jax.Array,  # (N, cap, cap)
    *,
    lengthscale: float,
    prior: float,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Client-batched scoring: grid (N, n/block_n) -> (N, n)."""
    nb, n, d = cands.shape
    cap = xs.shape[1]
    assert n % block_n == 0, (n, block_n)
    assert xs.shape == (nb, cap, d), (xs.shape, cands.shape)
    assert binv.shape == pmat.shape == (nb, cap, cap), (binv.shape, pmat.shape)
    grid = (nb, n // block_n)
    out = pl.pallas_call(
        functools.partial(
            _kernel_clients,
            inv_two_l2=0.5 / (lengthscale**2),
            inv_l4=1.0 / (lengthscale**4),
            prior=prior,
        ),
        out_shape=jax.ShapeDtypeStruct((nb, n, 1), cands.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, cap, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, cap, cap), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, cap, cap), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, 1), lambda b, i: (b, i, 0)),
        interpret=interpret,
    )(cands, xs, binv, pmat)
    return out[:, :, 0]
