"""Public jit'd wrappers around the Pallas kernels.

Handles block-size padding (zero-pad, slice back), block-size selection and
backend selection: on TPU the Pallas kernels run compiled; elsewhere they
run in interpret mode when ``force_pallas`` (used by tests) or fall back to
the jnp oracles in ref.py, which are numerically identical.

The GP kernels (scoring / grad mean) take ``block_n`` / ``block_cap``; when
left ``None`` the tuner in ``kernels/autotune.py`` picks them
deterministically per (backend, shape).  ``block_cap >= cap`` routes to the
VMEM-resident kernels; smaller ``block_cap`` routes to the cap-tiled
kernels, with the trajectory axis zero-padded to a tile multiple -- padded
slots contribute EXACTLY zero (zero B/P rows+columns for scoring, zero
alpha for the grad mean), so tiling never perturbs results.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels.gp_grad import (
    grad_mean_clients_kernel,
    grad_mean_kernel,
    grad_mean_tiled_clients_kernel,
    grad_mean_tiled_kernel,
)
from repro.kernels.gp_score import (
    uncertainty_scores_clients_kernel,
    uncertainty_scores_kernel,
    uncertainty_scores_tiled_clients_kernel,
    uncertainty_scores_tiled_kernel,
)
from repro.kernels.rff_features import rff_features_kernel
from repro.kernels.rff_grad import rff_grad_kernel
from repro.kernels.sqexp import sqexp_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _static_float(x) -> float | None:
    """Concrete python float, or None for a traced value.

    The Pallas kernels bake scalars (lengthscale, prior) into the program as
    compile-time constants; when a caller threads TRACED hyperparameters
    (e.g. the federated round loop jits over GPHyper arrays) the wrappers
    fall back to the jnp oracle, which XLA fuses well on every backend.
    """
    try:
        return float(x)
    except (TypeError, jax.errors.TracerArrayConversionError, jax.errors.ConcretizationTypeError):
        return None


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_rows(a: jax.Array, target: int) -> jax.Array:
    pad = target - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))


def _pad_axis1(a: jax.Array, target: int) -> jax.Array:
    """Zero-pad the second axis (the per-client candidate axis)."""
    pad = target - a.shape[1]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))


def _pad_axis(a: jax.Array, axis: int, target: int) -> jax.Array:
    """Zero-pad one axis to ``target`` (cap-axis padding for tiled kernels)."""
    pad = target - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _pad_gram(a: jax.Array, target: int) -> jax.Array:
    """Zero-pad BOTH trailing axes of a (..., cap, cap) Gram-shaped array.
    Zero rows AND columns make padded trajectory slots contribute exactly
    zero in the tiled bilinear form (see kernels/gp_score.py)."""
    return _pad_axis(_pad_axis(a, a.ndim - 1, target), a.ndim - 2, target)


def _resolve_blocks(kind, n, cap, d, n_clients, block_n, block_cap, dtype=None):
    """Fill in unset block sizes from the deterministic autotuner; validate
    user-pinned ones against the VMEM budget (tuner picks are feasible by
    construction, explicit pins are not)."""
    pinned = block_n is not None or block_cap is not None
    if block_n is None or block_cap is None:
        bn, bc = autotune.select_blocks(
            kind, n=n, cap=cap, d=d, n_clients=n_clients, dtype=dtype
        )
        block_n = bn if block_n is None else block_n
        block_cap = bc if block_cap is None else block_cap
    if pinned:
        autotune.validate_blocks(kind, block_n=block_n, block_cap=block_cap,
                                 cap=cap, d=d, dtype=dtype)
    return block_n, block_cap


def rff_features(
    x: jax.Array,
    v: jax.Array,
    b: jax.Array,
    *,
    block_n: int = 128,
    block_m: int = 256,
    force_pallas: bool = False,
) -> jax.Array:
    """phi(X): (n,d),(M,d),(M,) -> (n,M)."""
    if not (_on_tpu() or force_pallas):
        return ref.rff_features(x, v, b)
    n, m = x.shape[0], v.shape[0]
    npad, mpad = _round_up(n, block_n), _round_up(m, block_m)
    out = rff_features_kernel(
        _pad_rows(x, npad), _pad_rows(v, mpad), _pad_rows(b, mpad),
        n_features=m, block_n=block_n, block_m=block_m, interpret=not _on_tpu(),
    )
    return out[:n, :m]


def rff_grad(
    x: jax.Array,
    v: jax.Array,
    b: jax.Array,
    w: jax.Array,
    *,
    block_n: int = 128,
    block_m: int = 256,
    force_pallas: bool = False,
) -> jax.Array:
    """grad phi(X)^T w: (n,d),(M,d),(M,),(M,) -> (n,d)."""
    if not (_on_tpu() or force_pallas):
        return ref.rff_grad(x, v, b, w)
    n, m = x.shape[0], v.shape[0]
    npad, mpad = _round_up(n, block_n), _round_up(m, block_m)
    # Padded feature slots carry v == 0 AND w == 0 => zero contribution.
    out = rff_grad_kernel(
        _pad_rows(x, npad), _pad_rows(v, mpad), _pad_rows(b, mpad), _pad_rows(w, mpad),
        n_features=m, block_n=block_n, block_m=block_m, interpret=not _on_tpu(),
    )
    return out[:n, :]


def sqexp(
    x1: jax.Array,
    x2: jax.Array,
    lengthscale: float,
    *,
    block_n: int = 128,
    block_m: int = 128,
    force_pallas: bool = False,
) -> jax.Array:
    """SE Gram matrix: (n,d),(m,d) -> (n,m).

    Note: padded rows produce exp(-||x||^2/2l^2) junk values that are sliced
    away before returning (padding uses zeros, never NaN).
    """
    if not (_on_tpu() or force_pallas):
        return ref.sqexp(x1, x2, lengthscale)
    n, m = x1.shape[0], x2.shape[0]
    npad, mpad = _round_up(n, block_n), _round_up(m, block_m)
    out = sqexp_kernel(
        _pad_rows(x1, npad), _pad_rows(x2, mpad),
        lengthscale=lengthscale, block_n=block_n, block_m=block_m,
        interpret=not _on_tpu(),
    )
    return out[:n, :m]


def uncertainty_scores(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    *,
    lengthscale,
    prior,
    block_n: int | None = None,
    block_cap: int | None = None,
    force_pallas: bool = False,
) -> jax.Array:
    """Fused active-query uncertainty scores: (n,d) candidates -> (n,).

    ``binv`` is the masked Gram inverse and ``pmat = binv o XX^T``; see
    ref.uncertainty_scores for the algebra.  Padded candidate rows (zeros)
    produce junk scores that are sliced away before returning.  With
    ``block_cap < cap`` the cap-tiled kernel runs and the trajectory axis is
    zero-padded to a tile multiple (padded slots contribute exactly zero:
    the B/P padding rows+columns are zero); otherwise the whole (cap, cap)
    factor pair stays VMEM-resident.  Unset block sizes come from the
    deterministic autotuner.  Traced lengthscale/prior fall back to the jnp
    oracle.
    """
    ls, pr = _static_float(lengthscale), _static_float(prior)
    if not (_on_tpu() or force_pallas) or ls is None or pr is None:
        return ref.uncertainty_scores(cands, xs, binv, pmat, lengthscale, prior)
    n, d = cands.shape
    cap = xs.shape[0]
    block_n, block_cap = _resolve_blocks(
        "score", n, cap, d, 1, block_n, block_cap, dtype=cands.dtype
    )
    npad = _round_up(n, block_n)
    interpret = not _on_tpu()
    if block_cap >= cap:
        out = uncertainty_scores_kernel(
            _pad_rows(cands, npad), xs, binv, pmat,
            lengthscale=ls, prior=pr, block_n=block_n, interpret=interpret,
        )
    else:
        cpad = _round_up(cap, block_cap)
        out = uncertainty_scores_tiled_kernel(
            _pad_rows(cands, npad), _pad_rows(xs, cpad),
            _pad_gram(binv, cpad), _pad_gram(pmat, cpad),
            lengthscale=ls, prior=pr, block_n=block_n, block_cap=block_cap,
            interpret=interpret,
        )
    return out[:n]


def uncertainty_scores_clients(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    *,
    lengthscale,
    prior,
    block_n: int | None = None,
    block_cap: int | None = None,
    force_pallas: bool = False,
) -> jax.Array:
    """Client-batched fused uncertainty scores: (N, n, d) -> (N, n).

    One kernel launch with a client grid dimension for the whole batch;
    same padding/backend/traced-scalar/tiling contract as
    ``uncertainty_scores`` (the candidate and trajectory axes are padded per
    client, the client axis never is).  The CPU execution path is the
    fused-epilogue contraction (``ref.uncertainty_scores_clients_fused``);
    the textbook oracle stays in ``ref.uncertainty_scores_clients``.
    """
    ls, pr = _static_float(lengthscale), _static_float(prior)
    if not (_on_tpu() or force_pallas) or ls is None or pr is None:
        return ref.uncertainty_scores_clients_fused(
            cands, xs, binv, pmat, lengthscale, prior
        )
    nb, n, d = cands.shape
    cap = xs.shape[1]
    block_n, block_cap = _resolve_blocks(
        "score", n, cap, d, nb, block_n, block_cap, dtype=cands.dtype
    )
    npad = _round_up(n, block_n)
    interpret = not _on_tpu()
    if block_cap >= cap:
        out = uncertainty_scores_clients_kernel(
            _pad_axis1(cands, npad), xs, binv, pmat,
            lengthscale=ls, prior=pr, block_n=block_n, interpret=interpret,
        )
    else:
        cpad = _round_up(cap, block_cap)
        out = uncertainty_scores_tiled_clients_kernel(
            _pad_axis1(cands, npad), _pad_axis(xs, 1, cpad),
            _pad_gram(binv, cpad), _pad_gram(pmat, cpad),
            lengthscale=ls, prior=pr, block_n=block_n, block_cap=block_cap,
            interpret=interpret,
        )
    return out[:, :n]


def grad_mean_clients(
    cands: jax.Array,
    xs: jax.Array,
    alpha: jax.Array,
    *,
    lengthscale,
    block_n: int | None = None,
    block_cap: int | None = None,
    force_pallas: bool = False,
) -> jax.Array:
    """Client-batched fused gradient mean: (N, n, d) -> (N, n, d).

    ``alpha`` (N, cap) must already carry each client's validity mask.
    With ``block_cap < cap`` the cap-tiled accumulator kernel runs; padded
    trajectory slots carry alpha == 0 and contribute exactly zero.
    """
    ls = _static_float(lengthscale)
    if not (_on_tpu() or force_pallas) or ls is None:
        return ref.grad_mean_clients(cands, xs, alpha, lengthscale)
    nb, n, d = cands.shape
    cap = xs.shape[1]
    block_n, block_cap = _resolve_blocks(
        "grad", n, cap, d, nb, block_n, block_cap, dtype=cands.dtype
    )
    npad = _round_up(n, block_n)
    interpret = not _on_tpu()
    if block_cap >= cap:
        out = grad_mean_clients_kernel(
            _pad_axis1(cands, npad), xs, alpha[:, None, :],
            lengthscale=ls, block_n=block_n, interpret=interpret,
        )
    else:
        cpad = _round_up(cap, block_cap)
        out = grad_mean_tiled_clients_kernel(
            _pad_axis1(cands, npad), _pad_axis(xs, 1, cpad),
            _pad_axis(alpha, 1, cpad)[:, None, :],
            lengthscale=ls, block_n=block_n, block_cap=block_cap,
            interpret=interpret,
        )
    return out[:, :n, :]


def grad_mean_batch(
    cands: jax.Array,
    xs: jax.Array,
    alpha: jax.Array,
    *,
    lengthscale,
    block_n: int | None = None,
    block_cap: int | None = None,
    force_pallas: bool = False,
) -> jax.Array:
    """Fused batched derived-GP gradient mean: (n,d) queries -> (n,d).

    ``alpha`` (cap,) must already carry the validity mask (masked solves
    leave invalid slots exactly zero, so padded trajectory slots contribute
    nothing -- the same invariant makes cap-axis zero-padding exact on the
    tiled path).  Padded candidate rows are sliced away before returning.
    Traced lengthscale falls back to the jnp oracle.
    """
    ls = _static_float(lengthscale)
    if not (_on_tpu() or force_pallas) or ls is None:
        return ref.grad_mean_batch(cands, xs, alpha, lengthscale)
    n, d = cands.shape
    cap = xs.shape[0]
    block_n, block_cap = _resolve_blocks(
        "grad", n, cap, d, 1, block_n, block_cap, dtype=cands.dtype
    )
    npad = _round_up(n, block_n)
    interpret = not _on_tpu()
    if block_cap >= cap:
        out = grad_mean_kernel(
            _pad_rows(cands, npad), xs, alpha[None, :],
            lengthscale=ls, block_n=block_n, interpret=interpret,
        )
    else:
        cpad = _round_up(cap, block_cap)
        out = grad_mean_tiled_kernel(
            _pad_rows(cands, npad), _pad_rows(xs, cpad),
            _pad_axis(alpha, 0, cpad)[None, :],
            lengthscale=ls, block_n=block_n, block_cap=block_cap,
            interpret=interpret,
        )
    return out[:n, :]
