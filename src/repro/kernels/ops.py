"""Public jit'd wrappers around the Pallas kernels.

Handles block-size padding (zero-pad, slice back) and backend selection:
on TPU the Pallas kernels run compiled; elsewhere they run in interpret
mode when ``force_pallas`` (used by tests) or fall back to the jnp oracles
in ref.py, which are numerically identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gp_grad import grad_mean_clients_kernel, grad_mean_kernel
from repro.kernels.gp_score import (
    uncertainty_scores_clients_kernel,
    uncertainty_scores_kernel,
)
from repro.kernels.rff_features import rff_features_kernel
from repro.kernels.rff_grad import rff_grad_kernel
from repro.kernels.sqexp import sqexp_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _static_float(x) -> float | None:
    """Concrete python float, or None for a traced value.

    The Pallas kernels bake scalars (lengthscale, prior) into the program as
    compile-time constants; when a caller threads TRACED hyperparameters
    (e.g. the federated round loop jits over GPHyper arrays) the wrappers
    fall back to the jnp oracle, which XLA fuses well on every backend.
    """
    try:
        return float(x)
    except (TypeError, jax.errors.TracerArrayConversionError, jax.errors.ConcretizationTypeError):
        return None


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_rows(a: jax.Array, target: int) -> jax.Array:
    pad = target - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))


def _pad_axis1(a: jax.Array, target: int) -> jax.Array:
    """Zero-pad the second axis (the per-client candidate axis)."""
    pad = target - a.shape[1]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))


def rff_features(
    x: jax.Array,
    v: jax.Array,
    b: jax.Array,
    *,
    block_n: int = 128,
    block_m: int = 256,
    force_pallas: bool = False,
) -> jax.Array:
    """phi(X): (n,d),(M,d),(M,) -> (n,M)."""
    if not (_on_tpu() or force_pallas):
        return ref.rff_features(x, v, b)
    n, m = x.shape[0], v.shape[0]
    npad, mpad = _round_up(n, block_n), _round_up(m, block_m)
    out = rff_features_kernel(
        _pad_rows(x, npad), _pad_rows(v, mpad), _pad_rows(b, mpad),
        n_features=m, block_n=block_n, block_m=block_m, interpret=not _on_tpu(),
    )
    return out[:n, :m]


def rff_grad(
    x: jax.Array,
    v: jax.Array,
    b: jax.Array,
    w: jax.Array,
    *,
    block_n: int = 128,
    block_m: int = 256,
    force_pallas: bool = False,
) -> jax.Array:
    """grad phi(X)^T w: (n,d),(M,d),(M,),(M,) -> (n,d)."""
    if not (_on_tpu() or force_pallas):
        return ref.rff_grad(x, v, b, w)
    n, m = x.shape[0], v.shape[0]
    npad, mpad = _round_up(n, block_n), _round_up(m, block_m)
    # Padded feature slots carry v == 0 AND w == 0 => zero contribution.
    out = rff_grad_kernel(
        _pad_rows(x, npad), _pad_rows(v, mpad), _pad_rows(b, mpad), _pad_rows(w, mpad),
        n_features=m, block_n=block_n, block_m=block_m, interpret=not _on_tpu(),
    )
    return out[:n, :]


def sqexp(
    x1: jax.Array,
    x2: jax.Array,
    lengthscale: float,
    *,
    block_n: int = 128,
    block_m: int = 128,
    force_pallas: bool = False,
) -> jax.Array:
    """SE Gram matrix: (n,d),(m,d) -> (n,m).

    Note: padded rows produce exp(-||x||^2/2l^2) junk values that are sliced
    away before returning (padding uses zeros, never NaN).
    """
    if not (_on_tpu() or force_pallas):
        return ref.sqexp(x1, x2, lengthscale)
    n, m = x1.shape[0], x2.shape[0]
    npad, mpad = _round_up(n, block_n), _round_up(m, block_m)
    out = sqexp_kernel(
        _pad_rows(x1, npad), _pad_rows(x2, mpad),
        lengthscale=lengthscale, block_n=block_n, block_m=block_m,
        interpret=not _on_tpu(),
    )
    return out[:n, :m]


def uncertainty_scores(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    *,
    lengthscale,
    prior,
    block_n: int = 128,
    force_pallas: bool = False,
) -> jax.Array:
    """Fused active-query uncertainty scores: (n,d) candidates -> (n,).

    ``binv`` is the masked Gram inverse and ``pmat = binv o XX^T``; see
    ref.uncertainty_scores for the algebra.  Padded candidate rows (zeros)
    produce junk scores that are sliced away before returning; the resident
    trajectory/Gram inputs are never padded (cap is the compile-time ring
    capacity).  Traced lengthscale/prior fall back to the jnp oracle.
    """
    ls, pr = _static_float(lengthscale), _static_float(prior)
    if not (_on_tpu() or force_pallas) or ls is None or pr is None:
        return ref.uncertainty_scores(cands, xs, binv, pmat, lengthscale, prior)
    n = cands.shape[0]
    npad = _round_up(n, block_n)
    out = uncertainty_scores_kernel(
        _pad_rows(cands, npad), xs, binv, pmat,
        lengthscale=ls, prior=pr, block_n=block_n, interpret=not _on_tpu(),
    )
    return out[:n]


def uncertainty_scores_clients(
    cands: jax.Array,
    xs: jax.Array,
    binv: jax.Array,
    pmat: jax.Array,
    *,
    lengthscale,
    prior,
    block_n: int = 128,
    force_pallas: bool = False,
) -> jax.Array:
    """Client-batched fused uncertainty scores: (N, n, d) -> (N, n).

    One kernel launch with a client grid dimension for the whole batch;
    same padding/backend/traced-scalar contract as ``uncertainty_scores``
    (the candidate axis is padded per client, the client axis never is).
    """
    ls, pr = _static_float(lengthscale), _static_float(prior)
    if not (_on_tpu() or force_pallas) or ls is None or pr is None:
        return ref.uncertainty_scores_clients(cands, xs, binv, pmat, lengthscale, prior)
    n = cands.shape[1]
    npad = _round_up(n, block_n)
    out = uncertainty_scores_clients_kernel(
        _pad_axis1(cands, npad), xs, binv, pmat,
        lengthscale=ls, prior=pr, block_n=block_n, interpret=not _on_tpu(),
    )
    return out[:, :n]


def grad_mean_clients(
    cands: jax.Array,
    xs: jax.Array,
    alpha: jax.Array,
    *,
    lengthscale,
    block_n: int = 128,
    force_pallas: bool = False,
) -> jax.Array:
    """Client-batched fused gradient mean: (N, n, d) -> (N, n, d).

    ``alpha`` (N, cap) must already carry each client's validity mask.
    """
    ls = _static_float(lengthscale)
    if not (_on_tpu() or force_pallas) or ls is None:
        return ref.grad_mean_clients(cands, xs, alpha, lengthscale)
    n = cands.shape[1]
    npad = _round_up(n, block_n)
    out = grad_mean_clients_kernel(
        _pad_axis1(cands, npad), xs, alpha[:, None, :],
        lengthscale=ls, block_n=block_n, interpret=not _on_tpu(),
    )
    return out[:, :n, :]


def grad_mean_batch(
    cands: jax.Array,
    xs: jax.Array,
    alpha: jax.Array,
    *,
    lengthscale,
    block_n: int = 128,
    force_pallas: bool = False,
) -> jax.Array:
    """Fused batched derived-GP gradient mean: (n,d) queries -> (n,d).

    ``alpha`` (cap,) must already carry the validity mask (masked solves
    leave invalid slots exactly zero, so padded trajectory slots contribute
    nothing).  Padded candidate rows are sliced away before returning.
    Traced lengthscale falls back to the jnp oracle.
    """
    ls = _static_float(lengthscale)
    if not (_on_tpu() or force_pallas) or ls is None:
        return ref.grad_mean_batch(cands, xs, alpha, lengthscale)
    n = cands.shape[0]
    npad = _round_up(n, block_n)
    out = grad_mean_kernel(
        _pad_rows(cands, npad), xs, alpha[None, :],
        lengthscale=ls, block_n=block_n, interpret=not _on_tpu(),
    )
    return out[:n, :]
