# Pallas TPU kernels for the FZooS surrogate hot paths, each with a pure-jnp
# oracle in ref.py and a padding/backend wrapper in ops.py (see DESIGN.md
# Sec. 3):
#
#   sqexp        - fused SE Gram tiles (trajectory kernel matrix)
#   rff_features - phi(X) feature map (eq. 6)
#   rff_grad     - grad phi(X)^T w contraction (eq. 8)
#   gp_score     - fused active-query uncertainty scoring vs the cached
#                  Gram-factor inverse (ISSUE 1 tentpole)
#   gp_grad      - fused batched derived-GP gradient mean (eq. 5)
#
# Import kernels via repro.kernels.ops; the kernel modules themselves are
# implementation detail.
