"""llama4-scout-17b-16e [moe] -- MoE, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1 with a
shared expert; chunked local attention as sliding window.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-16e",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    moe_top_k=1,
    n_shared_experts=1,
    sliding_window=8192,
    rope_theta=500_000.0,
    supports_long_context=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = dataclasses.replace(
    FULL,
    name="llama4-scout-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    n_experts=4,
    sliding_window=64,
)
