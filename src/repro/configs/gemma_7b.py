"""gemma-7b [dense] -- GeGLU, head_dim=256 [arXiv:2403.08295].

28L d_model=3072 16H (kv=16, i.e. MHA at 7B; MQA is the 2B variant)
d_ff=24576 vocab=256000.  Pure full attention -> long_500k skipped
(DESIGN.md Arch-applicability).
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="geglu",
    tie_embeddings=True,
    source="arXiv:2403.08295",
)

SMOKE = dataclasses.replace(
    FULL,
    name="gemma-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
)
