"""whisper-base [audio] -- enc-dec transformer backbone [arXiv:2212.04356].

6L (x2: 6 encoder + 6 decoder) d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
The mel-spectrogram + conv frontend is a STUB: input_specs supplies
precomputed frame embeddings (B, 1500, d_model).  Learned positions, no rope.
Enc-dec (not encoder-only) -> decode_32k IS lowered; long_500k skipped
(quadratic decoder attention, 1.5k-frame encoder bound).
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-base",
    arch_type="encdec",
    n_layers=6,
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    mlp_act="geglu",
    rope_mode="none",
    enc_seq=1500,
    frontend_dim=512,
    dec_pos_len=32768,  # decode_32k cache length
    source="arXiv:2212.04356",
)

SMOKE = dataclasses.replace(
    FULL,
    name="whisper-smoke",
    n_layers=2,
    n_enc_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    enc_seq=64,
    frontend_dim=128,
    dec_pos_len=256,
)
