"""mamba2-370m [ssm] -- SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024 (attention-free) vocab=50280, ssm_state=128.
d_inner = 2*d_model = 2048, head_dim 64 -> 32 SSD heads.  O(L) decode makes
long_500k native for this arch.
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    rope_mode="none",
    supports_long_context=True,
    source="arXiv:2405.21060",
)

SMOKE = dataclasses.replace(
    FULL,
    name="mamba2-smoke",
    n_layers=2,
    d_model=128,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=32,
)
