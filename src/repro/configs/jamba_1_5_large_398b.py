"""jamba-1.5-large-398b [hybrid] -- Mamba+attention 1:7 interleave + MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Scanned as 9 super-blocks of [1 attn + 7 mamba] layers, every layer with a
16-expert top-2 MoE MLP.  Mamba layers make long_500k O(L); the 9 attention
layers use a sliding window in long-context serving.
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    attn_every=8,  # 1:7 attn:mamba
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    moe_top_k=2,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    sliding_window=4096,  # attn layers go local in long-context serving
    supports_long_context=True,
    source="arXiv:2403.19887",
)

SMOKE = dataclasses.replace(
    FULL,
    name="jamba-smoke",
    n_layers=4,
    attn_every=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    n_experts=4,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=32,
    sliding_window=64,
)
