"""minitron-8b [dense] -- pruned nemotron [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Full attention -> long_500k skipped.
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minitron-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    source="arXiv:2407.14679",
)

SMOKE = dataclasses.replace(
    FULL,
    name="minitron-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
)
