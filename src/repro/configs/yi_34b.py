"""yi-34b [dense] -- llama-arch GQA [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Full attention -> long_500k skipped.  56 heads do not divide the 16-way
model axis; projections are sharded on the flat H*hd dim (7168 % 16 == 0),
see DESIGN.md Sec. 6.
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="yi-34b",
    arch_type="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)

SMOKE = dataclasses.replace(
    FULL,
    name="yi-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
)
