"""qwen2-vl-7b [vlm] -- M-RoPE, dynamic resolution [arXiv:2409.12191].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
The ViT/projector frontend is a STUB: input_specs supplies precomputed patch
embeddings (B, n_patches, d_model) merged into the first token positions;
M-RoPE rotates with (t, h, w) position triples split (16, 24, 24) across
frequency slots.  Full attention -> long_500k skipped.
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_mode="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    n_patches=1024,  # stub patch-embedding count
    source="arXiv:2409.12191",
)

SMOKE = dataclasses.replace(
    FULL,
    name="qwen2-vl-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    n_patches=16,
    mrope_sections=(4, 6, 6),  # head_dim 32 -> 16 frequency slots
)
