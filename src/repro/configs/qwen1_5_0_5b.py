"""qwen1.5-0.5b [dense] -- QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936.
Full attention -> long_500k skipped.
"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)

SMOKE = dataclasses.replace(
    FULL,
    name="qwen1.5-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
)
