"""Assigned-architecture registry.

Each module defines ``FULL`` (the exact published config, dry-run only) and
``SMOKE`` (a reduced same-family variant: <=2 layers, d_model <= 512,
<=4 experts) that runs a real forward/train step on CPU.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "llama4_maverick_400b_a17b",
    "llama4_scout_17b_16e",
    "mamba2_370m",
    "jamba_1_5_large_398b",
    "gemma_7b",
    "whisper_base",
    "yi_34b",
    "minitron_8b",
    "qwen2_vl_7b",
    "qwen1_5_0_5b",
)

# CLI ids use dashes (as in the assignment table); module names use underscores.
def _norm(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, variant: str = "full") -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch_id)}")
    if variant == "full":
        return mod.FULL
    if variant == "smoke":
        return mod.SMOKE
    raise ValueError(f"unknown variant {variant!r}")


def all_configs(variant: str = "full") -> dict[str, ModelConfig]:
    return {a: get_config(a, variant) for a in ARCH_IDS}
