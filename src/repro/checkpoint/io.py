"""Pytree checkpointing to .npz with a JSON treedef sidecar (no orbax in the
environment).

Two layouts (DESIGN.md Sec. 3):

* **single** (the default):  ``<dir>/step_<N>/arrays.npz + meta.json`` --
  every leaf fully gathered to one host file.  Arbitrary pytrees (flat
  dicts, NamedTuples, nested) round-trip through ``jax.tree_util``
  flattening; bfloat16 leaves are stored as uint16 views with a dtype tag so
  numpy's npz (which lacks bf16) stays lossless.
* **sharded** (round-state checkpoints with a mesh):
  ``<dir>/step_<N>/meta.json + shard_<p>/{arrays.npz, shard.json}`` -- one
  shard file per *process*, written from process-local addressable data
  (``Array.addressable_shards``), so no process ever gathers the full
  client-sharded ``ClientState``.  ``meta.json`` is the manifest: it records
  {layout, n_shards, mesh axis names+shape, per-group treedef/dtypes} and
  restore validates all of it loudly, so a checkpoint taken on one topology
  cannot silently restore onto another.  Replicated history buffers ride in
  every shard file (they are process-local by definition).

Both layouts write into a ``.tmp`` sibling directory and rename into place,
so a preemption mid-write leaves only a ``*.tmp`` directory that
``latest_step`` never matches and resume falls back to the last COMPLETE
checkpoint.

For boundary pipelining, saving is split into ``prepare_round_state`` (ALL
device reads happen here, synchronously, before the caller donates the live
buffers to the next chunk executable) and ``write_round_state`` (pure file
I/O on host numpy arrays -- safe to run on a background thread while the
next chunk computes).  ``AsyncCheckpointWriter`` is the single-worker thread
driving that overlap.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"
_SHARDED_LAYOUT = "sharded-v1"
_POOL_LAYOUT = "pool-v1"


class CorruptCheckpointError(ValueError):
    """A checkpoint step exists but its contents fail an integrity check:
    truncated/unreadable ``arrays.npz``, a zip-member CRC failure (flipped
    bytes), a per-leaf manifest checksum mismatch, or a missing member.
    ``rounds._restore_newest_good`` catches this and falls back to the
    next-older step instead of dying on a torn write."""


def _crc(arr: np.ndarray) -> int:
    """Stable content checksum of one stored (already-tagged) array."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _load_npz(path: str):
    """np.load with corruption mapped to ``CorruptCheckpointError`` (a
    truncated file presents as a bad zip central directory)."""
    try:
        return np.load(path)
    except Exception as e:  # noqa: BLE001 - any load failure = corrupt file
        raise CorruptCheckpointError(f"unreadable arrays file {path!r}: {e}") from e


def _npz_member(data, key: str, path: str) -> np.ndarray:
    """One npz member; zipfile verifies the member CRC on read, so flipped
    payload bytes surface here as ``CorruptCheckpointError``."""
    try:
        return data[key]
    except KeyError as e:
        raise CorruptCheckpointError(f"missing array {key!r} in {path!r}") from e
    except Exception as e:  # noqa: BLE001 - zip CRC / decompress failures
        raise CorruptCheckpointError(f"corrupt array {key!r} in {path!r}: {e}") from e


def _np_tag(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """Tag an ALREADY-host numpy array (no device read)."""
    if str(arr.dtype) == _BF16:
        return arr.view(np.uint16), _BF16
    return arr, str(arr.dtype)


def _to_numpy(x) -> tuple[np.ndarray, str]:
    return _np_tag(np.asarray(jax.device_get(x)))


def _from_numpy(arr: np.ndarray, tag: str):
    if tag == _BF16:
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(arr)


def _np_from_tag(arr: np.ndarray, tag: str) -> np.ndarray:
    """Stored npz entry -> host numpy array with the recorded dtype."""
    if tag == _BF16:
        return arr.view(jnp.bfloat16)  # ml_dtypes bf16 is a numpy dtype
    return arr


def _check_leaf(i: int, got_shape, got_tag: str, want) -> None:
    """Shape AND dtype validation of one restored leaf against the template.

    The docstring of ``restore`` always promised dtype validation; without it
    a leaf saved as bf16 silently restored into an f32 template (the caller
    then mixed precisions downstream).  Fail loudly instead.
    """
    if tuple(got_shape) != tuple(want.shape):
        raise ValueError(
            f"shape mismatch at leaf {i}: checkpoint {tuple(got_shape)} vs "
            f"template {tuple(want.shape)}"
        )
    want_tag = str(want.dtype)
    if got_tag != want_tag:
        raise ValueError(
            f"dtype mismatch at leaf {i}: checkpoint holds {got_tag}, "
            f"template wants {want_tag}"
        )


def _flatten_to_host(tree: Any) -> tuple[dict, dict]:
    """(npz arrays, meta) for one pytree -- the device_get half of a save.

    Deliberately does NOT compute the per-leaf checksums: the snapshot half
    runs on the driver's timed boundary path (``prepare_round_state``),
    while the crc is file-integrity metadata that belongs with the file
    I/O -- ``_with_checksums`` adds it at write time, on the background
    writer thread for async round checkpoints."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays, tags = {}, []
    for i, leaf in enumerate(leaves):
        arr, tag = _to_numpy(leaf)
        arrays[f"leaf_{i}"] = arr
        tags.append(tag)
    meta = {"treedef": str(treedef), "n_leaves": len(leaves), "dtypes": tags}
    return arrays, meta


def _with_checksums(meta: dict, arrays: dict) -> dict:
    """meta + per-leaf content CRCs, ordered ``leaf_0..leaf_{n-1}``."""
    out = dict(meta)
    out["checksums"] = [int(_crc(arrays[f"leaf_{i}"]))
                        for i in range(meta["n_leaves"])]
    return out


def _write_step_dir(path: str, populate: Callable[[str], None]) -> str:
    """Atomic-ish write: populate a ``.tmp`` sibling, then rename into place."""
    tmp = path + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    populate(tmp)
    if os.path.isdir(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def save(path: str, tree: Any, step: int | None = None, extra_meta: dict | None = None) -> str:
    """Atomic-ish save: write into a ``.tmp`` sibling, then rename into
    place.  A preemption mid-write leaves only a ``*.tmp`` directory, which
    ``latest_step`` never matches, so resume falls back to the last COMPLETE
    checkpoint instead of dying on a truncated one."""
    if step is not None:
        path = os.path.join(path, f"step_{step:08d}")
    arrays, meta = _flatten_to_host(tree)
    meta = _with_checksums(meta, arrays)
    if step is not None:
        meta["step"] = step
    if extra_meta:
        meta["extra"] = extra_meta

    def populate(tmp: str) -> None:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)

    return _write_step_dir(path, populate)


def restore(path: str, like: Any, step: int | None = None) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated).

    Integrity is verified end to end: a truncated ``arrays.npz`` or a failed
    zip-member CRC raises ``CorruptCheckpointError``, and when the meta
    records per-leaf ``checksums`` (every checkpoint since they were added)
    each restored leaf's content CRC is re-checked against them."""
    if step is not None:
        path = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    apath = os.path.join(path, "arrays.npz")
    data = _load_npz(apath)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if len(leaves_like) != meta["n_leaves"]:
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, template has {len(leaves_like)}"
        )
    sums = meta.get("checksums")
    leaves = []
    for i, want in enumerate(leaves_like):
        raw, tag = _npz_member(data, f"leaf_{i}", apath), meta["dtypes"][i]
        if sums is not None and _crc(raw) != sums[i]:
            raise CorruptCheckpointError(
                f"checksum mismatch at leaf {i} in {apath!r}"
            )
        got = _np_from_tag(raw, tag)
        _check_leaf(i, got.shape, str(got.dtype), want)
        leaves.append(_from_numpy(raw, tag))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def list_steps(root: str) -> list[int]:
    """All COMPLETE checkpoint step numbers under ``root``, ascending
    (``*.tmp`` directories from torn writes never match)."""
    if not os.path.isdir(root):
        return []
    return sorted(
        int(m.group(1))
        for d in os.listdir(root)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


def save_train_state(root: str, step: int, params, opt_state, metrics: dict | None = None) -> str:
    return save(root, {"params": params, "opt": opt_state}, step=step, extra_meta=metrics)


def restore_train_state(root: str, params_like, opt_like, step: int | None = None):
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    tree = restore(root, {"params": params_like, "opt": opt_like}, step=step)
    return tree["params"], tree["opt"], step


def load_meta(root: str, step: int) -> dict:
    """The meta.json sidecar of one checkpoint (treedef, dtypes, extra).
    Works for both layouts: the sharded manifest IS the step's meta.json."""
    with open(os.path.join(root, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Round-state checkpoints (core/rounds.py): single + per-shard layouts
# ---------------------------------------------------------------------------


def _client_shardings(mesh):
    """(client-sharded, replicated) NamedShardings for round-state trees."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    # deferred import: checkpoint io must not pull the whole algorithm
    # stack in at module import time, but the client-axis definition must
    # stay single-sourced with the engine that wrote the state
    from repro.core.federated import client_axes

    return (NamedSharding(mesh, P(client_axes(mesh))),
            NamedSharding(mesh, P()))


def _local_block(arr: jax.Array) -> tuple[np.ndarray, int, int]:
    """The process-local rows of a leading-axis-sharded array as ONE
    contiguous host block -- reads only ``addressable_shards``, never the
    global array, so no cross-process gather is issued.  Returns
    (block, row_start, row_stop).  Duplicate row ranges (replication across
    a non-client mesh axis) are read once."""
    uniq: dict[tuple[int, int], Any] = {}
    n_rows = arr.shape[0]
    for s in arr.addressable_shards:
        sl = s.index[0] if s.index else slice(None)
        start = sl.start if sl.start is not None else 0
        stop = sl.stop if sl.stop is not None else n_rows
        uniq.setdefault((int(start), int(stop)), s.data)
    spans = sorted(uniq)
    lo, expect, parts = spans[0][0], spans[0][0], []
    for start, stop in spans:
        if start != expect:
            raise ValueError(
                f"addressable shard rows are not contiguous: gap at row {expect} "
                f"(next shard starts at {start}); per-shard checkpointing "
                "assumes block sharding of the client axis"
            )
        parts.append(np.asarray(jax.device_get(uniq[(start, stop)])))
        expect = stop
    block = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
    return block, lo, expect


def _sync(tag: str) -> None:
    """Cross-process barrier; a no-op in single-process runs (the test and
    CPU path).  Multi-process runs order shard writes vs the process-0
    manifest rename through it.

    MUST run on the main thread: ``sync_global_devices`` is a collective,
    and on a multi-process mesh every collective must be issued in the same
    order on every process.  A barrier issued from the async checkpoint
    writer thread races the main thread's round collectives and deadlocks
    the pod, so we refuse loudly instead (``run_rounds`` forces the
    blocking write path on pods for exactly this reason)."""
    if jax.process_count() > 1:
        if threading.current_thread() is not threading.main_thread():
            raise RuntimeError(
                "checkpoint _sync barrier issued off the main thread on a "
                f"multi-process mesh (tag={tag!r}); collectives from the "
                "async writer thread deadlock against the round loop. "
                "Use async_checkpoint=False for distributed per-shard writes."
            )
        from jax.experimental import multihost_utils  # pragma: no cover

        multihost_utils.sync_global_devices(f"repro-ckpt-{tag}")  # pragma: no cover


def prepare_round_state(states, history, mesh=None) -> dict:
    """Host-side snapshot of a round-state checkpoint.

    ALL device reads happen here (synchronously -- the caller is about to
    donate the live buffers to the next chunk executable, so the snapshot
    must complete first); the returned payload is plain numpy + JSON and
    ``write_round_state`` can persist it from a background thread.

    ``mesh=None`` produces the single-file layout.  With a mesh, each
    process reads only its addressable shard of the client-sharded
    ``states`` leaves (no full gather) plus the replicated ``history``.
    """
    if mesh is None:
        arrays, meta = _flatten_to_host({"states": states, "hist": history})
        return {"layout": "single", "arrays": arrays, "meta": meta}

    s_leaves, s_def = jax.tree_util.tree_flatten(states)
    h_leaves, h_def = jax.tree_util.tree_flatten(history)
    arrays: dict[str, np.ndarray] = {}
    s_tags: list[str] = []
    rows: Optional[tuple[int, int]] = None
    for i, leaf in enumerate(s_leaves):
        block, lo, hi = _local_block(leaf)
        arr, tag = _np_tag(block)
        arrays[f"states_{i}"] = arr
        s_tags.append(tag)
        if rows is None:
            rows = (lo, hi)
        elif rows != (lo, hi):
            raise ValueError(
                f"inconsistent addressable rows across states leaves: "
                f"{rows} vs {(lo, hi)} at leaf {i}"
            )
    h_tags: list[str] = []
    for i, leaf in enumerate(h_leaves):
        arr, tag = _to_numpy(leaf)
        arrays[f"hist_{i}"] = arr
        h_tags.append(tag)
    manifest = {
        "layout": _SHARDED_LAYOUT,
        "n_shards": jax.process_count(),
        "mesh": {
            "axis_names": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
        },
        "states": {
            "treedef": str(s_def),
            "n_leaves": len(s_leaves),
            "dtypes": s_tags,
            "global_rows": int(s_leaves[0].shape[0]),
        },
        "hist": {"treedef": str(h_def), "n_leaves": len(h_leaves), "dtypes": h_tags},
    }
    # checksums are added by write_round_state (background thread): the crc
    # is write-time file metadata, not part of the timed boundary snapshot
    shard_meta = {
        "shard": jax.process_index(),
        "row_start": int(rows[0]),
        "row_stop": int(rows[1]),
    }
    return {
        "layout": "sharded",
        "arrays": arrays,
        "manifest": manifest,
        "shard_meta": shard_meta,
    }


def write_round_state(root: str, round_idx: int, payload: dict,
                      extra_meta: dict | None = None) -> str:
    """Persist a ``prepare_round_state`` payload: pure file I/O, no device
    access -- safe on a background thread (``AsyncCheckpointWriter``)."""
    path = os.path.join(root, f"step_{round_idx:08d}")
    if payload["layout"] == "single":
        meta = _with_checksums(payload["meta"], payload["arrays"])
        meta["step"] = round_idx
        if extra_meta:
            meta["extra"] = extra_meta

        def populate(tmp: str) -> None:
            np.savez(os.path.join(tmp, "arrays.npz"), **payload["arrays"])
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)

        return _write_step_dir(path, populate)

    # -- sharded layout: every process writes its own shard dir; process 0
    # writes the manifest and performs the rename after all shards landed.
    tmp = path + ".tmp"
    if jax.process_index() == 0 and os.path.isdir(tmp):
        shutil.rmtree(tmp)
    _sync(f"clean-{round_idx}")
    sdir = os.path.join(tmp, f"shard_{payload['shard_meta']['shard']:05d}")
    os.makedirs(sdir, exist_ok=True)  # exist_ok: concurrent process creation
    np.savez(os.path.join(sdir, "arrays.npz"), **payload["arrays"])
    shard_meta = dict(payload["shard_meta"])
    shard_meta["checksums"] = {k: int(_crc(a))
                               for k, a in payload["arrays"].items()}
    with open(os.path.join(sdir, "shard.json"), "w") as f:
        json.dump(shard_meta, f)
    _sync(f"shards-{round_idx}")
    if jax.process_index() == 0:
        manifest = dict(payload["manifest"])
        manifest["step"] = round_idx
        if extra_meta:
            manifest["extra"] = extra_meta
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
    _sync(f"renamed-{round_idx}")
    return path


def save_round_state(root: str, round_idx: int, states, history,
                     extra_meta: dict | None = None, mesh=None) -> str:
    """Chunk-boundary checkpoint of the scan engine (core/rounds.py):
    the stacked ClientState plus the preallocated SimResult history buffers,
    keyed by the number of completed rounds.  With ``mesh`` the per-shard
    layout is used (see module docstring); without, the single-file one."""
    payload = prepare_round_state(states, history, mesh=mesh)
    return write_round_state(root, round_idx, payload, extra_meta=extra_meta)


def _validate_manifest(meta: dict, mesh) -> None:
    """Loud topology identity check: a sharded checkpoint only restores onto
    the shard count and mesh it was written from."""
    if meta.get("n_shards") != jax.process_count():
        raise ValueError(
            f"sharded checkpoint was written by {meta.get('n_shards')} "
            f"process(es), cannot restore with {jax.process_count()}"
        )
    want = {
        "axis_names": list(mesh.axis_names),
        "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
    }
    if meta.get("mesh") != want:
        raise ValueError(
            f"sharded checkpoint was written on mesh {meta.get('mesh')}, "
            f"cannot restore onto {want}"
        )


def _place_sharded(block: np.ndarray, want, sharding, row_start: int,
                   row_stop: int) -> jax.Array:
    """Place one process-local block directly onto this process's devices
    (``make_array_from_single_device_arrays``) -- the restore-side analogue
    of the gather-free save."""
    gshape = tuple(want.shape)
    per_dev = []
    for dev, idx in sharding.addressable_devices_indices_map(gshape).items():
        sl = idx[0] if idx else slice(None)
        lo = sl.start if sl.start is not None else 0
        hi = sl.stop if sl.stop is not None else gshape[0]
        if lo < row_start or hi > row_stop:
            raise ValueError(
                f"shard file covers rows [{row_start}, {row_stop}) but device "
                f"{dev} wants [{lo}, {hi}); the checkpoint does not match this "
                "process's client placement"
            )
        per_dev.append(jax.device_put(block[lo - row_start : hi - row_start], dev))
    return jax.make_array_from_single_device_arrays(gshape, sharding, per_dev)


def restore_round_state(root: str, states_like, hist_like, step: int | None = None,
                        mesh=None):
    """Inverse of save_round_state; returns (states, history, round_idx).

    Reads the step's meta.json to dispatch on layout, so legacy single-file
    round checkpoints keep restoring (the caller re-shards them); sharded
    checkpoints require ``mesh``, validate the manifest topology, and place
    each process's block straight onto its devices without materializing the
    global state on any host.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    meta = load_meta(root, step)
    if meta.get("layout") != _SHARDED_LAYOUT:
        tree = restore(root, {"states": states_like, "hist": hist_like}, step=step)
        return tree["states"], tree["hist"], step

    if mesh is None:
        raise ValueError(
            f"checkpoint step {step} under {root!r} uses the per-shard layout; "
            "restoring it requires the device mesh it was written on"
        )
    _validate_manifest(meta, mesh)
    cshard, rshard = _client_shardings(mesh)
    path = os.path.join(root, f"step_{step:08d}")
    sdir = os.path.join(path, f"shard_{jax.process_index():05d}")
    with open(os.path.join(sdir, "shard.json")) as f:
        shard_meta = json.load(f)
    apath = os.path.join(sdir, "arrays.npz")
    data = _load_npz(apath)
    row_start, row_stop = shard_meta["row_start"], shard_meta["row_stop"]
    sums = shard_meta.get("checksums") or {}

    def member(key: str) -> np.ndarray:
        raw = _npz_member(data, key, apath)
        if key in sums and _crc(raw) != sums[key]:
            raise CorruptCheckpointError(
                f"checksum mismatch at {key!r} in {apath!r}"
            )
        return raw

    s_like, s_def = jax.tree_util.tree_flatten(states_like)
    if len(s_like) != meta["states"]["n_leaves"]:
        raise ValueError(
            f"checkpoint has {meta['states']['n_leaves']} states leaves, "
            f"template has {len(s_like)}"
        )
    s_leaves = []
    for i, want in enumerate(s_like):
        block = _np_from_tag(member(f"states_{i}"), meta["states"]["dtypes"][i])
        got_shape = (meta["states"]["global_rows"],) + tuple(block.shape[1:])
        _check_leaf(i, got_shape, str(block.dtype), want)
        if block.shape[0] != row_stop - row_start:
            raise ValueError(
                f"shard rows [{row_start}, {row_stop}) disagree with stored "
                f"block of {block.shape[0]} rows at states leaf {i}"
            )
        s_leaves.append(_place_sharded(block, want, cshard, row_start, row_stop))
    states = jax.tree_util.tree_unflatten(s_def, s_leaves)

    h_like, h_def = jax.tree_util.tree_flatten(hist_like)
    if len(h_like) != meta["hist"]["n_leaves"]:
        raise ValueError(
            f"checkpoint has {meta['hist']['n_leaves']} hist leaves, "
            f"template has {len(h_like)}"
        )
    h_leaves = []
    for i, want in enumerate(h_like):
        got = _np_from_tag(member(f"hist_{i}"), meta["hist"]["dtypes"][i])
        _check_leaf(i, got.shape, str(got.dtype), want)
        h_leaves.append(jax.device_put(got, rshard))
    hist = jax.tree_util.tree_unflatten(h_def, h_leaves)
    return states, hist, step


# ---------------------------------------------------------------------------
# Client-pool checkpoints (core/pool.py): host-resident per-shard layout
# ---------------------------------------------------------------------------


def prepare_pool_state(pool_leaves: list[np.ndarray], treedef_str: str,
                       row_start: int, global_rows: int, history) -> dict:
    """Snapshot of a client-pool checkpoint (core/pool.py).

    The pool lives on the HOST (stacked numpy leaves, leading axis = this
    process's rows), so the only device read here is the replicated history.
    The pool leaves are COPIED: the next chunk's scatter mutates them in
    place while the async writer is still serializing the snapshot.  The
    payload reuses the ``step_<N>/shard_<p>`` layout of round-state
    checkpoints (``write_round_state`` persists it unchanged), with
    ``pool_<i>`` array keys and a ``pool-v1`` manifest tag.
    """
    arrays: dict[str, np.ndarray] = {}
    p_tags: list[str] = []
    for i, leaf in enumerate(pool_leaves):
        arr, tag = _np_tag(np.array(leaf, copy=True))
        arrays[f"pool_{i}"] = arr
        p_tags.append(tag)
    h_leaves, h_def = jax.tree_util.tree_flatten(history)
    h_tags: list[str] = []
    for i, leaf in enumerate(h_leaves):
        arr, tag = _to_numpy(leaf)
        arrays[f"hist_{i}"] = arr
        h_tags.append(tag)
    manifest = {
        "layout": _POOL_LAYOUT,
        "n_shards": jax.process_count(),
        "pool": {
            "treedef": treedef_str,
            "n_leaves": len(pool_leaves),
            "dtypes": p_tags,
            "global_rows": int(global_rows),
        },
        "hist": {"treedef": str(h_def), "n_leaves": len(h_leaves), "dtypes": h_tags},
    }
    shard_meta = {
        "shard": jax.process_index(),
        "row_start": int(row_start),
        "row_stop": int(row_start) + (int(pool_leaves[0].shape[0]) if pool_leaves else 0),
    }
    return {
        "layout": "sharded",
        "arrays": arrays,
        "manifest": manifest,
        "shard_meta": shard_meta,
    }


def restore_pool_state(root: str, pool_like: list[np.ndarray], hist_like,
                       step: int | None = None):
    """Inverse of ``prepare_pool_state`` + ``write_round_state``: returns
    (host pool leaves, history, round_idx) for this process's row range.

    Validates the ``pool-v1`` manifest (layout, shard count), per-array
    checksums, and every leaf's shape/dtype against the ``pool_like``
    templates -- the same loud-failure contract as ``restore_round_state``.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    meta = load_meta(root, step)
    if meta.get("layout") != _POOL_LAYOUT:
        raise ValueError(
            f"checkpoint step {step} under {root!r} has layout "
            f"{meta.get('layout')!r}, expected {_POOL_LAYOUT!r} (a client-pool "
            "checkpoint directory must not be shared with round-state runs)"
        )
    if meta.get("n_shards") != jax.process_count():
        raise ValueError(
            f"pool checkpoint was written by {meta.get('n_shards')} "
            f"process(es), cannot restore with {jax.process_count()}"
        )
    path = os.path.join(root, f"step_{step:08d}")
    sdir = os.path.join(path, f"shard_{jax.process_index():05d}")
    with open(os.path.join(sdir, "shard.json")) as f:
        shard_meta = json.load(f)
    apath = os.path.join(sdir, "arrays.npz")
    data = _load_npz(apath)
    sums = shard_meta.get("checksums") or {}

    def member(key: str) -> np.ndarray:
        raw = _npz_member(data, key, apath)
        if key in sums and _crc(raw) != sums[key]:
            raise CorruptCheckpointError(
                f"checksum mismatch at {key!r} in {apath!r}"
            )
        return raw

    if len(pool_like) != meta["pool"]["n_leaves"]:
        raise ValueError(
            f"pool checkpoint has {meta['pool']['n_leaves']} leaves, "
            f"template has {len(pool_like)}"
        )
    local_rows = shard_meta["row_stop"] - shard_meta["row_start"]
    leaves = []
    for i, want in enumerate(pool_like):
        got = _np_from_tag(member(f"pool_{i}"), meta["pool"]["dtypes"][i])
        _check_leaf(i, (local_rows,) + tuple(got.shape[1:]), str(got.dtype), want)
        if got.shape[0] != local_rows:
            raise ValueError(
                f"shard rows [{shard_meta['row_start']}, "
                f"{shard_meta['row_stop']}) disagree with stored block of "
                f"{got.shape[0]} rows at pool leaf {i}"
            )
        leaves.append(np.array(got, copy=True))  # writable, owns its data

    h_like, h_def = jax.tree_util.tree_flatten(hist_like)
    if len(h_like) != meta["hist"]["n_leaves"]:
        raise ValueError(
            f"pool checkpoint has {meta['hist']['n_leaves']} hist leaves, "
            f"template has {len(h_like)}"
        )
    h_leaves = []
    for i, want in enumerate(h_like):
        raw, tag = member(f"hist_{i}"), meta["hist"]["dtypes"][i]
        got = _np_from_tag(raw, tag)
        _check_leaf(i, got.shape, str(got.dtype), want)
        h_leaves.append(_from_numpy(raw, tag))
    hist = jax.tree_util.tree_unflatten(h_def, h_leaves)
    return leaves, hist, step


class AsyncCheckpointWriter:
    """Single-worker background writer for chunk-boundary checkpoints.

    At most one write is in flight: ``submit`` joins the previous write
    first (so the steady-state boundary cost is the host snapshot only,
    never two stacked writes) and re-raises any error the previous write
    hit -- a failing checkpoint must fail the run, not be swallowed by a
    daemon thread.  ``wait()`` drains the writer; the driver calls it before
    returning so the final checkpoint is durable when ``run_rounds`` exits.

    TRANSIENT I/O errors (``OSError``: a flaky network filesystem, a brief
    ENOSPC) are retried on the writer thread with capped exponential backoff
    (``retries`` extra attempts, ``backoff_s`` doubling up to
    ``max_backoff_s``); only the final failure surfaces.  Non-I/O errors
    are never retried.
    """

    def __init__(self, retries: int = 2, backoff_s: float = 0.1,
                 max_backoff_s: float = 2.0) -> None:
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._retries = retries
        self._backoff_s = backoff_s
        self._max_backoff_s = max_backoff_s

    def _run(self, fn: Callable[[], Any]) -> None:
        delay, attempt = self._backoff_s, 0
        while True:
            try:
                fn()
                return
            except OSError as e:
                if attempt >= self._retries:
                    self._error = e  # re-raised on the main thread
                    return
                attempt += 1
                time.sleep(min(delay, self._max_backoff_s))
                delay *= 2
            except BaseException as e:  # noqa: BLE001 - re-raised on the main thread
                self._error = e
                return

    def submit(self, fn: Callable[[], Any]) -> None:
        self.wait()
        self._thread = threading.Thread(
            target=self._run, args=(fn,), name="repro-ckpt-writer", daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
