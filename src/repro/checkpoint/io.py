"""Pytree checkpointing to .npz with a JSON treedef sidecar (no orbax in the
environment).

Layout:  <dir>/step_<N>/arrays.npz + meta.json
Arbitrary pytrees (flat dicts, NamedTuples, nested) round-trip through
``jax.tree_util`` flattening; bfloat16 leaves are stored as uint16 views with
a dtype tag so numpy's npz (which lacks bf16) stays lossless.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(x))
    if str(arr.dtype) == _BF16:
        return arr.view(np.uint16), _BF16
    return arr, str(arr.dtype)


def _from_numpy(arr: np.ndarray, tag: str):
    if tag == _BF16:
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(arr)


def save(path: str, tree: Any, step: int | None = None, extra_meta: dict | None = None) -> str:
    """Atomic-ish save: write into a ``.tmp`` sibling, then rename into
    place.  A preemption mid-write leaves only a ``*.tmp`` directory, which
    ``latest_step`` never matches, so resume falls back to the last COMPLETE
    checkpoint instead of dying on a truncated one."""
    if step is not None:
        path = os.path.join(path, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays, tags = {}, []
    for i, leaf in enumerate(leaves):
        arr, tag = _to_numpy(leaf)
        arrays[f"leaf_{i}"] = arr
        tags.append(tag)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"treedef": str(treedef), "n_leaves": len(leaves), "dtypes": tags}
    if step is not None:
        meta["step"] = step
    if extra_meta:
        meta["extra"] = extra_meta
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.isdir(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def restore(path: str, like: Any, step: int | None = None) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    if step is not None:
        path = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if len(leaves_like) != meta["n_leaves"]:
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, template has {len(leaves_like)}"
        )
    leaves = [
        _from_numpy(data[f"leaf_{i}"], meta["dtypes"][i]) for i in range(meta["n_leaves"])
    ]
    for got, want in zip(leaves, leaves_like):
        if tuple(got.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch {got.shape} vs {want.shape}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(root)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def save_train_state(root: str, step: int, params, opt_state, metrics: dict | None = None) -> str:
    return save(root, {"params": params, "opt": opt_state}, step=step, extra_meta=metrics)


def restore_train_state(root: str, params_like, opt_like, step: int | None = None):
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    tree = restore(root, {"params": params_like, "opt": opt_like}, step=step)
    return tree["params"], tree["opt"], step


def load_meta(root: str, step: int) -> dict:
    """The meta.json sidecar of one checkpoint (treedef, dtypes, extra)."""
    with open(os.path.join(root, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)


def save_round_state(root: str, round_idx: int, states, history,
                     extra_meta: dict | None = None) -> str:
    """Chunk-boundary checkpoint of the scan engine (core/rounds.py):
    the stacked ClientState plus the preallocated SimResult history buffers,
    keyed by the number of completed rounds."""
    return save(root, {"states": states, "hist": history}, step=round_idx,
                extra_meta=extra_meta)


def restore_round_state(root: str, states_like, hist_like, step: int | None = None):
    """Inverse of save_round_state; returns (states, history, round_idx)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    tree = restore(root, {"states": states_like, "hist": hist_like}, step=step)
    return tree["states"], tree["hist"], step
