from repro.checkpoint.io import (  # noqa: F401
    latest_step,
    restore,
    restore_round_state,
    restore_train_state,
    save,
    save_round_state,
    save_train_state,
)
