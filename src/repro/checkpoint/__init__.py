from repro.checkpoint.io import (  # noqa: F401
    latest_step,
    restore,
    restore_train_state,
    save,
    save_train_state,
)
