from repro.checkpoint.io import (  # noqa: F401
    AsyncCheckpointWriter,
    latest_step,
    prepare_round_state,
    restore,
    restore_round_state,
    restore_train_state,
    save,
    save_round_state,
    save_train_state,
    write_round_state,
)
