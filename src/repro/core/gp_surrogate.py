"""Trajectory-informed derived-GP gradient surrogates (paper Sec. 4.1, eq. 4-5).

Every client keeps the history of its own function queries (the *optimization
trajectory*).  Under the paper's assumption ``f_i ~ GP(mu, k)`` with a
shift-invariant kernel, the gradient follows a *derived* posterior GP whose mean

    grad_mu(x) = d_x k(x, X)^T (K + sigma^2 I)^{-1} y            (eq. 5)

is used as the local gradient surrogate, and whose covariance at ``x``

    d_sigma2(x) = d_x d_x' k|_{x,x} - d_x k(x,X)^T (K+s^2 I)^{-1} d_x' k(X,x)

provides the uncertainty measure driving active queries (Thm. 1 terms (1)/(2)).

Implementation notes (hardware adaptation, see DESIGN.md Sec. 2):

* The trajectory grows during optimization, which would force re-tracing under
  JIT.  We therefore keep a **fixed-capacity ring buffer** with a validity mask;
  the padded Gram system is block-diagonal ``[K_n + s^2 I, I]`` so the masked
  Cholesky solve returns *exactly* the un-padded answer (property-tested).
* The paper keeps the full trajectory; for long runs the ring buffer keeps the
  most recent ``capacity`` queries.  Appx. C.3 of the paper shows distant
  queries are uninformative for the surrogate at the current iterate, so a
  recency window is the faithful finite-memory realization.
* All hot math below is pure jnp; the TPU Pallas kernels in
  ``repro.kernels`` implement the same contractions with explicit VMEM tiling
  and are validated against these functions.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Trajectory(NamedTuple):
    """Fixed-capacity ring buffer of (x, y) function queries."""

    xs: jax.Array  # (capacity, d)
    ys: jax.Array  # (capacity,)
    count: jax.Array  # () int32 -- total number of appends (may exceed capacity)

    @property
    def capacity(self) -> int:
        return self.xs.shape[0]

    @property
    def dim(self) -> int:
        return self.xs.shape[1]

    def n_valid(self) -> jax.Array:
        return jnp.minimum(self.count, self.capacity)

    def valid_mask(self) -> jax.Array:
        return (jnp.arange(self.capacity) < self.n_valid()).astype(self.xs.dtype)


def traj_init(capacity: int, dim: int, dtype=jnp.float32) -> Trajectory:
    return Trajectory(
        xs=jnp.zeros((capacity, dim), dtype),
        ys=jnp.zeros((capacity,), dtype),
        count=jnp.zeros((), jnp.int32),
    )


def traj_append(traj: Trajectory, x: jax.Array, y: jax.Array) -> Trajectory:
    """Append one query; overwrites the oldest entry when full."""
    idx = jnp.mod(traj.count, traj.capacity)
    xs = jax.lax.dynamic_update_slice(traj.xs, x[None, :].astype(traj.xs.dtype), (idx, 0))
    ys = jax.lax.dynamic_update_slice(traj.ys, jnp.reshape(y, (1,)).astype(traj.ys.dtype), (idx,))
    return Trajectory(xs=xs, ys=ys, count=traj.count + 1)


def traj_append_batch(traj: Trajectory, xs: jax.Array, ys: jax.Array) -> Trajectory:
    """Append a batch of queries as ONE masked scatter (batch size is static).

    Semantically identical to folding ``traj_append`` over the rows (later
    rows win when the batch itself wraps the ring), but issues a single
    scatter instead of a length-k chain of ``dynamic_update_slice`` calls --
    this sits on the same per-step hot path as the Gram-factor cache.
    """
    k = xs.shape[0]
    cap = traj.capacity
    total = traj.count + k
    if k > cap:
        # Only the last `cap` rows survive a full wrap; slicing keeps every
        # write index distinct so the scatter stays order-independent.
        xs, ys = xs[k - cap :], ys[k - cap :]
        offset = k - cap
        k_eff = cap
    else:
        offset = 0
        k_eff = k
    idx = jnp.mod(traj.count + offset + jnp.arange(k_eff), cap)
    new_xs = traj.xs.at[idx].set(xs.astype(traj.xs.dtype))
    new_ys = traj.ys.at[idx].set(ys.astype(traj.ys.dtype))
    return Trajectory(xs=new_xs, ys=new_ys, count=total)


# ---------------------------------------------------------------------------
# Squared-exponential kernel and its derivatives (Appx. B kernel choice).
# ---------------------------------------------------------------------------


def sqexp(x1: jax.Array, x2: jax.Array, lengthscale: float) -> jax.Array:
    """k(X1, X2) pairwise SE kernel.  x1: (n,d)  x2: (m,d) -> (n,m)."""
    d2 = pairwise_sqdist(x1, x2)
    return jnp.exp(-0.5 * d2 / (lengthscale**2))


def pairwise_sqdist(x1: jax.Array, x2: jax.Array) -> jax.Array:
    n1 = jnp.sum(x1 * x1, axis=-1)
    n2 = jnp.sum(x2 * x2, axis=-1)
    cross = x1 @ x2.T
    d2 = n1[:, None] + n2[None, :] - 2.0 * cross
    return jnp.maximum(d2, 0.0)


def dkdx(x: jax.Array, xs: jax.Array, lengthscale: float) -> jax.Array:
    """d_x k(x, X) for the SE kernel.

    x: (d,), xs: (n, d) -> (n, d) with row tau = -(x - x_tau)/l^2 * k(x, x_tau).
    """
    diff = x[None, :] - xs  # (n, d)
    k = jnp.exp(-0.5 * jnp.sum(diff * diff, axis=-1) / (lengthscale**2))  # (n,)
    return (-diff / (lengthscale**2)) * k[:, None]


class GPHyper(NamedTuple):
    lengthscale: jax.Array  # ()
    noise: jax.Array  # () observation noise variance sigma^2


def default_hyper(lengthscale: float = 1.0, noise: float = 1e-4) -> GPHyper:
    return GPHyper(jnp.asarray(lengthscale, jnp.float32), jnp.asarray(noise, jnp.float32))


def _jitter_of(hyper: GPHyper) -> jax.Array:
    return jnp.maximum(hyper.noise, 1e-4)


def _padded_gram(traj: Trajectory, hyper: GPHyper) -> tuple[jax.Array, jax.Array]:
    """Padded Gram system [K_n + s^2 I, I] and the validity mask.

    Invalid rows/cols are zeroed and their diagonal set to 1, so the solve on
    masked targets is exactly the solve of the live n x n system.
    """
    mask = traj.valid_mask()  # (cap,)
    k = sqexp(traj.xs, traj.xs, hyper.lengthscale)
    m2 = mask[:, None] * mask[None, :]
    jitter = _jitter_of(hyper)
    gram = k * m2 + jnp.diag(jitter * mask + (1.0 - mask))
    return gram, mask


def _masked_gram_chol(traj: Trajectory, hyper: GPHyper) -> tuple[tuple[jax.Array, jax.Array], jax.Array]:
    """Eigh factorization of the padded Gram system.

    Float32 + clustered active queries make the Gram numerically indefinite
    -- a trajectory full of points within the 0.01 active-query ball produced
    NaN Cholesky pivots in practice -- so we factor with eigh and CLAMP the
    spectrum at the jitter floor: a principled pseudo-solve that never
    explodes (capacity <= a few hundred, so the O(cap^3) is negligible).
    Returns ((eigvecs, eigvals), mask).

    This is the from-scratch ORACLE; the per-step hot path uses the
    incrementally maintained ``GramFactor`` below (DESIGN.md Sec. 2).
    """
    gram, mask = _padded_gram(traj, hyper)
    jitter = _jitter_of(hyper)
    w, v = jnp.linalg.eigh(gram)
    w = jnp.maximum(w, jitter)
    return (v, w), mask


def _gram_solve(factors: tuple[jax.Array, jax.Array], b: jax.Array) -> jax.Array:
    """(K+jitter)^-1 b via the clamped eigh factors.  b: (cap,) or (cap, d)."""
    v, w = factors
    vb = v.T @ b
    if b.ndim == 1:
        return v @ (vb / w)
    return v @ (vb / w[:, None])


def gp_alpha(traj: Trajectory, hyper: GPHyper) -> jax.Array:
    """alpha = (K + s^2 I)^{-1} y with masking.  (capacity,)"""
    factors, mask = _masked_gram_chol(traj, hyper)
    return _gram_solve(factors, traj.ys * mask)


def grad_mean(traj: Trajectory, hyper: GPHyper, x: jax.Array, alpha: jax.Array | None = None) -> jax.Array:
    """Posterior gradient mean  grad_mu(x)  (eq. 5).  x: (d,) -> (d,)."""
    if alpha is None:
        alpha = gp_alpha(traj, hyper)
    j = dkdx(x, traj.xs, hyper.lengthscale) * traj.valid_mask()[:, None]  # (cap, d)
    return j.T @ alpha


def grad_mean_batch(traj: Trajectory, hyper: GPHyper, xs: jax.Array) -> jax.Array:
    alpha = gp_alpha(traj, hyper)
    return jax.vmap(lambda x: grad_mean(traj, hyper, x, alpha))(xs)


def grad_uncertainty_trace(traj: Trajectory, hyper: GPHyper, x: jax.Array, chol_mask=None) -> jax.Array:
    """tr d_sigma2(x) -- the uncertainty score used for active queries.

    For the SE kernel  d_x d_x' k|_{x=x'} = I / l^2, so the prior trace is
    d / l^2 and the data correction is  sum_ij J A^{-1} J  with
    J = d_x k(x, X).  Trace is the cheap principled surrogate for the matrix
    norm in Thm. 1 (it upper-bounds the spectral norm up to d and preserves
    the ranking used to select active queries).
    """
    if chol_mask is None:
        factors, mask = _masked_gram_chol(traj, hyper)
    else:
        factors, mask = chol_mask
    d = x.shape[-1]
    j = dkdx(x, traj.xs, hyper.lengthscale) * mask[:, None]  # (cap, d)
    sol = _gram_solve(factors, j)  # (cap, d)
    prior = d / (hyper.lengthscale**2)
    corr = jnp.sum(j * sol)
    return jnp.maximum(prior - corr, 0.0)


def grad_uncertainty_batch(traj: Trajectory, hyper: GPHyper, xs: jax.Array) -> jax.Array:
    cm = _masked_gram_chol(traj, hyper)
    return jax.vmap(lambda x: grad_uncertainty_trace(traj, hyper, x, cm))(xs)


def select_active_queries(
    key: jax.Array,
    traj: Trajectory,
    hyper: GPHyper,
    center: jax.Array,
    n_candidates: int,
    n_select: int,
    radius: float,
    lo: float = 0.0,
    hi: float = 1.0,
) -> jax.Array:
    """Paper Appx. E general settings: sample ``n_candidates`` points
    uniformly in ``center +- radius``, return the ``n_select`` with the
    highest gradient-surrogate uncertainty.  -> (n_select, d)
    """
    d = center.shape[-1]
    delta = jax.random.uniform(key, (n_candidates, d), minval=-radius, maxval=radius)
    cands = jnp.clip(center[None, :] + delta, lo, hi)
    scores = grad_uncertainty_batch(traj, hyper, cands)
    _, top = jax.lax.top_k(scores, n_select)
    return cands[top]


def mean_value(traj: Trajectory, hyper: GPHyper, x: jax.Array) -> jax.Array:
    """Plain GP posterior mean of f itself (used in tests/benchmarks)."""
    alpha = gp_alpha(traj, hyper)
    kvec = sqexp(x[None, :], traj.xs, hyper.lengthscale)[0] * traj.valid_mask()
    return kvec @ alpha


# ---------------------------------------------------------------------------
# Incremental Gram-factor cache (DESIGN.md Sec. 2).
#
# The seed implementation refactorized the padded Gram system from scratch --
# an O(cap^3) eigh with iterative-QR constants -- at EVERY surrogate
# evaluation: once inside active-query scoring and once for the gradient
# estimate, i.e. twice per local step per client.  A step only appends
# ``1 + active_per_iter`` rows to the ring buffer, so the factorization is
# now carried in ``ClientState`` and maintained incrementally:
#
#   * the padded Gram MATRIX is updated by exact row/col replacement,
#     O(k * cap * d) per append event instead of O(cap^2 * d) rebuilds;
#   * while the buffer is still filling, the Cholesky factor is extended by
#     BORDERING: one triangular solve + a k x k factorization, O(cap^2 * k);
#   * once the ring wraps, row replacement invalidates trailing columns of
#     the factor, and the factor is refreshed with ONE blocked potrf of the
#     updated Gram.  That is O(cap^3 / 3) with LAPACK-grade constants --
#     measured ~8x cheaper than a single eigh at cap=128 -- and, unlike
#     hyperbolic-rotation cholupdate chains (implemented below, and
#     benchmarked slower on CPU because the column recurrence serializes),
#     it is a single fused XLA op with zero drift: every refresh factors the
#     true current Gram;
#   * if any live Cholesky pivot dips below the jitter floor (clustered
#     active queries can make the f32 Gram numerically indefinite), we fall
#     back to the seed's full clamped-eigh refactorization and KEEP the eigh
#     factors, so the pseudo-solve in that regime is identical to the
#     from-scratch oracle.  This preserves the NaN-robustness guarantee.
# ---------------------------------------------------------------------------

#: A live pivot below ``PIVOT_FLOOR_SCALE * sqrt(jitter)`` triggers the
#: clamped-eigh fallback.  sqrt(jitter) is the exact-arithmetic lower bound
#: for live pivots of the padded system, so 0.5x flags only genuine f32
#: indefiniteness, not the benign rounding of pivots sitting AT the floor.
PIVOT_FLOOR_SCALE = 0.5


class GramFactor(NamedTuple):
    """Cached factorization state of the padded Gram system.

    ``chol`` is the lower Cholesky factor of ``gram`` whenever ``exact`` is
    True.  After a clamped-eigh fallback ``exact`` is False and solves route
    through ``(eigvecs, eigvals)`` -- the clamped spectrum -- instead; the
    next append event always refreshes from ``gram`` directly, so inexact
    factors never compound.

    ``needs_repair`` is the deferred-repair flag (DESIGN.md Sec. 2.6): the
    branch-free update path (``factor_update_deferred``) never eigh-repairs
    inline.  An unhealthy candidate factor raises the flag and FREEZES the
    factor -- solves keep routing through the last-good factors -- until the
    chunk-boundary repair pass (``factor_repair_masked`` /
    ``core.rounds.repair_flagged_clients``) refactorizes the exact cached
    Gram.  The inline path (``factor_update``) never sets it.
    """

    gram: jax.Array  # (cap, cap) padded Gram matrix (always exact)
    chol: jax.Array  # (cap, cap) lower Cholesky factor (valid iff exact)
    eigvecs: jax.Array  # (cap, cap) fallback eigh factors (valid iff not exact)
    eigvals: jax.Array  # (cap,) clamped spectrum (valid iff not exact)
    exact: jax.Array  # () bool -- solve route selector
    n_updates: jax.Array  # () int32 incremental append events applied
    n_refactors: jax.Array  # () int32 clamped-eigh fallbacks/repairs taken
    needs_repair: jax.Array  # () bool -- deferred-repair flag (frozen factors)


def _factor_health(chol: jax.Array, mask: jax.Array, jitter: jax.Array) -> jax.Array:
    """True when every live pivot is finite and above the pivot floor."""
    floor = PIVOT_FLOOR_SCALE * jnp.sqrt(jitter)
    diag = jnp.diagonal(chol)
    live_diag = jnp.where(mask > 0, diag, 1.0)
    return jnp.isfinite(chol).all() & (live_diag >= floor).all()


def _clamped_eigh(gram: jax.Array, jitter: jax.Array) -> tuple[jax.Array, jax.Array]:
    w, v = jnp.linalg.eigh(gram)
    return v, jnp.maximum(w, jitter)


def factor_init(traj: Trajectory, hyper: GPHyper) -> GramFactor:
    """Build the factor cache from scratch (once per client, at init)."""
    gram, mask = _padded_gram(traj, hyper)
    jitter = _jitter_of(hyper)
    chol = jnp.linalg.cholesky(gram)
    ok = _factor_health(chol, mask, jitter)

    def fallback(_):
        return _clamped_eigh(gram, jitter)

    def keep(_):
        cap = gram.shape[0]
        return jnp.eye(cap, dtype=gram.dtype), jnp.ones((cap,), gram.dtype)

    v, w = jax.lax.cond(ok, keep, fallback, None)
    return GramFactor(
        gram=gram,
        chol=jnp.where(ok, chol, jnp.eye(gram.shape[0], dtype=gram.dtype)),
        eigvecs=v,
        eigvals=w,
        exact=ok,
        n_updates=jnp.zeros((), jnp.int32),
        n_refactors=(~ok).astype(jnp.int32),
        needs_repair=jnp.zeros((), bool),
    )


def _border_extend(
    chol: jax.Array, gram: jax.Array, start: jax.Array, k: int, jitter: jax.Array
) -> jax.Array:
    """Extend a Cholesky factor by k contiguous appended rows (no wrap).

    Rows ``start .. start+k-1`` of ``gram`` are newly valid; rows at and
    beyond ``start`` of ``chol`` are still identity (the invalid-slot
    padding), so the bordered update is one masked triangular solve plus a
    k x k factorization -- O(cap^2 * k), no refactorization.
    """
    cap = chol.shape[0]
    cols = jax.lax.dynamic_slice(gram, (0, start), (cap, k))  # (cap, k)
    prefix = (jnp.arange(cap) < start).astype(cols.dtype)[:, None]
    # Invalid rows of `chol` are e_i, so zeroing their rhs keeps z supported
    # on the live prefix: the full-size solve equals the p x p solve.
    z = jax.scipy.linalg.solve_triangular(chol, cols * prefix, lower=True)  # (cap, k)
    c22 = jax.lax.dynamic_slice(gram, (start, start), (k, k))
    s = c22 - z.T @ z
    ls = jnp.linalg.cholesky(s)  # (k, k) lower; NaN here -> health check fails
    rows = z.T * prefix.T  # (k, cap) -- left border, zero at/after `start`
    rows = jax.lax.dynamic_update_slice(rows, ls, (0, start))
    return jax.lax.dynamic_update_slice(chol, rows, (start, 0))


def chol_rank1_update(chol: jax.Array, x: jax.Array, sign: float, floor: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Rank-1 Cholesky update (+1) / downdate (-1) via hyperbolic rotations.

    Returns (L', ok) where ok is False if any pivot fell below ``floor``;
    on failure L' is unusable by contract (callers refactor).  O(cap^2) but a
    length-cap SEQUENTIAL column recurrence -- measured slower than one
    blocked potrf at cap=128 on CPU (see benchmarks/kernels_bench.py), which
    is why the hot path refreshes with potrf instead.  Kept as the textbook
    O(cap^2) row-replace primitive and validated against refactorization.
    """
    n = chol.shape[0]
    floor2 = floor * floor

    def body(k, carry):
        L, x, ok = carry
        lkk = L[k, k]
        xk = x[k]
        r2 = lkk * lkk + sign * xk * xk
        ok = ok & (r2 > floor2)
        r = jnp.sqrt(jnp.maximum(r2, floor2))
        c = r / lkk
        s = xk / lkk
        below = jnp.arange(n) > k
        col = L[:, k]
        newcol = jnp.where(below, (col + sign * s * x) / c, col).at[k].set(r)
        xnew = jnp.where(below, c * x - s * newcol, x)
        return L.at[:, k].set(newcol), xnew, ok

    L, _, ok = jax.lax.fori_loop(0, n, body, (chol, x, jnp.asarray(True)))
    return L, ok


def _gram_replace_rows(
    factor: GramFactor,
    traj_new: Trajectory,
    hyper: GPHyper,
    k: int,
    old_count: jax.Array,
) -> jax.Array:
    """Exact incremental row/col replacement of the padded Gram: O(k*cap*d)."""
    cap = traj_new.capacity
    jitter = _jitter_of(hyper)
    mask = traj_new.valid_mask()
    idx = jnp.mod(old_count + jnp.arange(k), cap)  # replaced slots
    xb = traj_new.xs[idx]  # (k, d)
    rows = sqexp(xb, traj_new.xs, hyper.lengthscale) * mask[None, :]
    rows = rows.at[jnp.arange(k), idx].add(jitter)  # live diagonal = 1 + jitter
    gram = factor.gram.at[idx, :].set(rows)
    return gram.at[:, idx].set(rows.T)


def factor_update(
    factor: GramFactor,
    traj_new: Trajectory,
    hyper: GPHyper,
    k: int,
    old_count: jax.Array,
) -> GramFactor:
    """Maintain the factor cache across one append event of k rows.

    ``traj_new`` must be ``traj_append_batch(traj_old, ...)`` with a static
    batch size ``k <= capacity``; ``old_count`` is ``traj_old.count``.
    """
    cap = traj_new.capacity
    if k > cap:
        raise ValueError(f"append event of {k} rows exceeds capacity {cap}")
    jitter = _jitter_of(hyper)
    mask = traj_new.valid_mask()
    gram = _gram_replace_rows(factor, traj_new, hyper, k, old_count)

    # --- factor maintenance: border while filling, blocked refresh after wrap
    fits = old_count + k <= cap

    def border(_):
        return _border_extend(factor.chol, gram, old_count, k, jitter)

    def refresh(_):
        return jnp.linalg.cholesky(gram)

    chol = jax.lax.cond(fits & factor.exact, border, refresh, None)
    ok = _factor_health(chol, mask, jitter)

    # --- spectral-clamp fallback: identical to the from-scratch oracle
    def fallback(_):
        return _clamped_eigh(gram, jitter)

    def keep(_):
        return factor.eigvecs, factor.eigvals

    v, w = jax.lax.cond(ok, keep, fallback, None)
    return GramFactor(
        gram=gram,
        chol=jnp.where(ok, chol, jnp.eye(cap, dtype=gram.dtype)),
        eigvecs=v,
        eigvals=w,
        exact=ok,
        n_updates=factor.n_updates + 1,
        n_refactors=factor.n_refactors + (~ok).astype(jnp.int32),
        needs_repair=jnp.zeros((), bool),
    )


def factor_update_deferred(
    factor: GramFactor,
    traj_new: Trajectory,
    hyper: GPHyper,
    k: int,
    old_count: jax.Array,
) -> GramFactor:
    """Branch-free Cholesky-only factor maintenance: NO eigh, ever.

    Same inputs/contract as ``factor_update``, but the rare unhealthy case
    no longer falls back to the clamped-eigh refactorization inline (under a
    client vmap ``lax.cond`` computes both branches, so the inline fallback
    costs one O(cap^3) eigh per client per append event whether taken or
    not).  Instead:

      * a healthy candidate factor (border pre-wrap, blocked potrf refresh
        post-wrap) is adopted as before;
      * an unhealthy candidate raises ``needs_repair`` and the factor
        FREEZES: solves keep routing through the last-good factors (the
        stale Cholesky factor when ``exact``, the retained eigh factors
        otherwise) via the same masked selection ``factor_solve`` already
        uses.  The cached Gram keeps its exact row/col updates, so nothing
        is lost -- the repair pass refactorizes it whole;
      * a flagged factor adopts NOTHING until ``factor_repair_masked``
        (driven at chunk boundaries by ``core.rounds.repair_flagged_clients``)
        clears the flag with one batched clamped-eigh over the flagged
        clients -- amortizing the eigh from per-step-per-client to
        per-chunk-per-flagged-client.

    Inexact factors still never compound: the first update after a repair
    refreshes from the (always-exact) cached Gram, exactly like the inline
    path.
    """
    cap = traj_new.capacity
    if k > cap:
        raise ValueError(f"append event of {k} rows exceeds capacity {cap}")
    jitter = _jitter_of(hyper)
    mask = traj_new.valid_mask()
    gram = _gram_replace_rows(factor, traj_new, hyper, k, old_count)

    fits = old_count + k <= cap
    use_border = fits & factor.exact & ~factor.needs_repair

    # Border vs blocked refresh under lax.cond: the unbatched per-device path
    # skips the untaken O(cap^3/3) potrf; under a client vmap both candidates
    # are computed and masked -- still no eigh anywhere in the graph.
    cand = jax.lax.cond(
        use_border,
        lambda: _border_extend(factor.chol, gram, old_count, k, jitter),
        lambda: jnp.linalg.cholesky(gram),
    )
    ok = _factor_health(cand, mask, jitter)
    adopt = ok & ~factor.needs_repair
    return GramFactor(
        gram=gram,
        chol=jnp.where(adopt, cand, factor.chol),
        eigvecs=factor.eigvecs,
        eigvals=factor.eigvals,
        exact=jnp.where(adopt, True, factor.exact),
        n_updates=factor.n_updates + 1,
        n_refactors=factor.n_refactors,  # repairs are counted at the boundary
        needs_repair=factor.needs_repair | ~ok,
    )


def factor_repair_masked(factor: GramFactor, jitter: jax.Array) -> GramFactor:
    """Clamped-eigh repair of flagged clients over a STACKED factor batch.

    ``factor`` leaves carry a leading client axis.  One batched eigh of the
    exact cached Grams; only flagged clients adopt the new (clamped) eigh
    factors -- identical to the inline fallback's pseudo-solve -- and drop
    their flag.  Runs under jit/shard_map with no collectives, so the
    distributed engine repairs per-shard.  (The vmap front door gathers the
    flagged subset on the host first -- see ``core.rounds`` -- so the eigh
    batch really is flagged-clients-only there.)
    """
    w, v = jnp.linalg.eigh(factor.gram)
    w = jnp.maximum(w, jitter)
    flag = factor.needs_repair  # (N,)
    fv = flag[:, None, None]
    return factor._replace(
        eigvecs=jnp.where(fv, v.astype(factor.eigvecs.dtype), factor.eigvecs),
        eigvals=jnp.where(flag[:, None], w.astype(factor.eigvals.dtype), factor.eigvals),
        exact=jnp.where(flag, False, factor.exact),
        n_refactors=factor.n_refactors + flag.astype(jnp.int32),
        needs_repair=jnp.zeros_like(flag),
    )


def factor_repair_gated(factor: GramFactor, jitter: jax.Array) -> GramFactor:
    """``factor_repair_masked`` behind a DEVICE-side flag-count gate.

    ``factor`` leaves carry a leading client axis.  The repair decision is
    made on device -- ``lax.cond`` on the scalar count of raised
    ``needs_repair`` flags -- so the caller never reads the flag vector to
    host: the all-healthy boundary (the measured ~1.0 case) costs one O(N)
    reduction and the untaken batched-eigh branch is skipped at runtime
    (the cond predicate is unbatched).  This is the zero-host-sync chunk
    boundary of DESIGN.md Sec. 3; ``core.rounds.repair_flagged_clients``
    keeps the host-read decision as the loop-driver oracle.
    """
    n_flagged = jnp.sum(factor.needs_repair.astype(jnp.int32))
    return jax.lax.cond(
        n_flagged > 0,
        lambda: factor_repair_masked(factor, jitter),
        lambda: factor,
    )


def traj_extend(
    traj: Trajectory,
    factor: GramFactor,
    xs: jax.Array,
    ys: jax.Array,
    hyper: GPHyper,
    deferred: bool = False,
) -> tuple[Trajectory, GramFactor]:
    """Append a (static-size) batch of queries and maintain the factor.

    ``deferred=True`` selects the branch-free Cholesky-only update
    (``factor_update_deferred``); the default keeps the inline clamped-eigh
    fallback as the equivalence oracle.
    """
    old_count = traj.count
    traj2 = traj_append_batch(traj, xs, ys)
    upd = factor_update_deferred if deferred else factor_update
    return traj2, upd(factor, traj2, hyper, xs.shape[0], old_count)


def factor_solve(factor: GramFactor, b: jax.Array) -> jax.Array:
    """(K + jitter)^-1 b through the cached factors.  b: (cap,) or (cap, m).

    Routes through the Cholesky factor in the exact regime and through the
    clamped-eigh factors after a fallback.  ``lax.cond`` lets the unbatched
    (per-device / benchmark) path skip the untaken branch entirely; under a
    client vmap the cond degenerates to computing both O(cap^2) branches,
    which is still far below one eigh.
    """
    return jax.lax.cond(
        factor.exact,
        lambda: jax.scipy.linalg.cho_solve((factor.chol, True), b),
        lambda: _gram_solve((factor.eigvecs, factor.eigvals), b),
    )


def factor_inverse(factor: GramFactor) -> jax.Array:
    """Explicit (K + jitter)^-1 -- feeds the fused candidate-scoring kernel."""
    eye = jnp.eye(factor.gram.shape[0], dtype=factor.gram.dtype)

    def from_chol():
        return jax.scipy.linalg.cho_solve((factor.chol, True), eye)

    def from_eigh():
        v, w = factor.eigvecs, factor.eigvals
        return (v / w[None, :]) @ v.T

    return jax.lax.cond(factor.exact, from_chol, from_eigh)


def gp_alpha_cached(traj: Trajectory, factor: GramFactor, hyper: GPHyper) -> jax.Array:
    """alpha = (K + s^2 I)^{-1} y via the cached factor.  O(cap^2)."""
    del hyper  # hyperparameters are baked into the factor
    return factor_solve(factor, traj.ys * traj.valid_mask())


def grad_mean_cached(
    traj: Trajectory,
    factor: GramFactor,
    hyper: GPHyper,
    x: jax.Array,
    alpha: jax.Array | None = None,
) -> jax.Array:
    """Posterior gradient mean (eq. 5) from cached factors."""
    if alpha is None:
        alpha = gp_alpha_cached(traj, factor, hyper)
    j = dkdx(x, traj.xs, hyper.lengthscale) * traj.valid_mask()[:, None]
    return j.T @ alpha


def grad_uncertainty_batch_cached(
    traj: Trajectory, factor: GramFactor, hyper: GPHyper, xs_q: jax.Array
) -> jax.Array:
    """Uncertainty scores for a candidate batch, O(cap^2) per candidate.

    Expands tr(J^T A^{-1} J) through the SE-kernel structure of J so the
    per-candidate cost drops from O(cap^2 d) triangular solves to one
    matvec against the masked inverse (see kernels/ref.py:uncertainty_scores
    for the algebra); the whole batch is one fused pass in
    ``repro.kernels.ops.uncertainty_scores``.

    The contraction is evaluated in coordinates SHIFTED to the candidate
    centroid: the expansion's three terms cancel against each other, and in
    the original frame their magnitudes scale with ||x||^2, costing ~10x in
    f32 accuracy.  Distances (hence h and the scores) are shift-invariant,
    so this is numerics only.
    """
    from repro.kernels import ops  # deferred: keep core importable without kernels

    mask = traj.valid_mask()
    binv = factor_inverse(factor) * (mask[:, None] * mask[None, :])
    c0 = jnp.mean(xs_q, axis=0)
    xs_sh = (traj.xs - c0[None, :]) * mask[:, None]
    pmat = binv * (xs_sh @ xs_sh.T)
    d = traj.dim
    prior = d / (hyper.lengthscale**2)
    return ops.uncertainty_scores(
        xs_q - c0[None, :], xs_sh, binv, pmat, lengthscale=hyper.lengthscale, prior=prior
    )


def grad_uncertainty_trace_cached(
    traj: Trajectory, factor: GramFactor, hyper: GPHyper, x: jax.Array
) -> jax.Array:
    return grad_uncertainty_batch_cached(traj, factor, hyper, x[None, :])[0]


def select_active_queries_cached(
    key: jax.Array,
    traj: Trajectory,
    factor: GramFactor,
    hyper: GPHyper,
    center: jax.Array,
    n_candidates: int,
    n_select: int,
    radius: float,
    lo: float = 0.0,
    hi: float = 1.0,
) -> jax.Array:
    """``select_active_queries`` scoring through the cached factor."""
    d = center.shape[-1]
    delta = jax.random.uniform(key, (n_candidates, d), minval=-radius, maxval=radius)
    cands = jnp.clip(center[None, :] + delta, lo, hi)
    scores = grad_uncertainty_batch_cached(traj, factor, hyper, cands)
    _, top = jax.lax.top_k(scores, n_select)
    return cands[top]


# ---------------------------------------------------------------------------
# Client-batched cached surrogate (DESIGN.md Sec. 2.6 / Sec. 4).
#
# Under the vmapped simulation engine every client evaluates the SAME
# surrogate contraction shapes at every local step, so the scoring and
# gradient-mean kernels take the whole client batch in ONE launch (a client
# grid dimension in the Pallas kernels) instead of N vmapped launches.  All
# stacked arguments carry a leading client axis N; the math per client is
# identical to the unbatched functions above (tested).
# ---------------------------------------------------------------------------


def traj_extend_clients(
    trajs: Trajectory,
    factors: GramFactor,
    xs: jax.Array,  # (N, k, d)
    ys: jax.Array,  # (N, k)
    hyper: GPHyper,
    deferred: bool = False,
) -> tuple[Trajectory, GramFactor]:
    """``traj_extend`` over a stacked client batch (same default as there)."""
    return jax.vmap(lambda tr, fa, x, y: traj_extend(tr, fa, x, y, hyper, deferred))(
        trajs, factors, xs, ys
    )


def gp_alpha_cached_clients(trajs: Trajectory, factors: GramFactor) -> jax.Array:
    """Stacked alpha = (K + s^2 I)^{-1} y, (N, cap)."""
    masks = jax.vmap(Trajectory.valid_mask)(trajs)
    return jax.vmap(factor_solve)(factors, trajs.ys * masks)


def grad_mean_cached_clients(
    trajs: Trajectory,
    factors: GramFactor,
    hyper: GPHyper,
    xs: jax.Array,
    *,
    block_n: int | None = None,
    block_cap: int | None = None,
) -> jax.Array:
    """Posterior gradient mean at one point per client: (N, d) -> (N, d).

    One client-batched fused kernel launch (``ops.grad_mean_clients``)
    instead of N vmapped launches.  Unset block sizes defer to the
    autotuner, which resolves the single-query candidate axis to the f32
    sublane tile (block_n=8: a 128-row block would be ~99% padding work);
    ``AlgoConfig.grad_block_*`` pins them instead.
    """
    from repro.kernels import ops  # deferred: keep core importable without kernels

    alpha = gp_alpha_cached_clients(trajs, factors)
    out = ops.grad_mean_clients(
        xs[:, None, :], trajs.xs, alpha, lengthscale=hyper.lengthscale,
        block_n=block_n, block_cap=block_cap,
    )
    return out[:, 0, :]


def grad_uncertainty_batch_cached_clients(
    trajs: Trajectory,
    factors: GramFactor,
    hyper: GPHyper,
    xs_q: jax.Array,
    *,
    block_n: int | None = None,
    block_cap: int | None = None,
) -> jax.Array:
    """Uncertainty scores for a per-client candidate batch: (N, nc, d) -> (N, nc).

    Client-batched analogue of ``grad_uncertainty_batch_cached`` (same
    centroid-shifted contraction, see that docstring for the numerics); the
    whole client batch is ONE fused pass in ``ops.uncertainty_scores_clients``.
    Unset block sizes defer to the autotuner; ``AlgoConfig.score_block_*``
    pins them.
    """
    from repro.kernels import ops  # deferred: keep core importable without kernels

    masks = jax.vmap(Trajectory.valid_mask)(trajs)  # (N, cap)
    binv = jax.vmap(factor_inverse)(factors) * (masks[:, :, None] * masks[:, None, :])
    c0 = jnp.mean(xs_q, axis=1)  # (N, d) per-client candidate centroid
    xs_sh = (trajs.xs - c0[:, None, :]) * masks[:, :, None]
    pmat = binv * jnp.einsum("ncd,nkd->nck", xs_sh, xs_sh)
    d = trajs.xs.shape[-1]
    prior = d / (hyper.lengthscale**2)
    return ops.uncertainty_scores_clients(
        xs_q - c0[:, None, :], xs_sh, binv, pmat,
        lengthscale=hyper.lengthscale, prior=prior,
        block_n=block_n, block_cap=block_cap,
    )


def select_active_queries_cached_clients(
    keys: jax.Array,  # (N, 2) per-client PRNG keys
    trajs: Trajectory,
    factors: GramFactor,
    hyper: GPHyper,
    centers: jax.Array,  # (N, d)
    n_candidates: int,
    n_select: int,
    radius: float,
    lo: float = 0.0,
    hi: float = 1.0,
    *,
    block_n: int | None = None,
    block_cap: int | None = None,
) -> jax.Array:
    """``select_active_queries_cached`` for the whole client batch: (N, n_select, d)."""
    d = centers.shape[-1]
    delta = jax.vmap(
        lambda k: jax.random.uniform(k, (n_candidates, d), minval=-radius, maxval=radius)
    )(keys)
    cands = jnp.clip(centers[:, None, :] + delta, lo, hi)
    scores = grad_uncertainty_batch_cached_clients(
        trajs, factors, hyper, cands, block_n=block_n, block_cap=block_cap
    )
    _, top = jax.lax.top_k(scores, n_select)  # batched over the client axis
    return jnp.take_along_axis(cands, top[:, :, None], axis=1)
