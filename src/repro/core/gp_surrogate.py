"""Trajectory-informed derived-GP gradient surrogates (paper Sec. 4.1, eq. 4-5).

Every client keeps the history of its own function queries (the *optimization
trajectory*).  Under the paper's assumption ``f_i ~ GP(mu, k)`` with a
shift-invariant kernel, the gradient follows a *derived* posterior GP whose mean

    grad_mu(x) = d_x k(x, X)^T (K + sigma^2 I)^{-1} y            (eq. 5)

is used as the local gradient surrogate, and whose covariance at ``x``

    d_sigma2(x) = d_x d_x' k|_{x,x} - d_x k(x,X)^T (K+s^2 I)^{-1} d_x' k(X,x)

provides the uncertainty measure driving active queries (Thm. 1 terms (1)/(2)).

Implementation notes (hardware adaptation, see DESIGN.md Sec. 2):

* The trajectory grows during optimization, which would force re-tracing under
  JIT.  We therefore keep a **fixed-capacity ring buffer** with a validity mask;
  the padded Gram system is block-diagonal ``[K_n + s^2 I, I]`` so the masked
  Cholesky solve returns *exactly* the un-padded answer (property-tested).
* The paper keeps the full trajectory; for long runs the ring buffer keeps the
  most recent ``capacity`` queries.  Appx. C.3 of the paper shows distant
  queries are uninformative for the surrogate at the current iterate, so a
  recency window is the faithful finite-memory realization.
* All hot math below is pure jnp; the TPU Pallas kernels in
  ``repro.kernels`` implement the same contractions with explicit VMEM tiling
  and are validated against these functions.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Trajectory(NamedTuple):
    """Fixed-capacity ring buffer of (x, y) function queries."""

    xs: jax.Array  # (capacity, d)
    ys: jax.Array  # (capacity,)
    count: jax.Array  # () int32 -- total number of appends (may exceed capacity)

    @property
    def capacity(self) -> int:
        return self.xs.shape[0]

    @property
    def dim(self) -> int:
        return self.xs.shape[1]

    def n_valid(self) -> jax.Array:
        return jnp.minimum(self.count, self.capacity)

    def valid_mask(self) -> jax.Array:
        return (jnp.arange(self.capacity) < self.n_valid()).astype(self.xs.dtype)


def traj_init(capacity: int, dim: int, dtype=jnp.float32) -> Trajectory:
    return Trajectory(
        xs=jnp.zeros((capacity, dim), dtype),
        ys=jnp.zeros((capacity,), dtype),
        count=jnp.zeros((), jnp.int32),
    )


def traj_append(traj: Trajectory, x: jax.Array, y: jax.Array) -> Trajectory:
    """Append one query; overwrites the oldest entry when full."""
    idx = jnp.mod(traj.count, traj.capacity)
    xs = jax.lax.dynamic_update_slice(traj.xs, x[None, :].astype(traj.xs.dtype), (idx, 0))
    ys = jax.lax.dynamic_update_slice(traj.ys, jnp.reshape(y, (1,)).astype(traj.ys.dtype), (idx,))
    return Trajectory(xs=xs, ys=ys, count=traj.count + 1)


def traj_append_batch(traj: Trajectory, xs: jax.Array, ys: jax.Array) -> Trajectory:
    """Append a batch of queries (scan over rows; batch is static)."""

    def body(t, xy):
        x, y = xy
        return traj_append(t, x, y), None

    out, _ = jax.lax.scan(body, traj, (xs, ys))
    return out


# ---------------------------------------------------------------------------
# Squared-exponential kernel and its derivatives (Appx. B kernel choice).
# ---------------------------------------------------------------------------


def sqexp(x1: jax.Array, x2: jax.Array, lengthscale: float) -> jax.Array:
    """k(X1, X2) pairwise SE kernel.  x1: (n,d)  x2: (m,d) -> (n,m)."""
    d2 = pairwise_sqdist(x1, x2)
    return jnp.exp(-0.5 * d2 / (lengthscale**2))


def pairwise_sqdist(x1: jax.Array, x2: jax.Array) -> jax.Array:
    n1 = jnp.sum(x1 * x1, axis=-1)
    n2 = jnp.sum(x2 * x2, axis=-1)
    cross = x1 @ x2.T
    d2 = n1[:, None] + n2[None, :] - 2.0 * cross
    return jnp.maximum(d2, 0.0)


def dkdx(x: jax.Array, xs: jax.Array, lengthscale: float) -> jax.Array:
    """d_x k(x, X) for the SE kernel.

    x: (d,), xs: (n, d) -> (n, d) with row tau = -(x - x_tau)/l^2 * k(x, x_tau).
    """
    diff = x[None, :] - xs  # (n, d)
    k = jnp.exp(-0.5 * jnp.sum(diff * diff, axis=-1) / (lengthscale**2))  # (n,)
    return (-diff / (lengthscale**2)) * k[:, None]


class GPHyper(NamedTuple):
    lengthscale: jax.Array  # ()
    noise: jax.Array  # () observation noise variance sigma^2


def default_hyper(lengthscale: float = 1.0, noise: float = 1e-4) -> GPHyper:
    return GPHyper(jnp.asarray(lengthscale, jnp.float32), jnp.asarray(noise, jnp.float32))


def _masked_gram_chol(traj: Trajectory, hyper: GPHyper) -> tuple[tuple[jax.Array, jax.Array], jax.Array]:
    """Eigh factorization of the padded Gram system.

    Padded system is block-diagonal [K_n + s^2 I, I]: invalid rows/cols are
    zeroed and their diagonal set to 1, so the solve on masked targets is
    exactly the solve of the live n x n system.

    Float32 + clustered active queries make the Gram numerically indefinite
    -- a trajectory full of points within the 0.01 active-query ball produced
    NaN Cholesky pivots in practice -- so we factor with eigh and CLAMP the
    spectrum at the jitter floor: a principled pseudo-solve that never
    explodes (capacity <= a few hundred, so the O(cap^3) is negligible).
    Returns ((eigvecs, eigvals), mask).
    """
    mask = traj.valid_mask()  # (cap,)
    k = sqexp(traj.xs, traj.xs, hyper.lengthscale)
    m2 = mask[:, None] * mask[None, :]
    jitter = jnp.maximum(hyper.noise, 1e-4)
    gram = k * m2 + jnp.diag(jitter * mask + (1.0 - mask))
    w, v = jnp.linalg.eigh(gram)
    w = jnp.maximum(w, jitter)
    return (v, w), mask


def _gram_solve(factors: tuple[jax.Array, jax.Array], b: jax.Array) -> jax.Array:
    """(K+jitter)^-1 b via the clamped eigh factors.  b: (cap,) or (cap, d)."""
    v, w = factors
    vb = v.T @ b
    if b.ndim == 1:
        return v @ (vb / w)
    return v @ (vb / w[:, None])


def gp_alpha(traj: Trajectory, hyper: GPHyper) -> jax.Array:
    """alpha = (K + s^2 I)^{-1} y with masking.  (capacity,)"""
    factors, mask = _masked_gram_chol(traj, hyper)
    return _gram_solve(factors, traj.ys * mask)


def grad_mean(traj: Trajectory, hyper: GPHyper, x: jax.Array, alpha: jax.Array | None = None) -> jax.Array:
    """Posterior gradient mean  grad_mu(x)  (eq. 5).  x: (d,) -> (d,)."""
    if alpha is None:
        alpha = gp_alpha(traj, hyper)
    j = dkdx(x, traj.xs, hyper.lengthscale) * traj.valid_mask()[:, None]  # (cap, d)
    return j.T @ alpha


def grad_mean_batch(traj: Trajectory, hyper: GPHyper, xs: jax.Array) -> jax.Array:
    alpha = gp_alpha(traj, hyper)
    return jax.vmap(lambda x: grad_mean(traj, hyper, x, alpha))(xs)


def grad_uncertainty_trace(traj: Trajectory, hyper: GPHyper, x: jax.Array, chol_mask=None) -> jax.Array:
    """tr d_sigma2(x) -- the uncertainty score used for active queries.

    For the SE kernel  d_x d_x' k|_{x=x'} = I / l^2, so the prior trace is
    d / l^2 and the data correction is  sum_ij J A^{-1} J  with
    J = d_x k(x, X).  Trace is the cheap principled surrogate for the matrix
    norm in Thm. 1 (it upper-bounds the spectral norm up to d and preserves
    the ranking used to select active queries).
    """
    if chol_mask is None:
        factors, mask = _masked_gram_chol(traj, hyper)
    else:
        factors, mask = chol_mask
    d = x.shape[-1]
    j = dkdx(x, traj.xs, hyper.lengthscale) * mask[:, None]  # (cap, d)
    sol = _gram_solve(factors, j)  # (cap, d)
    prior = d / (hyper.lengthscale**2)
    corr = jnp.sum(j * sol)
    return jnp.maximum(prior - corr, 0.0)


def grad_uncertainty_batch(traj: Trajectory, hyper: GPHyper, xs: jax.Array) -> jax.Array:
    cm = _masked_gram_chol(traj, hyper)
    return jax.vmap(lambda x: grad_uncertainty_trace(traj, hyper, x, cm))(xs)


def select_active_queries(
    key: jax.Array,
    traj: Trajectory,
    hyper: GPHyper,
    center: jax.Array,
    n_candidates: int,
    n_select: int,
    radius: float,
    lo: float = 0.0,
    hi: float = 1.0,
) -> jax.Array:
    """Paper Appx. E general settings: sample ``n_candidates`` points
    uniformly in ``center +- radius``, return the ``n_select`` with the
    highest gradient-surrogate uncertainty.  -> (n_select, d)
    """
    d = center.shape[-1]
    delta = jax.random.uniform(key, (n_candidates, d), minval=-radius, maxval=radius)
    cands = jnp.clip(center[None, :] + delta, lo, hi)
    scores = grad_uncertainty_batch(traj, hyper, cands)
    _, top = jax.lax.top_k(scores, n_select)
    return cands[top]


def mean_value(traj: Trajectory, hyper: GPHyper, x: jax.Array) -> jax.Array:
    """Plain GP posterior mean of f itself (used in tests/benchmarks)."""
    alpha = gp_alpha(traj, hyper)
    kvec = sqexp(x[None, :], traj.xs, hyper.lengthscale)[0] * traj.valid_mask()
    return kvec @ alpha
