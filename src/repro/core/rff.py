"""Random Fourier features and the transferable global gradient surrogate
(paper Sec. 4.2.1 + Appx. B).

phi(x) = sqrt(2/M) cos(V x + b),  V_j ~ N(0, I/l^2),  b_j ~ U[0, 2pi]

so that  k(x, x') ~= phi(x)^T phi(x')  for the SE kernel with lengthscale l.
The feature bank (V, b) is sampled ONCE before optimization and shared by all
clients and the server (Appx. B), making the M-dim weight vector

    w = Phi (Khat + s^2 I)^{-1} y,    Phi = [phi(x_tau)]  (M x n)      (eq. 6)

a transferable compression of the whole local surrogate:

    grad_muhat(x) = grad_phi(x)^T w,
    grad_phi(x)^T w = -sqrt(2/M) * (sin(Vx + b) * w) @ V   in R^d.

The server aggregates  w_r = mean_i w^(i)  (eq. 7) -- an M-float payload per
client per round, which is the paper's entire extra communication cost.

The contractions here are mirrored by the Pallas TPU kernels in
``repro.kernels`` (rff_features / rff_grad); these jnp versions are the
oracles and the CPU execution path.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gp_surrogate import GPHyper, Trajectory


class RFFParams(NamedTuple):
    v: jax.Array  # (M, d) frequencies
    b: jax.Array  # (M,) phases

    @property
    def n_features(self) -> int:
        return self.v.shape[0]


def make_rff(key: jax.Array, n_features: int, dim: int, lengthscale: float) -> RFFParams:
    """Sample the shared feature bank (done once; see Appx. B)."""
    kv, kb = jax.random.split(key)
    v = jax.random.normal(kv, (n_features, dim)) / lengthscale
    b = jax.random.uniform(kb, (n_features,), minval=0.0, maxval=2.0 * math.pi)
    return RFFParams(v=v, b=b)


def features(params: RFFParams, xs: jax.Array) -> jax.Array:
    """phi(X): xs (n, d) -> (n, M)."""
    m = params.n_features
    proj = xs @ params.v.T + params.b[None, :]
    return math.sqrt(2.0 / m) * jnp.cos(proj)


def grad_features_t_w(params: RFFParams, x: jax.Array, w: jax.Array) -> jax.Array:
    """grad phi(x)^T w: x (d,), w (M,) -> (d,)."""
    m = params.n_features
    s = jnp.sin(x @ params.v.T + params.b)  # (M,)
    return -math.sqrt(2.0 / m) * ((s * w) @ params.v)


def grad_features_t_w_batch(params: RFFParams, xs: jax.Array, w: jax.Array) -> jax.Array:
    """xs (n, d), w (M,) -> (n, d)."""
    m = params.n_features
    s = jnp.sin(xs @ params.v.T + params.b[None, :])  # (n, M)
    return -math.sqrt(2.0 / m) * ((s * w[None, :]) @ params.v)


def grad_features_t_w_rows(params: RFFParams, xs: jax.Array, ws: jax.Array) -> jax.Array:
    """Per-row weight vectors (the client-batched engine): xs (n, d), ws (n, M)
    -> (n, d).  Row i is ``grad_features_t_w(params, xs[i], ws[i])``."""
    m = params.n_features
    s = jnp.sin(xs @ params.v.T + params.b[None, :])  # (n, M)
    return -math.sqrt(2.0 / m) * ((s * ws) @ params.v)


def fit_w(params: RFFParams, traj: Trajectory, hyper: GPHyper) -> jax.Array:
    """w = Phi (Khat + s^2 I)^{-1} y  with the same masked-padding scheme as
    the exact GP (invalid trajectory slots contribute nothing).  -> (M,)
    """
    mask = traj.valid_mask()
    phi = features(params, traj.xs) * mask[:, None]  # (cap, M) rows zeroed when invalid
    khat = phi @ phi.T  # (cap, cap), already masked
    # same clamped-eigh pseudo-solve as the exact GP (see gp_surrogate):
    # the RFF Gram is rank <= M and often near-singular in float32.
    jitter = jnp.maximum(hyper.noise, 1e-4)
    gram = khat + jnp.diag(jitter * mask + (1.0 - mask))
    w, v = jnp.linalg.eigh(gram)
    w = jnp.maximum(w, jitter)
    alpha = v @ ((v.T @ (traj.ys * mask)) / w)
    return phi.T @ alpha


def fit_w_chol(params: RFFParams, traj: Trajectory, hyper: GPHyper, factor) -> jax.Array:
    """Eigh-free eq. 6 fit for the deferred-repair engine (DESIGN.md Sec. 2.6).

    Same RFF-Gram system as ``fit_w`` but solved with one blocked Cholesky
    instead of the clamped eigh (``Khat`` is PSD and the jitter floor keeps
    the padded system PD in exact arithmetic, so the potrf is the natural
    factorization; the eigh was only ever the NaN-robustness fallback).
    Robustness is preserved branch-free: if any live pivot dips below the
    same pivot floor the solve routes -- by masked selection, no eigh in the
    graph -- through the client's cached exact-GP ``GramFactor``, i.e. the
    ``fit_w_from_factor`` answer, which differs from eq. 6 only by the
    O(1/sqrt(M)) feature-approximation error the method already tolerates.
    """
    from repro.core import gp_surrogate as gp

    mask = traj.valid_mask()
    phi = features(params, traj.xs) * mask[:, None]
    jitter = jnp.maximum(hyper.noise, 1e-4)
    gram = phi @ phi.T + jnp.diag(jitter * mask + (1.0 - mask))
    chol = jnp.linalg.cholesky(gram)
    ok = gp._factor_health(chol, mask, jitter)
    ys_m = traj.ys * mask
    alpha = jax.scipy.linalg.cho_solve((jnp.where(ok, chol, jnp.eye(gram.shape[0], dtype=gram.dtype)), True), ys_m)
    alpha_fb = gp.factor_solve(factor, ys_m)
    return phi.T @ jnp.where(ok, alpha, alpha_fb)


def fit_w_from_factor(params: RFFParams, traj: Trajectory, factor) -> jax.Array:
    """w = Phi (K + s^2 I)^{-1} y through the cached EXACT-GP Gram factor.

    The paper's eq. 6 solves against the RFF-approximated Gram
    ``Khat = Phi^T Phi``; this variant reuses the per-client ``GramFactor``
    (core/gp_surrogate) already maintained for the surrogate hot path, so the
    round-end fit is one O(cap^2) cached solve instead of an O(cap^3) eigh of
    Khat.  Because Khat = K + O(1/sqrt(M)), the fitted w differs from eq. 6
    by the same feature-approximation error the method already tolerates;
    the executable default keeps eq. 6 (``AlgoConfig.rff_fit_exact`` opts in).
    """
    from repro.core import gp_surrogate as gp

    mask = traj.valid_mask()
    alpha = gp.factor_solve(factor, traj.ys * mask)
    phi = features(params, traj.xs) * mask[:, None]
    return phi.T @ alpha


def approx_kernel(params: RFFParams, x1: jax.Array, x2: jax.Array) -> jax.Array:
    """phi(X1) phi(X2)^T -- used by tests for the O(1/sqrt(M)) error law."""
    return features(params, x1) @ features(params, x2).T
