"""Federated black-box objectives.

An *objective* is a stacked pytree of per-client parameters (leading axis N)
plus module-level pure functions:

    query(client_params_i, x, key)  -> noisy scalar y_i(x)   (the only thing
                                        the optimizer may call -- ZOO contract)
    value(client_params_i, x)       -> noiseless f_i(x)       (diagnostics)
    grad(client_params_i, x)        -> exact grad f_i(x)      (diagnostics,
                                        synthetic objectives only)

All inputs live in the paper's normalized domain X = [0,1]^d (Sec. 2 /
Appx. E min-max normalization); objectives internally map to their natural
coordinates.

Synthetic family = paper Appx. E.1 heterogeneous quadratics:

    f_i(x) = 1/(10 d) * ( sum_j [ (1 + C (a_j^i - 1/N)) xr_j^2
                                 + (1 + C (b_j^i - 1/N)) xr_j ] + 1 ),
    xr in [-10, 10]^d,  a_j, b_j ~ Dir(1/N * 1) across clients,

so the global average is F(x) = 1/(10d) (sum_j xr_j^2 + xr_j + 1) regardless
of C, while C controls client heterogeneity (Fig. 1).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Heterogeneous quadratics (Appx. E.1)
# ---------------------------------------------------------------------------


class QuadraticClient(NamedTuple):
    a: jax.Array  # (d,) Dirichlet weights for the quadratic term
    b: jax.Array  # (d,) Dirichlet weights for the linear term
    c_het: jax.Array  # () heterogeneity constant C
    n_clients: jax.Array  # () float N
    noise_std: jax.Array  # () observation noise sigma


def make_quadratic(
    key: jax.Array,
    n_clients: int,
    dim: int,
    c_het: float,
    noise_std: float = 0.01,
) -> QuadraticClient:
    """Stacked per-client params (leading axis N)."""
    ka, kb = jax.random.split(key)
    alpha = jnp.full((n_clients,), 1.0 / n_clients)
    # Dirichlet across clients, independently per dimension.
    a = jax.random.dirichlet(ka, alpha, shape=(dim,)).T  # (N, d)
    b = jax.random.dirichlet(kb, alpha, shape=(dim,)).T  # (N, d)
    rep = lambda v: jnp.full((n_clients,), v, jnp.float32)
    return QuadraticClient(
        a=a.astype(jnp.float32),
        b=b.astype(jnp.float32),
        c_het=rep(c_het),
        n_clients=rep(float(n_clients)),
        noise_std=rep(noise_std),
    )


def _to_raw(x_unit: jax.Array) -> jax.Array:
    return 20.0 * x_unit - 10.0  # [0,1] -> [-10,10]


def quadratic_value(cp: QuadraticClient, x_unit: jax.Array) -> jax.Array:
    xr = _to_raw(x_unit)
    d = xr.shape[-1]
    wa = 1.0 + cp.c_het * (cp.a - 1.0 / cp.n_clients)
    wb = 1.0 + cp.c_het * (cp.b - 1.0 / cp.n_clients)
    return (jnp.sum(wa * xr * xr + wb * xr) + 1.0) / (10.0 * d)


def quadratic_grad(cp: QuadraticClient, x_unit: jax.Array) -> jax.Array:
    """Exact grad wrt the *unit-domain* x (chain rule factor 20)."""
    xr = _to_raw(x_unit)
    d = xr.shape[-1]
    wa = 1.0 + cp.c_het * (cp.a - 1.0 / cp.n_clients)
    wb = 1.0 + cp.c_het * (cp.b - 1.0 / cp.n_clients)
    return 20.0 * (2.0 * wa * xr + wb) / (10.0 * d)


def quadratic_query(cp: QuadraticClient, x_unit: jax.Array, key: jax.Array) -> jax.Array:
    return quadratic_value(cp, x_unit) + cp.noise_std * jax.random.normal(key, ())


def quadratic_global_value(cps: QuadraticClient, x_unit: jax.Array) -> jax.Array:
    """F(x) = mean_i f_i(x) over the stacked clients."""
    return jnp.mean(jax.vmap(lambda cp: quadratic_value(cp, x_unit))(cps))


def quadratic_global_grad(cps: QuadraticClient, x_unit: jax.Array) -> jax.Array:
    return jnp.mean(jax.vmap(lambda cp: quadratic_grad(cp, x_unit))(cps), axis=0)


def quadratic_optimum_unit(dim: int) -> jax.Array:
    """argmin F: xr_j = -1/2  ->  unit coords (xr+10)/20 = 0.475."""
    return jnp.full((dim,), 0.475, jnp.float32)


def quadratic_fstar(dim: int) -> float:
    """F at the optimum: (d*(-1/4) + 1)/(10 d)."""
    return float((-0.25 * dim + 1.0) / (10.0 * dim))


# ---------------------------------------------------------------------------
# Non-convex synthetic (robustness coverage beyond the paper's Fig. 1)
# ---------------------------------------------------------------------------


class SinQuadClient(NamedTuple):
    a: jax.Array  # (d,)
    phase: jax.Array  # (d,)
    c_het: jax.Array  # ()
    n_clients: jax.Array  # ()
    noise_std: jax.Array  # ()


def make_sinquad(key: jax.Array, n_clients: int, dim: int, c_het: float, noise_std: float = 0.01) -> SinQuadClient:
    ka, kp = jax.random.split(key)
    alpha = jnp.full((n_clients,), 1.0 / n_clients)
    a = jax.random.dirichlet(ka, alpha, shape=(dim,)).T
    phase = jax.random.uniform(kp, (n_clients, dim), maxval=2 * jnp.pi)
    rep = lambda v: jnp.full((n_clients,), v, jnp.float32)
    return SinQuadClient(a.astype(jnp.float32), phase, rep(c_het), rep(float(n_clients)), rep(noise_std))


def sinquad_value(cp: SinQuadClient, x_unit: jax.Array) -> jax.Array:
    xr = 4.0 * x_unit - 2.0
    d = xr.shape[-1]
    wa = 1.0 + cp.c_het * (cp.a - 1.0 / cp.n_clients)
    base = jnp.sum(wa * xr * xr) / d
    ripple = jnp.sum(jnp.sin(3.0 * xr + cp.phase)) * (0.1 * cp.c_het / jnp.maximum(d, 1))
    return base + ripple


def sinquad_grad(cp: SinQuadClient, x_unit: jax.Array) -> jax.Array:
    return jax.grad(lambda u: sinquad_value(cp, u))(x_unit)


def sinquad_query(cp: SinQuadClient, x_unit: jax.Array, key: jax.Array) -> jax.Array:
    return sinquad_value(cp, x_unit) + cp.noise_std * jax.random.normal(key, ())


def sinquad_global_value(cps: SinQuadClient, x_unit: jax.Array) -> jax.Array:
    return jnp.mean(jax.vmap(lambda cp: sinquad_value(cp, x_unit))(cps))


def sinquad_global_grad(cps: SinQuadClient, x_unit: jax.Array) -> jax.Array:
    return jnp.mean(jax.vmap(lambda cp: sinquad_grad(cp, x_unit))(cps), axis=0)


# ---------------------------------------------------------------------------
# Heterogeneity measurement (the paper's G)
# ---------------------------------------------------------------------------


def heterogeneity_g(grad_fn, cps, xs_unit: jax.Array) -> jax.Array:
    """Empirical  max_x (1/N) sum_i ||grad f_i(x) - grad F(x)||^2  over probe xs."""

    def at_x(x):
        gs = jax.vmap(lambda cp: grad_fn(cp, x))(cps)  # (N, d)
        gbar = jnp.mean(gs, axis=0)
        return jnp.mean(jnp.sum((gs - gbar) ** 2, axis=-1))

    return jnp.max(jax.vmap(at_x)(xs_unit))
