"""Partial-participation client pool (DESIGN.md Sec. 9).

The paper's federated ZOO setting (and the client-sampling regime of
Fang et al., arXiv 2201.09531) assumes only a cohort of K << N clients
participates each round, but the scan engine runs a dense stacked
``ClientState`` of ALL clients: N is capped by mesh memory and the psum
mean divides by a static ``cfg.n_clients``, which is simply wrong under
partial participation.  This module supplies the population half:

  * ``ClientPool`` -- a HOST-resident store of the N pooled client states
    (stacked numpy leaves, leading axis N).  Only the active cohort ever
    touches the mesh, so the pool size is bounded by host memory, not HBM,
    and N need not divide the client shard count (only K must).
  * ``sample_cohort`` -- a deterministic PRNG cohort sampler keyed
    ``fold_in(PRNGKey(seed), round)`` (the same discipline as
    ``faults/injector.py``): pure in (seed, round, N, K), independent of
    topology, chunk length, and resume point.  ``K == N`` short-circuits to
    the identity so the pooled engine is BITWISE the dense engine (the
    equivalence oracle the tests pin).
  * ``run_pooled_rounds`` -- the pooled driver: at every chunk boundary it
    samples a cohort, gathers those K states (and their objectives) onto
    the mesh, runs the EXISTING scanned chunk engine over the cohort, and
    scatters the updated state back to the pool.  Aggregation inside the
    round body is participation-weighted: the cohort body always runs the
    fault engine's masked ``sum_fn`` path (a zero-rate ``FaultConfig`` when
    the caller injects no faults), so the denominator is the LIVE cohort
    count -- never the dense ``n_clients`` mean -- and dropped/quarantined
    cohort members are masked out of the aggregate exactly as in the dense
    faulted engine.  One chunk executable keyed on K serves every cohort
    (same shapes/dtypes/shardings each gather -- asserted recompile-free by
    the tests via ``analysis.no_recompiles``).

Checkpointing reuses the per-shard ``step_<N>/shard_<p>`` layout
(``checkpoint/io.prepare_pool_state``): each process persists its own row
range of the host pool plus the replicated history, with the same
atomic-rename, per-leaf checksum, and corrupt-step-fallback story as
round-state checkpoints.  Fault rollback restores {pool, history} from the
newest good step and replays the lost chunks; the cohort schedule is keyed
on the absolute round, so a rolled-back or resumed run re-draws the SAME
cohorts and matches an uninterrupted one bitwise (tested).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import io as ckpt_io
from repro.core import algorithms as alg
from repro.core import federated as fed
from repro.core import rff as rfflib
from repro.core import rounds as rounds_mod
from repro.faults.injector import FaultConfig, effective_config


# ---------------------------------------------------------------------------
# Cohort sampling
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnums=(1,))
def _perm(key: jax.Array, n: int) -> jax.Array:
    return jax.random.permutation(key, n)


def sample_cohort(seed: int, round_idx: int, pool_size: int, cohort: int) -> np.ndarray:
    """Deterministic cohort for the gather at absolute round ``round_idx``.

    Keyed ``fold_in(PRNGKey(seed), round_idx)`` -- the injector's keying
    discipline -- so the schedule is a pure function of (seed, round, N, K):
    the same cohorts are drawn under vmap and shard_map, after a resume, and
    after a rollback replay.  Returns SORTED global indices (pool order ==
    batch order, so the gathered cohort aggregates in a stable order).
    ``cohort == pool_size`` returns the identity arrangement: the pooled
    engine then IS the dense engine (the bitwise equivalence oracle).
    """
    if not 1 <= cohort <= pool_size:
        raise ValueError(
            f"cohort={cohort} must be in [1, pool_size={pool_size}]"
        )
    if cohort == pool_size:
        return np.arange(pool_size, dtype=np.int64)
    key = jax.random.fold_in(jax.random.PRNGKey(int(seed)), int(round_idx))
    perm = np.asarray(jax.device_get(_perm(key, pool_size)))
    return np.sort(perm[:cohort]).astype(np.int64)


# ---------------------------------------------------------------------------
# The pool store
# ---------------------------------------------------------------------------


class ClientPool:
    """Host-resident store of N stacked client states.

    Leaves are writable numpy arrays with leading axis N (this process's
    rows); ``gather`` lifts a cohort's rows onto the device/mesh and
    ``scatter`` writes updated cohort state back.  The round trip is
    bitwise: numpy advanced indexing copies values unchanged, so a
    gather-scatter of untouched rows is a no-op.
    """

    def __init__(self, leaves: list[np.ndarray], treedef, row_start: int = 0,
                 global_rows: Optional[int] = None) -> None:
        if not leaves:
            raise ValueError("ClientPool requires at least one state leaf")
        self._leaves = leaves
        self._treedef = treedef
        self.row_start = int(row_start)
        self.global_rows = int(global_rows if global_rows is not None
                               else leaves[0].shape[0])

    @property
    def size(self) -> int:
        return self.global_rows

    @property
    def leaves(self) -> list[np.ndarray]:
        return self._leaves

    @property
    def treedef_str(self) -> str:
        return str(self._treedef)

    @classmethod
    def from_states(cls, states: alg.ClientState) -> "ClientPool":
        """Pool a stacked ``ClientState`` (device or host) by value."""
        leaves, treedef = jax.tree_util.tree_flatten(states)
        host = [np.array(jax.device_get(leaf)) for leaf in leaves]
        return cls(host, treedef)

    def load_leaves(self, leaves: list[np.ndarray]) -> None:
        """Replace the pool contents (checkpoint restore path)."""
        if len(leaves) != len(self._leaves):
            raise ValueError(
                f"pool has {len(self._leaves)} leaves, got {len(leaves)}"
            )
        for i, (old, new) in enumerate(zip(self._leaves, leaves)):
            if old.shape != new.shape or old.dtype != new.dtype:
                raise ValueError(
                    f"pool leaf {i}: cannot load {new.shape}/{new.dtype} over "
                    f"{old.shape}/{old.dtype}"
                )
        self._leaves = [np.array(leaf) for leaf in leaves]

    def gather(self, idx: np.ndarray, mesh: Optional[Mesh] = None) -> alg.ClientState:
        """Lift the cohort rows ``idx`` onto the device (sharded on a mesh).

        Every gather produces arrays of the same (K, ...) shapes, dtypes and
        shardings, so one compiled chunk executable serves every cohort."""
        idx = np.asarray(idx)
        cohort = [jnp.asarray(leaf[idx]) for leaf in self._leaves]
        states = jax.tree_util.tree_unflatten(self._treedef, cohort)
        if mesh is not None:
            states = fed.shard_clients(mesh, states)
        return states

    def scatter(self, idx: np.ndarray, states: alg.ClientState) -> None:
        """Write updated cohort state back into rows ``idx``."""
        idx = np.asarray(idx)
        leaves, treedef = jax.tree_util.tree_flatten(states)
        if str(treedef) != str(self._treedef):
            raise ValueError(
                "scatter: cohort state structure does not match the pool "
                f"({treedef} vs {self._treedef})"
            )
        for i, (dst, src) in enumerate(zip(self._leaves, leaves)):
            arr = np.asarray(jax.device_get(src))
            if arr.shape[1:] != dst.shape[1:] or arr.dtype != dst.dtype:
                raise ValueError(
                    f"scatter: leaf {i} is {arr.shape[1:]}/{arr.dtype}, pool "
                    f"holds {dst.shape[1:]}/{dst.dtype}"
                )
            dst[idx] = arr


def init_pool(cfg: alg.AlgoConfig, key: jax.Array, x0: jax.Array,
              batch: Optional[int] = None) -> ClientPool:
    """Initialize an N-client pool on the host.

    ``batch=None`` initializes all N clients in one vmap -- bitwise
    identical to ``alg.init_states`` (the dense engine's init).  A smaller
    ``batch`` bounds the device footprint of initialization to ``batch``
    clients at a time (the point of pooling: N never has to fit on the
    mesh), at the cost of per-slice vmap dispatches.
    """
    n = cfg.n_clients
    if batch is None:
        batch = n
    if batch < 1:
        raise ValueError(f"batch={batch} must be >= 1")
    keys = jax.random.split(key, n)
    leaves: Optional[list[np.ndarray]] = None
    treedef = None
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        ids = jnp.arange(lo, hi, dtype=jnp.int32)
        block = jax.vmap(lambda k, i: alg.init_client_state(cfg, k, x0, i))(
            keys[lo:hi], ids
        )
        flat, treedef = jax.tree_util.tree_flatten(block)
        host = [np.asarray(jax.device_get(a)) for a in flat]
        if leaves is None:
            leaves = [np.empty((n,) + h.shape[1:], h.dtype) for h in host]
        for dst, h in zip(leaves, host):
            dst[lo:hi] = h
    return ClientPool(leaves, treedef)


# ---------------------------------------------------------------------------
# The pooled round driver
# ---------------------------------------------------------------------------


def _gather_cobjs(cobjs_host, idx: np.ndarray, n: int, mesh: Optional[Mesh]):
    """Cohort rows of the stacked per-client objectives."""
    idx = np.asarray(idx)

    def one(a: np.ndarray):
        if a.shape[0] != n:
            raise ValueError(
                f"cobjs leaf has leading axis {a.shape[0]}, expected the "
                f"pool size {n} (per-client objectives must stack over N)"
            )
        return jnp.asarray(a[idx])

    cohort = jax.tree_util.tree_map(one, cobjs_host)
    if mesh is not None:
        cohort = fed.shard_clients(mesh, cohort)
    return cohort


def _restore_newest_good_pool(checkpoint_dir: str, run_meta: dict, rounds: int,
                              x0: jax.Array, pool: ClientPool):
    """Pool analogue of ``rounds._restore_newest_good``: newest COMPLETE,
    uncorrupted pool checkpoint, falling back past corrupt steps; a step
    from a different run identity raises."""
    for step in sorted(ckpt_io.list_steps(checkpoint_dir), reverse=True):
        try:
            saved = (ckpt_io.load_meta(checkpoint_dir, step).get("extra") or {})
        except (OSError, ValueError) as e:
            print(f"[repro.pool] checkpoint step {step}: unreadable meta "
                  f"({e}); trying an older step")
            continue
        for field in ("rounds", "cfg", "eval_every", "faults",
                      "pool_size", "cohort", "cohort_seed"):
            if saved.get(field) not in (None, run_meta[field]):
                raise ValueError(
                    f"checkpoint_dir {checkpoint_dir!r} holds a run with "
                    f"{field}={saved[field]!r}, cannot resume it with "
                    f"{field}={run_meta[field]!r}; point at a fresh directory"
                )
        hist_like = rounds_mod.history_init(rounds, x0, jnp.zeros((), jnp.float32))
        try:
            leaves, hist, start = ckpt_io.restore_pool_state(
                checkpoint_dir, pool.leaves, hist_like, step=step
            )
        except (ckpt_io.CorruptCheckpointError, OSError) as e:
            print(f"[repro.pool] checkpoint step {step}: corrupt "
                  f"({e}); trying an older step")
            continue
        return leaves, hist, min(start, rounds)
    return None, None, 0


def run_pooled_rounds(
    cfg: alg.AlgoConfig,
    rff: Optional[rfflib.RFFParams],
    query_fn: alg.QueryFn,
    cobjs,
    pool: ClientPool,
    x0: jax.Array,
    global_value_fn: rounds_mod.GlobalValueFn,
    rounds: int,
    chunk: int,
    *,
    cohort: int,
    cohort_seed: int = 0,
    mesh: Optional[Mesh] = None,
    diag_global_grad=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    resume: bool = True,
    eval_every: int = 1,
    async_checkpoint: bool = True,
    faults=None,  # Optional[faults.FaultConfig]
    max_rollbacks: int = 3,
) -> tuple[ClientPool, alg.SimResult]:
    """Run ``rounds`` communication rounds with K-of-N partial participation.

    The driver is ``rounds.run_rounds`` with a gather/scatter boundary: at
    each chunk boundary a fresh cohort is sampled (``sample_cohort``, keyed
    on the absolute round of the gather), its K states and objectives are
    lifted onto the mesh, the scanned chunk engine runs over them, and the
    updated state is scattered back to the host pool.  Between boundaries
    the device never holds more than K client states -- the mesh footprint
    of a DENSE K-client run -- so the pool size N is a host-memory number.

    Aggregation is participation-weighted: the cohort round body always
    takes the fault engine's masked ``sum_fn`` path, renormalizing by the
    LIVE cohort count (``faults=None`` runs a zero-rate tolerant config, so
    all K members are live and the result is bitwise the dense mean -- the
    faults-off identity the fault suite pins).  With real ``faults``,
    dropped/poisoned cohort members are masked out of the aggregate and
    quarantined members are re-admitted at the boundary BEFORE their state
    scatters back, so a client never re-enters the pool quarantined.

    ``global_value_fn`` inside the scan sees the COHORT's objectives: under
    partial participation the reported F(x_r) curve is the standard cohort
    estimate of the global objective (exact when K = N; the initial f(x_0)
    entry is evaluated on the full pool).

    Checkpointing, resume, corrupt-step fallback and fault rollback follow
    the ``run_rounds`` contract, persisting {pool, history} in the pool
    per-shard layout.  Returns ``(pool, history)``.
    """
    if rounds < 0:
        raise ValueError(f"rounds must be >= 0, got {rounds}")
    if chunk < 1:
        raise ValueError("run_pooled_rounds requires chunk >= 1 (the pooled "
                         "engine has no Python-loop oracle; the dense engine "
                         "at K = N is the oracle)")
    if eval_every < 1:
        raise ValueError(f"eval_every must be >= 1, got {eval_every}")
    if cfg.n_clients != pool.size:
        raise ValueError(
            f"cfg.n_clients={cfg.n_clients} must equal the pool size "
            f"{pool.size} (the pool IS the client population)"
        )
    if not 1 <= cohort <= pool.size:
        raise ValueError(
            f"cohort={cohort} must be in [1, pool_size={pool.size}]"
        )
    if mesh is not None and diag_global_grad is not None:
        raise ValueError("diag_global_grad is only supported on the vmap path "
                         "(mesh=None)")
    if jax.process_count() > 1:
        raise NotImplementedError(
            "run_pooled_rounds is single-process for now: multi-process pools "
            "need per-process row ownership for gather/scatter (see ROADMAP)"
        )
    chunk = min(chunk, max(rounds, 1))
    x0 = jnp.asarray(x0)

    # The config the COHORT engine compiles against: the round body sees K
    # clients, so the masked aggregation's rates and the shard-divisibility
    # contract (K % n_shards == 0) are all relative to the cohort.
    ccfg = dataclasses.replace(cfg, n_clients=cohort)
    ufcfg = effective_config(faults, rounds)  # user faults (None if never active)
    # The body ALWAYS runs the masked sum_fn path: zero-rate + tolerate when
    # the caller injects nothing, so the denominator is the live cohort
    # count, never the dense n_clients mean.
    bcfg = ufcfg if ufcfg is not None else FaultConfig()

    run_meta = {"rounds": rounds, "chunk": chunk, "cfg": repr(cfg),
                "eval_every": eval_every, "faults": repr(ufcfg),
                "pool_size": pool.size, "cohort": cohort,
                "cohort_seed": cohort_seed}
    # Objectives are gathered per cohort from host copies, like the states.
    cobjs_host = jax.tree_util.tree_map(
        lambda a: np.asarray(jax.device_get(a)), cobjs
    )

    start, hist = 0, None
    if checkpoint_dir and resume and ckpt_io.latest_step(checkpoint_dir) is not None:
        r_leaves, r_hist, start = _restore_newest_good_pool(
            checkpoint_dir, run_meta, rounds, x0, pool
        )
        if r_hist is not None:
            pool.load_leaves(r_leaves)
            hist = r_hist
    if hist is None:
        hist = rounds_mod.history_init(rounds, x0, global_value_fn(cobjs, x0))

    sx = hist.xs[start]
    steps: dict[tuple, Any] = {}

    def step_for(k: int, body_cfg):
        skey = (k, body_cfg)
        if skey not in steps:
            if mesh is None:
                cf = rounds_mod.sim_chunk_fn(
                    ccfg, rff, query_fn, global_value_fn, diag_global_grad,
                    k, eval_every, rounds, faults=body_cfg,
                )
            else:
                cf = rounds_mod.dist_chunk_fn(
                    ccfg, mesh, rff, query_fn, global_value_fn,
                    k, eval_every, rounds, faults=body_cfg,
                )
            steps[skey] = rounds_mod.make_chunk_step(cf)
        return steps[skey]

    writer = (
        ckpt_io.AsyncCheckpointWriter()
        if (checkpoint_dir and async_checkpoint)
        else None
    )

    def snapshot():
        return ckpt_io.prepare_pool_state(
            pool.leaves, pool.treedef_str, pool.row_start, pool.size, hist
        )

    if ufcfg is not None and checkpoint_dir and ckpt_io.latest_step(checkpoint_dir) is None:
        # Rollback insurance: a restore target exists BEFORE the first
        # faulted chunk runs (one blocking write per fresh directory).
        ckpt_io.write_round_state(checkpoint_dir, start, snapshot(),
                                  extra_meta=run_meta)

    done, chunks_done, rollbacks = start, 0, 0
    try:
        while done < rounds:
            k = min(chunk, rounds - done)
            idx = sample_cohort(cohort_seed, done, pool.size, cohort)
            cstates = pool.gather(idx, mesh=mesh)
            c_cobjs = _gather_cobjs(cobjs_host, idx, pool.size, mesh)
            cstates, hist, sx = step_for(k, bcfg)(
                cstates, hist, c_cobjs, sx, jnp.asarray(done, jnp.int32)
            )
            done += k
            chunks_done += 1
            cstates = rounds_mod.boundary_repair_on_device(cstates, ccfg, mesh=mesh)
            if ufcfg is not None and bcfg.tolerate:
                # Re-admit quarantined cohort members BEFORE they scatter
                # back: a client never re-enters the pool quarantined.
                cstates = rounds_mod.boundary_quarantine_reset(
                    cstates, ccfg, sx, mesh=mesh
                )
            ok = True
            if ufcfg is not None:
                ok = bool(np.isfinite(np.asarray(jax.device_get(sx))).all())
            if ok:
                pool.scatter(idx, cstates)
            wrote_ok = True
            if ok and checkpoint_dir and (
                chunks_done % max(checkpoint_every, 1) == 0 or done == rounds
            ):
                payload = snapshot()
                try:
                    if writer is not None:
                        writer.submit(partial(
                            ckpt_io.write_round_state, checkpoint_dir, done,
                            payload, run_meta,
                        ))
                        if done >= rounds:
                            # Final boundary: drain now so a failed last
                            # write rolls back (see rounds.run_rounds).
                            writer.wait()
                    else:
                        ckpt_io.write_round_state(checkpoint_dir, done, payload,
                                                  extra_meta=run_meta)
                except OSError as e:
                    if ufcfg is None:
                        raise
                    print(f"[repro.pool] checkpoint write failed at round "
                          f"{done}: {e}")
                    wrote_ok = False
            if ufcfg is not None and (not ok or not wrote_ok):
                reason = ("non-finite server iterate" if not ok
                          else "checkpoint write failure")
                if not checkpoint_dir:
                    raise FloatingPointError(
                        f"{reason} at round {done} with no checkpoint_dir to "
                        "roll back to (chunk rollback needs checkpointing)"
                    )
                if rollbacks >= max_rollbacks:
                    raise FloatingPointError(
                        f"{reason} at round {done}: rollback budget "
                        f"max_rollbacks={max_rollbacks} exhausted"
                    )
                rollbacks += 1
                if writer is not None:
                    try:
                        writer.wait()
                    except OSError:
                        pass  # the failed write IS the fault being rolled back
                print(f"[repro.pool] ROLLBACK {rollbacks}/{max_rollbacks} at "
                      f"round {done} ({reason}): restoring last good checkpoint")
                r_leaves, r_hist, r_start = _restore_newest_good_pool(
                    checkpoint_dir, run_meta, rounds, x0, pool
                )
                if r_hist is None:
                    raise FloatingPointError(
                        f"rollback at round {done} failed: no restorable "
                        f"checkpoint under {checkpoint_dir!r}"
                    )
                pool.load_leaves(r_leaves)
                hist, done = r_hist, r_start
                sx = hist.xs[done]
                if not bcfg.tolerate:
                    print("[repro.pool] re-running with fault tolerance "
                          "FORCED ON")
                    bcfg = dataclasses.replace(bcfg, tolerate=True)
                chunks_done = 0
    finally:
        if writer is not None:
            writer.wait()

    return pool, hist
