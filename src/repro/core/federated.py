"""Distributed federated-ZOO engine: clients sharded over a device mesh.

The paper runs N clients as separate processes with a central server.  On a
TPU pod we map clients onto the mesh's ``data`` axis (and the ``pod`` axis in
multi-pod mode) with ``shard_map``:

  * each device hosts ``N / n_devices`` clients (an inner vmap),
  * the T local updates are collective-free by construction,
  * the server aggregation of the iterate x and the RFF weight vector w is a
    single ``psum`` over the client axes -- exactly the paper's one (or two,
    with round-end active queries / SCAFFOLD-I) transmissions per round.

Because the aggregation is the ONLY cross-device communication, the HLO of
one round makes the paper's communication-efficiency claim *inspectable*:
the all-reduce payload is ``d + M`` floats per round for FZooS vs ``d`` (plus
control variates) for the baselines, and the dry-run (launch/dryrun.py)
accounts those bytes in the roofline's collective term.

The per-client Gram-factor cache (``gp_surrogate.GramFactor``, three
(cap, cap) buffers riding in ``ClientState``) is DEVICE-LOCAL state: it
shards over the client axes with the rest of the state pytree and never
enters a collective -- ``shard_clients``/``distributed_round_fn`` treat it
like the trajectory ring buffer it summarizes.  At the default cap=128 that
is ~0.2 MB per client, so thousands of clients per device fit in HBM before
the trajectory itself becomes the constraint.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import algorithms as alg
from repro.core import rff as rfflib

Pytree = Any


def client_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that enumerate clients (everything except 'model')."""
    return tuple(n for n in mesh.axis_names if n != "model")


def _psum_mean(tree: Pytree, axes: tuple[str, ...], n_clients: int) -> Pytree:
    """Global mean over all clients: local sum -> psum over client axes -> /N."""

    def one(a):
        s = jnp.sum(a, axis=0)
        s = jax.lax.psum(s, axes)
        return s / n_clients

    return jax.tree_util.tree_map(one, tree)


def client_mean_fn(cfg: alg.AlgoConfig, mesh: Mesh):
    """(client axes, psum-mean aggregation fn) with the shard contract
    enforced: N clients must divide the product of the client mesh axes
    (equal-size shards are what makes mean-of-shard-means the global mean).
    """
    axes = client_axes(mesh)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    if cfg.n_clients % n_shards:
        raise ValueError(f"n_clients={cfg.n_clients} not divisible by client shards {n_shards}")
    return axes, partial(_psum_mean, axes=axes, n_clients=cfg.n_clients)


def client_sum_fn(mesh: Mesh):
    """Un-normalized global sum over all clients of ONE array: local axis-0
    sum -> psum over the client axes.  The aggregation primitive the
    fault-masked engine renormalizes by its own live count (the mask count
    rides inside the summed payload, so masking adds no extra psum)."""
    axes = client_axes(mesh)

    def one(a: jax.Array) -> jax.Array:
        return jax.lax.psum(jnp.sum(a, axis=0), axes)

    return one


def distributed_round_fn(
    cfg: alg.AlgoConfig,
    mesh: Mesh,
    rff: Optional[rfflib.RFFParams],
    query_fn: alg.QueryFn,
    faults=None,  # Optional[faults.FaultConfig]
):
    """Build a jitted one-round function with clients sharded over the mesh.

    Inputs (states, cobjs) are stacked over N clients; N must divide the
    product of the client mesh axes times 1-or-more clients per device.
    With ``faults`` the returned function takes an extra traced round-index
    argument: ``round_fn(states, cobjs, server_x, round_idx)``.
    """
    axes, mean_fn = client_mean_fn(cfg, mesh)
    sum_fn = client_sum_fn(mesh)

    cspec = P(axes)  # shard the client axis over all client mesh axes
    rspec = P()  # replicated

    if faults is None:
        def round_body(states, cobjs, server_x):
            new_states, stats = alg.run_round(
                cfg, rff, query_fn, cobjs, states, server_x, mean_fn, None
            )
            return new_states, stats

        in_specs = (cspec, cspec, rspec)
    else:
        def round_body(states, cobjs, server_x, round_idx):
            new_states, stats = alg.run_round(
                cfg, rff, query_fn, cobjs, states, server_x, mean_fn, None,
                sum_fn=sum_fn, faults=faults, round_idx=round_idx,
            )
            return new_states, stats

        in_specs = (cspec, cspec, rspec, rspec)

    shmapped = shard_map(
        round_body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(cspec, rspec),
        check_rep=False,
    )
    return jax.jit(shmapped)


def shard_clients(mesh: Mesh, tree: Pytree) -> Pytree:
    """Place a client-stacked pytree with the client axis sharded on the mesh."""
    axes = client_axes(mesh)
    sh = NamedSharding(mesh, P(axes))

    def put(a):
        return jax.device_put(a, sh)

    return jax.tree_util.tree_map(put, tree)


def run_distributed(
    cfg: alg.AlgoConfig,
    mesh: Mesh,
    key: jax.Array,
    cobjs,
    query_fn: alg.QueryFn,
    global_value_fn: Callable[[Any, jax.Array], jax.Array],
    rounds: int,
    x0: Optional[jax.Array] = None,
    chunk: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    eval_every: int = 1,
    async_checkpoint: bool = True,
    faults=None,  # Optional[faults.FaultConfig]
    max_rollbacks: int = 3,
    cohort: Optional[int] = None,
    cohort_seed: int = 0,
) -> alg.SimResult:
    """Distributed analogue of algorithms.simulate (same history contract).

    ``chunk`` selects the round driver exactly as in ``simulate``: ``None``
    scans ``rounds.DEFAULT_CHUNK``-round chunks INSIDE shard_map (one
    dispatch per chunk, the per-round psum stays the only collective),
    ``chunk=k>0`` sets the chunk length, ``chunk=0`` keeps the seed
    one-dispatch-per-round Python loop as the equivalence oracle.
    ``eval_every`` follows the ``simulate`` contract (skipped ``f_values``
    rows hold NaN).  Checkpoints on this path use the PER-SHARD layout
    (checkpoint/io.py): each process writes only its addressable slice of
    the client-sharded state, the chunk-boundary repair decision stays on
    device, and with ``async_checkpoint`` the file write overlaps the next
    chunk -- the steady-state boundary performs zero host syncs.

    ``cohort=K`` selects PARTIAL PARTICIPATION (core/pool.py): the full
    N-client population lives in a host-resident pool -- never sharded onto
    the mesh -- and each chunk a deterministic cohort of K clients is
    gathered onto the mesh, scanned, and scattered back.  Only K must
    divide the client shard count; N is a host-memory number.
    """
    if chunk is not None and chunk < 0:
        raise ValueError(f"chunk must be None, 0 (loop oracle) or positive, got {chunk}")
    if eval_every < 1:
        raise ValueError(f"eval_every must be >= 1, got {eval_every}")
    if x0 is None:
        x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    k_init, k_rff = jax.random.split(key)
    rff = None
    if cfg.is_fzoos:
        rff = rfflib.make_rff(k_rff, cfg.n_features, cfg.dim, cfg.lengthscale)

    if cohort is not None:
        if chunk == 0:
            raise ValueError("cohort (partial participation) requires the "
                             "scan driver (chunk != 0); the dense engine at "
                             "cohort == n_clients is the equivalence oracle")
        from repro.core import pool as pool_mod  # deferred: avoids cycle
        from repro.core import rounds as rounds_mod

        pool = pool_mod.init_pool(cfg, k_init, x0)
        _, res = pool_mod.run_pooled_rounds(
            cfg, rff, query_fn, cobjs, pool, x0, global_value_fn,
            rounds, chunk if chunk is not None else rounds_mod.DEFAULT_CHUNK,
            cohort=cohort, cohort_seed=cohort_seed, mesh=mesh,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            eval_every=eval_every, async_checkpoint=async_checkpoint,
            faults=faults, max_rollbacks=max_rollbacks,
        )
        return res

    states = alg.init_states(cfg, k_init, x0)
    states = shard_clients(mesh, states)
    cobjs = shard_clients(mesh, cobjs)

    if chunk is None or chunk > 0:
        from repro.core import rounds as rounds_mod  # deferred: avoids cycle

        if chunk is None:
            chunk = rounds_mod.DEFAULT_CHUNK
        _, res = rounds_mod.run_rounds(
            cfg, rff, query_fn, cobjs, states, x0, global_value_fn,
            rounds, chunk, mesh=mesh,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            eval_every=eval_every, async_checkpoint=async_checkpoint,
            faults=faults, max_rollbacks=max_rollbacks,
        )
        return res

    if checkpoint_dir:
        raise ValueError("checkpoint_dir requires the scan driver (chunk != 0)")
    from repro.core import rounds as rounds_mod  # deferred: avoids cycle

    if faults is not None:
        # Loop oracle matches the scan engine: a never-active window runs
        # the faults-free body (see rounds.run_rounds).
        from repro.faults.injector import effective_config
        faults = effective_config(faults, rounds)
    round_fn = distributed_round_fn(cfg, mesh, rff, query_fn, faults=faults)

    xs = [x0]
    fvals = [global_value_fn(cobjs, x0)]
    queries, coss, disps, rrs, reps = [], [], [], [], []
    drops, quars = [], []
    sx = x0
    for r in range(rounds):
        if faults is None:
            states, stats = round_fn(states, cobjs, sx)
        else:
            states, stats = round_fn(states, cobjs, sx, jnp.asarray(r, jnp.int32))
        if cfg.deferred:
            # Loop-oracle boundary: per-shard masked repair after every round
            # (the chunk=1 degenerate case of the deferred contract).
            states, _ = rounds_mod.repair_flagged_clients(states, cfg, mesh=mesh)
        sx = stats.server_x
        if faults is not None and faults.tolerate:
            states, _ = rounds_mod.quarantine_reset_flagged(
                states, cfg, sx, mesh=mesh
            )
        xs.append(sx)
        r1 = r + 1
        if r1 % eval_every == 0 or r1 == rounds:
            fvals.append(global_value_fn(cobjs, sx))
        else:
            fvals.append(jnp.full((), jnp.nan, jnp.float32))
        queries.append(stats.queries_per_client)
        coss.append(stats.mean_cos)
        disps.append(stats.mean_disparity)
        rrs.append(stats.refactor_rate)
        reps.append(stats.repair_rate)
        drops.append(stats.drop_rate)
        quars.append(stats.quarantine_rate)

    return alg.SimResult(
        xs=jnp.stack(xs),
        f_values=jnp.stack([jnp.asarray(f, jnp.float32) for f in fvals]),
        queries=jnp.stack(queries),
        mean_cos=jnp.stack(coss),
        mean_disparity=jnp.stack(disps),
        refactor_rate=jnp.stack(rrs),
        repair_rate=jnp.stack(reps),
        drop_rate=jnp.stack(drops),
        quarantine_rate=jnp.stack(quars),
    )
