"""Model-backed federated ZOO objectives -- the paper's real-world tasks.

1. Federated black-box adversarial attack (Sec. 6.2): N private classifiers
   trained on P-controlled label subsets; the ZOO input x is an image
   perturbation, the local function is client i's margin on z + x (lower is
   better; attack succeeds when the AVERAGE margin < 0).  No CIFAR ships in
   this container, so victims train on a synthetic blob-image task -- the
   optimization interface (query-only margins, P heterogeneity) is the
   paper's exactly.

2. Federated non-differentiable metric optimization (Sec. 6.3): a fully
   trained MLP is fine-tuned by perturbing its parameters to optimize
   1 - precision on each client's label subset (Covertype stand-in:
   synthetic 7-class tabular data).

3. LM-backbone objective (framework integration, DESIGN.md Sec. 5): the ZOO
   input reparameterizes a low-dim slice of ANY architecture-zoo model
   (theta = theta0 + scale * (x - 1/2) on the final-norm gains) and the local
   function is the client's own token-batch loss -- this is what
   launch/fedzoo.py --arch <id> runs.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import label_subset_partition
from repro.models.config import ModelConfig
from repro.models.model import lm_loss
from repro.optim import adam_init, adam_update
from repro.sharding.rules import ShardingPolicy


# ---------------------------------------------------------------------------
# shared tiny-MLP machinery (victims + metric model)
# ---------------------------------------------------------------------------


class MLPParams(NamedTuple):
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array


def mlp_init(key: jax.Array, d_in: int, d_hidden: int, n_classes: int) -> MLPParams:
    k1, k2 = jax.random.split(key)
    return MLPParams(
        w1=jax.random.normal(k1, (d_in, d_hidden)) / np.sqrt(d_in),
        b1=jnp.zeros((d_hidden,)),
        w2=jax.random.normal(k2, (d_hidden, n_classes)) / np.sqrt(d_hidden),
        b2=jnp.zeros((n_classes,)),
    )


def mlp_logits(p: MLPParams, x: jax.Array) -> jax.Array:
    h = jnp.tanh(x @ p.w1 + p.b1)
    return h @ p.w2 + p.b2


def _train_mlp(key, p: MLPParams, xs, ys, steps=300, lr=5e-3) -> MLPParams:
    opt = adam_init(p)

    def loss_fn(p):
        lg = mlp_logits(p, xs)
        return -jnp.mean(
            jax.nn.log_softmax(lg)[jnp.arange(xs.shape[0]), ys]
        )

    @jax.jit
    def step(p, opt):
        g = jax.grad(loss_fn)(p)
        return adam_update(opt, g, p, lr)

    for _ in range(steps):
        p, opt = step(p, opt)
    return p


# ---------------------------------------------------------------------------
# synthetic datasets
# ---------------------------------------------------------------------------


def blob_images(key: jax.Array, n: int, side: int = 16, n_classes: int = 10):
    """Class = a fixed spatial Gaussian-blob template + noise."""
    kt, kl, kn = jax.random.split(key, 3)
    ii, jj = jnp.meshgrid(jnp.arange(side), jnp.arange(side), indexing="ij")
    centers = jax.random.uniform(kt, (n_classes, 2), minval=3.0, maxval=side - 3.0)
    widths = jax.random.uniform(jax.random.fold_in(kt, 1), (n_classes,), minval=2.0, maxval=4.0)
    templates = jnp.exp(
        -((ii[None] - centers[:, 0, None, None]) ** 2 + (jj[None] - centers[:, 1, None, None]) ** 2)
        / (2 * widths[:, None, None] ** 2)
    )  # (C, side, side)
    labels = jax.random.randint(kl, (n,), 0, n_classes)
    imgs = templates[labels] + 0.3 * jax.random.normal(kn, (n, side, side))
    return imgs.reshape(n, side * side), labels


def tabular_covertype_like(key: jax.Array, n: int, d: int = 54, n_classes: int = 7):
    """Covertype stand-in: overlapping classes hard enough that the trained
    MLP sits visibly below 100% precision (so ZOO fine-tuning has headroom)."""
    kw, kx, kn = jax.random.split(key, 3)
    protos = jax.random.normal(kw, (n_classes, d))
    labels = jax.random.randint(jax.random.fold_in(kx, 1), (n,), 0, n_classes)
    xs = protos[labels] + 3.5 * jax.random.normal(kn, (n, d))
    return xs, labels


# ---------------------------------------------------------------------------
# 1) federated black-box adversarial attack (Sec. 6.2)
# ---------------------------------------------------------------------------


class AttackObjective(NamedTuple):
    """Stacked per-client victims + the target image (shared)."""

    victims: MLPParams  # leading axis N on every leaf
    z: jax.Array  # (d_img,) target image, shared
    label: jax.Array  # () true class, shared
    eps: jax.Array  # () L_inf attack radius (x in [0,1] -> [-eps, eps])
    noise_std: jax.Array  # ()


def make_attack_objective(
    key: jax.Array,
    n_clients: int = 10,
    p_shared: float = 0.5,
    side: int = 16,
    n_classes: int = 10,
    eps: float = 0.3,
    noise_std: float = 0.001,
    train_per_client: int = 512,
) -> tuple[AttackObjective, jax.Array]:
    """Trains N victims on P-controlled label subsets; picks a target image
    every victim classifies correctly.  Returns (objective, image)."""
    kd, kp, kt = jax.random.split(key, 3)
    xs, ys = blob_images(kd, 4096, side, n_classes)
    parts = label_subset_partition(np.asarray(ys), n_clients, p_shared, seed=int(kp[0]))

    victims = []
    for i, idx in enumerate(parts):
        sub = np.random.default_rng(i).choice(idx, size=min(train_per_client, len(idx)), replace=len(idx) < train_per_client)
        p0 = mlp_init(jax.random.fold_in(kt, i), side * side, 64, n_classes)
        victims.append(_train_mlp(jax.random.fold_in(kt, 100 + i), p0, xs[sub], ys[sub]))
    stacked = jax.tree_util.tree_map(lambda *vs: jnp.stack(vs), *victims)

    # target: the image with the LARGEST averaged true-class margin -- the
    # paper's success criterion is on the averaged model, and under strong
    # P-heterogeneity no single image may be known to every victim.
    def avg_margin(i):
        lg = jax.vmap(lambda vp: mlp_logits(vp, xs[i]))(stacked)  # (N, C)
        true = lg[:, ys[i]]
        other = jnp.max(lg - 1e9 * jax.nn.one_hot(ys[i], lg.shape[-1])[None], axis=-1)
        return jnp.mean(true - other)

    margins = jax.vmap(avg_margin)(jnp.arange(256))
    target = int(jnp.argmax(margins))
    rep = lambda v: jnp.full((n_clients,), v, jnp.float32)
    obj = AttackObjective(
        victims=stacked,
        z=jnp.broadcast_to(xs[target], (n_clients,) + xs[target].shape),
        label=jnp.full((n_clients,), ys[target], jnp.int32),
        eps=rep(eps),
        noise_std=rep(noise_std),
    )
    return obj, xs[target]


def attack_margin(cp: AttackObjective, x_unit: jax.Array) -> jax.Array:
    """f_i(x) = logit_true - max_other logit on z + perturbation.  < 0 ==
    this client misclassifies.  x_unit in [0,1]^d -> [-eps, eps]^d."""
    pert = (2.0 * x_unit - 1.0) * cp.eps
    lg = mlp_logits(cp.victims, cp.z + pert)
    true = lg[cp.label]
    other = jnp.max(lg - 1e9 * jax.nn.one_hot(cp.label, lg.shape[-1]), axis=-1)
    return (true - other) / 10.0  # scale into the |f|<=1 regime of Sec. 2


def attack_query(cp: AttackObjective, x_unit: jax.Array, key: jax.Array) -> jax.Array:
    return attack_margin(cp, x_unit) + cp.noise_std * jax.random.normal(key, ())


def attack_global_value(cps: AttackObjective, x_unit: jax.Array) -> jax.Array:
    return jnp.mean(jax.vmap(lambda cp: attack_margin(cp, x_unit))(cps))


def attack_success(cps: AttackObjective, x_unit: jax.Array) -> jax.Array:
    """Paper's success criterion: the AVERAGED margin misclassifies."""
    return (attack_global_value(cps, x_unit) < 0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# 2) federated non-differentiable metric optimization (Sec. 6.3)
# ---------------------------------------------------------------------------


class MetricObjective(NamedTuple):
    base: MLPParams  # theta* (shared; stacked for vmap)
    xs: jax.Array  # (N, n_eval, d) client eval data
    ys: jax.Array  # (N, n_eval)
    scale: jax.Array  # () perturbation scale
    noise_std: jax.Array  # ()
    n_classes: jax.Array  # ()


def make_metric_objective(
    key: jax.Array,
    n_clients: int = 7,
    p_shared: float = 0.7,
    n_eval: int = 256,
    scale: float = 0.25,
    noise_std: float = 0.001,
) -> tuple[MetricObjective, int]:
    """Returns (objective, perturbation dim d).  d = size of the output
    layer (w2, b2) of the fully trained MLP -- the fine-tuned slice."""
    kd, kt, kp = jax.random.split(key, 3)
    xs, ys = tabular_covertype_like(kd, 8192)
    p0 = mlp_init(kt, xs.shape[-1], 16, 7)
    theta = _train_mlp(jax.random.fold_in(kt, 1), p0, xs[:4096], ys[:4096], steps=150)

    parts = label_subset_partition(np.asarray(ys[4096:]), n_clients, p_shared, seed=int(kp[0]))
    exs, eys = [], []
    for i, idx in enumerate(parts):
        sub = np.random.default_rng(i).choice(idx, size=n_eval, replace=len(idx) < n_eval)
        exs.append(xs[4096:][sub])
        eys.append(ys[4096:][sub])
    rep = lambda v: jnp.full((n_clients,), v, jnp.float32)
    stacked_theta = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (n_clients,) + a.shape), theta
    )
    obj = MetricObjective(
        base=stacked_theta,
        xs=jnp.stack(exs),
        ys=jnp.stack(eys),
        scale=rep(scale),
        noise_std=rep(noise_std),
        n_classes=rep(7.0),
    )
    d = theta.w2.size + theta.b2.size
    return obj, d


def _perturbed(cp: MetricObjective, x_unit: jax.Array) -> MLPParams:
    delta = (2.0 * x_unit - 1.0) * cp.scale
    dw = delta[: cp.base.w2.size].reshape(cp.base.w2.shape)
    db = delta[cp.base.w2.size :].reshape(cp.base.b2.shape)
    return cp.base._replace(w2=cp.base.w2 + dw, b2=cp.base.b2 + db)


def soft_precision(logits: jax.Array, labels: jax.Array, n_classes: int) -> jax.Array:
    """Macro precision (the non-differentiable metric; argmax inside)."""
    preds = jnp.argmax(logits, -1)
    ph = jax.nn.one_hot(preds, n_classes)  # (n, C)
    lh = jax.nn.one_hot(labels, n_classes)
    tp = jnp.sum(ph * lh, axis=0)
    fp = jnp.sum(ph * (1 - lh), axis=0)
    support = jnp.sum(lh, axis=0) > 0
    prec = tp / jnp.maximum(tp + fp, 1.0)
    return jnp.sum(prec * support) / jnp.maximum(jnp.sum(support), 1.0)


def metric_value(cp: MetricObjective, x_unit: jax.Array) -> jax.Array:
    """f_i(x) = 1 - precision_i(theta* + delta(x))  (minimize)."""
    theta = _perturbed(cp, x_unit)
    lg = mlp_logits(theta, cp.xs)
    return 1.0 - soft_precision(lg, cp.ys, 7)


def metric_query(cp: MetricObjective, x_unit: jax.Array, key: jax.Array) -> jax.Array:
    return metric_value(cp, x_unit) + cp.noise_std * jax.random.normal(key, ())


def metric_global_value(cps: MetricObjective, x_unit: jax.Array) -> jax.Array:
    return jnp.mean(jax.vmap(lambda cp: metric_value(cp, x_unit))(cps))


# ---------------------------------------------------------------------------
# 3) LM-backbone objective: FZooS x architecture zoo
# ---------------------------------------------------------------------------


class LMObjective(NamedTuple):
    """Perturb the final-norm gains of a zoo model; f_i = client-batch loss."""

    batches_tokens: jax.Array  # (N, b, l)
    batches_labels: jax.Array  # (N, b, l)
    scale: jax.Array  # (N,)
    noise_std: jax.Array  # (N,)


def make_lm_objective(
    key: jax.Array,
    cfg: ModelConfig,
    n_clients: int,
    batch: int = 2,
    seq: int = 32,
    scale: float = 0.5,
    noise_std: float = 0.001,
):
    toks = jax.random.randint(key, (n_clients, batch, seq + 1), 0, cfg.vocab_size)
    rep = lambda v: jnp.full((n_clients,), v, jnp.float32)
    return LMObjective(
        batches_tokens=toks[..., :-1].astype(jnp.int32),
        batches_labels=toks[..., 1:].astype(jnp.int32),
        scale=rep(scale),
        noise_std=rep(noise_std),
    )


def make_lm_query(cfg: ModelConfig, params: dict, policy: ShardingPolicy | None = None):
    """Returns (query_fn, global_value_fn, dim).  The ZOO input x (in [0,1]^d,
    d = d_model) shifts the final-norm gains: gains = 1 + scale*(x - 1/2)."""
    policy = policy or ShardingPolicy(remat=False)

    def value(cp: LMObjective, x_unit: jax.Array) -> jax.Array:
        delta = cp.scale * (x_unit - 0.5)
        p2 = dict(params, final_norm=params["final_norm"] + delta.astype(params["final_norm"].dtype))
        batch = {"tokens": cp.batches_tokens, "labels": cp.batches_labels}
        total, _ = lm_loss(p2, cfg, batch, policy)
        return total / 10.0

    def query(cp, x, key):
        return value(cp, x) + cp.noise_std * jax.random.normal(key, ())

    def global_value(cps, x):
        return jnp.mean(jax.vmap(lambda cp: value(cp, x))(cps))

    return query, global_value, cfg.d_model, value
