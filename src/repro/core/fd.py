"""Finite-difference gradient estimation (paper eq. 3) -- the query-hungry
baseline estimator used by FedZO / FedProx / SCAFFOLD in the federated-ZOO
setting.

    Delta(x) = (1/Q) sum_q  (y(x + lam u_q) - y(x)) / lam * u_q

Each call consumes Q+1 function queries (Q perturbed + 1 at x); the paper's
query-inefficiency challenge (Sec. 3.2) is exactly this NTQ-per-round cost.
"""

from __future__ import annotations

from typing import Callable

import jax

QueryFn = Callable[..., jax.Array]  # (client_obj, x, key) -> noisy scalar


def sample_directions(key: jax.Array, q: int, dim: int) -> jax.Array:
    """u_q ~ N(0, I) as in the paper (Lemma D.1)."""
    return jax.random.normal(key, (q, dim))


def fd_grad(
    query_fn: QueryFn,
    client_obj,
    x: jax.Array,
    key: jax.Array,
    directions: jax.Array,
    lam: float,
) -> jax.Array:
    """Finite-difference estimate of grad f at x.  directions: (Q, d)."""
    q = directions.shape[0]
    kbase, kpert = jax.random.split(key)
    y0 = query_fn(client_obj, x, kbase)
    pert_keys = jax.random.split(kpert, q)
    ys = jax.vmap(lambda u, k: query_fn(client_obj, x + lam * u, k))(directions, pert_keys)
    coef = (ys - y0) / lam  # (Q,)
    return (coef[:, None] * directions).sum(axis=0) / q


def fd_queries(q: int) -> int:
    """Queries consumed per fd_grad call."""
    return q + 1
