"""Federated ZOO algorithms under the paper's unified update (eq. 2):

    ghat^(i)_{r,t-1} = g^(i)_{r,t-1} + gamma * ( g_{r-1}(x') - g^(i)_{r-1}(x'') )

Instances (Sec. 3.1 + Appx. D):

  fzoos      g = derived-GP surrogate grad_mu at the CURRENT iterate,
             correction = grad_muhat_global(x) - grad_muhat_local(x) via RFF,
             gamma adaptive (1/t practical choice, Cor. C.1)        [Algo. 2]
  fedzo      g = finite difference, gamma = 0                        [2]
  fedprox    g = finite difference, correction = x - x_{r-1}, gamma=mu [4]
  scaffold1  g = FD, correction = mean_j FD_j(x_{r-1}) - FD_i(x_{r-1}), gamma=1
  scaffold2  g = FD, correction = round-averaged FD gradients, gamma=1

The round structure mirrors Algo. 1/2 exactly: T collective-free local steps
per client, one x-aggregation, then (FZooS) round-end active queries, the RFF
re-fit and one w-aggregation -- i.e. the paper's one-or-two transmissions per
round.  ``mean_fn`` abstracts the server aggregation so the same code runs
under single-process vmap simulation and under shard_map on a device mesh
(see repro.core.federated).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import fd as fdlib
from repro.core import gp_surrogate as gp
from repro.core import rff as rfflib
from repro.optim import make_optimizer

Pytree = Any
QueryFn = Callable[..., jax.Array]
MeanFn = Callable[[Pytree], Pytree]

ALGORITHMS = ("fzoos", "fedzo", "fedprox", "scaffold1", "scaffold2")


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    """Static (hashable) algorithm configuration."""

    name: str
    dim: int
    n_clients: int
    eta: float = 0.01
    local_steps: int = 10  # T
    optimizer: str = "adam"  # paper Appx. E: Adam, lr 0.01
    # finite-difference baselines
    q: int = 20
    fd_lambda: float = 5e-3  # FD probe; must sit below curvature scale (see tests)
    # FedProx proximal coefficient (its gamma in eq. 2)
    prox_mu: float = 1.0
    # FZooS surrogate machinery
    n_features: int = 512  # M
    traj_capacity: int = 128
    lengthscale: float = 1.0
    noise: float = 1e-4
    gamma_mode: str = "inv_t"  # inv_t | const  (Cor. C.1 practical choice)
    gamma_const: float = 1.0
    active_per_iter: int = 5
    active_candidates: int = 100
    active_radius: float = 0.01
    active_round_end: int = 5
    # Per-step surrogate hot path: carry an incrementally maintained Gram
    # factorization in ClientState (DESIGN.md Sec. 2) instead of
    # refactorizing at every surrogate evaluation.  False = the seed's
    # eigh-from-scratch path, kept as the equivalence oracle for tests.
    use_factor_cache: bool = True
    # Deferred-repair vmapped engine (DESIGN.md Sec. 2.6): the scanned round
    # body is branch-free and eigh-free -- an unhealthy factor update flags
    # the client and freezes its factors until the chunk-boundary repair pass
    # -- and the local/post phases run client-BATCHED (one fused kernel
    # launch per step for the whole client batch).  False keeps PR 2's
    # inline-cond per-client path as the equivalence oracle, analogous to
    # use_factor_cache=False / chunk=0.  Only meaningful for fzoos with the
    # factor cache on (see ``deferred``).
    defer_repair: bool = True
    # Round-end RFF fit: solve through the exact-GP cached factor (one
    # O(cap^2) solve) instead of eigh-refactorizing the RFF Gram.  Off by
    # default: the RFF-Gram solve is the paper's eq. 6 and changing it
    # perturbs w by the O(1/sqrt(M)) feature-approximation error.
    rff_fit_exact: bool = False
    # Kernel tiling overrides for the client-batched scoring / grad-mean
    # Pallas kernels (kernels/ops.py).  None defers to the deterministic
    # per-(backend, shape) autotuner (kernels/autotune.py); pinning them
    # here makes a run's tiling reproducible independent of the autotuner's
    # model (the choice only affects scheduling, never results -- padded
    # trajectory slots contribute exactly zero on the tiled path).
    score_block_n: Optional[int] = None
    score_block_cap: Optional[int] = None
    grad_block_n: Optional[int] = None
    grad_block_cap: Optional[int] = None
    # domain
    lo: float = 0.0
    hi: float = 1.0

    def __post_init__(self):
        if self.name not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.name!r}; choose from {ALGORITHMS}")
        if self.rff_fit_exact and not self.use_factor_cache:
            raise ValueError("rff_fit_exact=True requires use_factor_cache=True "
                             "(the round-end fit consumes the cached Gram factor)")

    @property
    def is_fzoos(self) -> bool:
        return self.name == "fzoos"

    @property
    def deferred(self) -> bool:
        """True when the deferred-repair client-batched engine is active."""
        return self.is_fzoos and self.use_factor_cache and self.defer_repair

    @property
    def uses_fd(self) -> bool:
        return self.name in ("fedzo", "fedprox", "scaffold1", "scaffold2")

    def queries_per_round(self) -> int:
        """Static per-client query count per round (EXPERIMENTS.md bookkeeping)."""
        t = self.local_steps
        if self.is_fzoos:
            return t * (1 + self.active_per_iter) + self.active_round_end
        per_iter = fdlib.fd_queries(self.q)
        extra = fdlib.fd_queries(self.q) if self.name == "scaffold1" else 0
        return t * per_iter + extra

    def comm_floats_per_round(self) -> int:
        """Client->server payload floats per round (communication claim)."""
        base = self.dim  # the iterate
        if self.is_fzoos:
            return base + self.n_features  # + w^(i)  (Sec. 4.2.1)
        if self.name in ("scaffold1", "scaffold2"):
            return base + self.dim  # + control variate
        return base


class ClientState(NamedTuple):
    x: jax.Array  # (d,)
    traj: gp.Trajectory  # ring buffer (fzoos; 1-slot dummy otherwise)
    factor: gp.GramFactor  # cached Gram factorization of `traj` (DESIGN.md Sec. 2)
    w_local: jax.Array  # (M,) RFF weights of own surrogate at end of prev round
    w_global: jax.Array  # (M,) server-averaged weights
    c_local: jax.Array  # (d,) SCAFFOLD control variate
    c_global: jax.Array  # (d,)
    fd_bank: jax.Array  # (Q, d) shared direction bank (scaffold2, Prop. D.4)
    fd_accum: jax.Array  # (d,) running sum of FD grads this round (scaffold2)
    opt: Any  # local optimizer state
    queries: jax.Array  # () int32 cumulative per-client query counter
    key: jax.Array
    client_id: jax.Array  # () int32 global client identity (fault schedules)
    quarantined: jax.Array  # () bool -- excluded from aggregation until the
    #   chunk-boundary re-init (the fault-tolerance analogue of needs_repair)


class RoundStats(NamedTuple):
    server_x: jax.Array  # (d,) aggregated iterate after the round
    mean_cos: jax.Array  # () mean cos(ghat, grad F) over clients x iters (diag)
    mean_disparity: jax.Array  # () mean ||ghat - grad F||^2 (Thm. 1 Xi)
    queries_per_client: jax.Array  # () mean cumulative queries
    refactor_rate: jax.Array  # () mean clamped-eigh fallbacks / factor updates
    repair_rate: jax.Array  # () fraction of clients flagged needs_repair
    drop_rate: jax.Array  # () fraction of clients NOT contributing this round
    quarantine_rate: jax.Array  # () fraction of clients quarantined


def _hyper_of(cfg: AlgoConfig) -> gp.GPHyper:
    return gp.GPHyper(jnp.asarray(cfg.lengthscale), jnp.asarray(cfg.noise))


def init_client_state(cfg: AlgoConfig, key: jax.Array, x0: jax.Array,
                      client_id: int | jax.Array = 0) -> ClientState:
    cap = cfg.traj_capacity if cfg.is_fzoos else 1
    m = cfg.n_features if cfg.is_fzoos else 1
    qd = cfg.q if cfg.name == "scaffold2" else 1
    opt_init, _ = make_optimizer(cfg.optimizer)
    # The shared direction bank must be identical across clients (Prop. D.4):
    # derive it from a constant key, not the per-client key.
    # key-flow: ok (constant bank is intentional; collision with a user seed
    # requires a 2^-64 key-space coincidence)
    bank = fdlib.sample_directions(jax.random.PRNGKey(12345), qd, cfg.dim)
    traj0 = gp.traj_init(cap, cfg.dim)
    return ClientState(
        x=x0,
        traj=traj0,
        factor=gp.factor_init(traj0, _hyper_of(cfg)),
        w_local=jnp.zeros((m,), jnp.float32),
        w_global=jnp.zeros((m,), jnp.float32),
        c_local=jnp.zeros((cfg.dim,), jnp.float32),
        c_global=jnp.zeros((cfg.dim,), jnp.float32),
        fd_bank=bank,
        fd_accum=jnp.zeros((cfg.dim,), jnp.float32),
        opt=opt_init(x0),
        queries=jnp.zeros((), jnp.int32),
        key=key,
        client_id=jnp.asarray(client_id, jnp.int32),
        quarantined=jnp.zeros((), bool),
    )


def init_states(cfg: AlgoConfig, key: jax.Array, x0: jax.Array) -> ClientState:
    """Stacked states for all clients (leading axis N)."""
    keys = jax.random.split(key, cfg.n_clients)
    ids = jnp.arange(cfg.n_clients, dtype=jnp.int32)
    return jax.vmap(lambda k, i: init_client_state(cfg, k, x0, i))(keys, ids)


# ---------------------------------------------------------------------------
# Local phase: T collective-free steps on one client
# ---------------------------------------------------------------------------


def _estimate_gradient(
    cfg: AlgoConfig,
    rff: Optional[rfflib.RFFParams],
    query_fn: QueryFn,
    cobj,
    st: ClientState,
    server_x: jax.Array,
    t: jax.Array,
    key: jax.Array,
) -> tuple[jax.Array, ClientState]:
    """ghat^(i)_{r,t-1} per eq. (2)/(8).  Returns (ghat, state-with-queries)."""
    x = st.x
    if cfg.is_fzoos:
        hyper = _hyper_of(cfg)
        if cfg.use_factor_cache:
            g_loc = gp.grad_mean_cached(st.traj, st.factor, hyper, x)
        else:
            g_loc = gp.grad_mean(st.traj, hyper, x)
        corr = rfflib.grad_features_t_w(rff, x, st.w_global) - rfflib.grad_features_t_w(rff, x, st.w_local)
        if cfg.gamma_mode == "inv_t":
            gamma = 1.0 / t.astype(jnp.float32)  # Cor. C.1 practical choice
        else:
            gamma = jnp.asarray(cfg.gamma_const, jnp.float32)
        return g_loc + gamma * corr, st

    # FD family.  (Prop. D.4 analyzes SCAFFOLD-II under a shared direction
    # bank; with Q < d that traps the iterate in a Q-dim subspace forever,
    # so the executable algorithm samples fresh directions like the others.)
    key, kd = jax.random.split(key)
    dirs = fdlib.sample_directions(kd, cfg.q, cfg.dim)
    g_fd = fdlib.fd_grad(query_fn, cobj, x, key, dirs, cfg.fd_lambda)
    st = st._replace(queries=st.queries + fdlib.fd_queries(cfg.q))
    if cfg.name == "fedzo":
        return g_fd, st
    if cfg.name == "fedprox":
        return g_fd + cfg.prox_mu * (x - server_x), st
    # scaffold1 / scaffold2: gamma = 1 control-variate correction
    st = st._replace(fd_accum=st.fd_accum + g_fd)
    return g_fd + (st.c_global - st.c_local), st


def _local_phase(
    cfg: AlgoConfig,
    rff: Optional[rfflib.RFFParams],
    query_fn: QueryFn,
    cobj,
    st: ClientState,
    server_x: jax.Array,
    diag_global_grad: Optional[Callable[[jax.Array], jax.Array]],
) -> tuple[ClientState, jax.Array, jax.Array]:
    """Run T local steps.  Returns (state, sum_cos, sum_disparity)."""
    _, opt_update = make_optimizer(cfg.optimizer)

    def step(carry, t):
        st: ClientState = carry
        key, k_obs, k_act, k_est = jax.random.split(st.key, 4)
        st = st._replace(key=key)

        if cfg.is_fzoos:
            # Trajectory-informed: query the current iterate (+ active queries)
            # BEFORE estimating -- the estimate is conditioned on D_{r,t-1}.
            hyper = _hyper_of(cfg)
            y = query_fn(cobj, st.x, k_obs)
            if cfg.use_factor_cache:
                traj, factor = gp.traj_extend(
                    st.traj, st.factor, st.x[None, :], y[None], hyper
                )
            else:
                traj, factor = gp.traj_append(st.traj, st.x, y), st.factor
            n_q = 1
            if cfg.active_per_iter > 0:
                if cfg.use_factor_cache:
                    cands = gp.select_active_queries_cached(
                        k_act, traj, factor, hyper, st.x, cfg.active_candidates,
                        cfg.active_per_iter, cfg.active_radius, cfg.lo, cfg.hi,
                    )
                else:
                    cands = gp.select_active_queries(
                        k_act, traj, hyper, st.x, cfg.active_candidates, cfg.active_per_iter,
                        cfg.active_radius, cfg.lo, cfg.hi,
                    )
                # key-flow: ok (k_act sample/fold streams audited; kept for
                # bitwise seed-replay compatibility)
                kq = jax.random.split(jax.random.fold_in(k_act, 1), cfg.active_per_iter)
                ys = jax.vmap(lambda c, k: query_fn(cobj, c, k))(cands, kq)
                if cfg.use_factor_cache:
                    traj, factor = gp.traj_extend(traj, factor, cands, ys, hyper)
                else:
                    traj = gp.traj_append_batch(traj, cands, ys)
                n_q += cfg.active_per_iter
            st = st._replace(traj=traj, factor=factor, queries=st.queries + n_q)

        ghat, st = _estimate_gradient(cfg, rff, query_fn, cobj, st, server_x, t, k_est)
        new_x, new_opt = opt_update(st.opt, ghat, st.x, cfg.eta)
        new_x = jnp.clip(new_x, cfg.lo, cfg.hi)

        if diag_global_grad is not None:
            gf = diag_global_grad(st.x)
            cos = jnp.dot(ghat, gf) / (jnp.linalg.norm(ghat) * jnp.linalg.norm(gf) + 1e-12)
            disp = jnp.sum((ghat - gf) ** 2)
        else:
            cos = jnp.zeros(())
            disp = jnp.zeros(())

        st = st._replace(x=new_x, opt=new_opt)
        return st, (cos, disp)

    ts = jnp.arange(1, cfg.local_steps + 1)
    st, (coss, disps) = jax.lax.scan(step, st, ts)
    return st, jnp.sum(coss), jnp.sum(disps)


# ---------------------------------------------------------------------------
# Client-batched local/post phases (the deferred-repair engine).
#
# The per-client ``_local_phase`` is scanned over T INSIDE a client vmap, so
# every surrogate contraction launches once per client.  Local steps are
# collective-free and clients share all shapes, so scan-over-T with the
# client batch INSIDE each step is the same algorithm -- and lets the
# scoring / gradient-mean kernels take the whole client batch in ONE launch
# (the client grid dimension of kernels/gp_score.py, gp_grad.py).  RNG key
# derivations mirror the per-client path exactly, so the two engines follow
# the same query sequence up to f32 contraction ordering.
# ---------------------------------------------------------------------------


def _local_phase_clients(
    cfg: AlgoConfig,
    rff: rfflib.RFFParams,
    query_fn: QueryFn,
    cobjs,
    states: ClientState,  # stacked (N, ...)
    diag_global_grad: Optional[Callable[[jax.Array], jax.Array]],
) -> tuple[ClientState, jax.Array, jax.Array]:
    """T local FZooS steps for the whole client batch (deferred factors)."""
    _, opt_update = make_optimizer(cfg.optimizer)
    hyper = _hyper_of(cfg)

    def step(sts: ClientState, t):
        ks = jax.vmap(lambda k: jax.random.split(k, 4))(sts.key)  # (N, 4, 2)
        sts = sts._replace(key=ks[:, 0])
        k_act = ks[:, 2]

        y = jax.vmap(query_fn)(cobjs, sts.x, ks[:, 1])
        traj, factor = gp.traj_extend_clients(
            sts.traj, sts.factor, sts.x[:, None, :], y[:, None], hyper, deferred=True
        )
        n_q = 1
        if cfg.active_per_iter > 0:
            cands = gp.select_active_queries_cached_clients(
                k_act, traj, factor, hyper, sts.x, cfg.active_candidates,
                cfg.active_per_iter, cfg.active_radius, cfg.lo, cfg.hi,
                block_n=cfg.score_block_n, block_cap=cfg.score_block_cap,
            )  # (N, n_act, d)
            kq = jax.vmap(
                # key-flow: ok (k_act sample/fold streams audited; kept for
                # bitwise seed-replay compatibility)
                lambda k: jax.random.split(jax.random.fold_in(k, 1), cfg.active_per_iter)
            )(k_act)
            ys = jax.vmap(
                lambda cobj, cs, kk: jax.vmap(lambda c, k: query_fn(cobj, c, k))(cs, kk)
            )(cobjs, cands, kq)
            traj, factor = gp.traj_extend_clients(traj, factor, cands, ys, hyper, deferred=True)
            n_q += cfg.active_per_iter
        sts = sts._replace(traj=traj, factor=factor, queries=sts.queries + n_q)

        # eq. (2): batched surrogate mean + per-client RFF correction
        g_loc = gp.grad_mean_cached_clients(
            traj, factor, hyper, sts.x,
            block_n=cfg.grad_block_n, block_cap=cfg.grad_block_cap,
        )  # (N, d)
        corr = rfflib.grad_features_t_w_rows(rff, sts.x, sts.w_global) - \
            rfflib.grad_features_t_w_rows(rff, sts.x, sts.w_local)
        if cfg.gamma_mode == "inv_t":
            gamma = 1.0 / t.astype(jnp.float32)
        else:
            gamma = jnp.asarray(cfg.gamma_const, jnp.float32)
        ghat = g_loc + gamma * corr

        new_x, new_opt = jax.vmap(lambda o, g, x: opt_update(o, g, x, cfg.eta))(
            sts.opt, ghat, sts.x
        )
        new_x = jnp.clip(new_x, cfg.lo, cfg.hi)

        if diag_global_grad is not None:
            gf = jax.vmap(diag_global_grad)(sts.x)
            cos = jnp.sum(ghat * gf, -1) / (
                jnp.linalg.norm(ghat, axis=-1) * jnp.linalg.norm(gf, axis=-1) + 1e-12
            )
            disp = jnp.sum((ghat - gf) ** 2, -1)
        else:
            cos = jnp.zeros(sts.x.shape[:1])
            disp = jnp.zeros(sts.x.shape[:1])

        sts = sts._replace(x=new_x, opt=new_opt)
        return sts, (cos, disp)

    ts = jnp.arange(1, cfg.local_steps + 1)
    states, (coss, disps) = jax.lax.scan(step, states, ts)
    return states, jnp.sum(coss, axis=0), jnp.sum(disps, axis=0)


def _post_phase_clients(
    cfg: AlgoConfig,
    rff: rfflib.RFFParams,
    query_fn: QueryFn,
    cobjs,
    states: ClientState,
    new_server_x: jax.Array,
) -> ClientState:
    """Round-end active queries + eigh-free RFF fit for the client batch."""
    hyper = _hyper_of(cfg)
    states = states._replace(x=jnp.broadcast_to(new_server_x, states.x.shape))
    ks = jax.vmap(lambda k: jax.random.split(k, 2))(states.key)
    states = states._replace(key=ks[:, 0])
    k_act = ks[:, 1]
    traj, factor = states.traj, states.factor
    if cfg.active_round_end > 0:
        cands = gp.select_active_queries_cached_clients(
            k_act, traj, factor, hyper, states.x, cfg.active_candidates,
            cfg.active_round_end, cfg.active_radius, cfg.lo, cfg.hi,
            block_n=cfg.score_block_n, block_cap=cfg.score_block_cap,
        )
        kq = jax.vmap(
            # key-flow: ok (k_act sample/fold streams audited; kept for
            # bitwise seed-replay compatibility)
            lambda k: jax.random.split(jax.random.fold_in(k, 2), cfg.active_round_end)
        )(k_act)
        ys = jax.vmap(
            lambda cobj, cs, kk: jax.vmap(lambda c, k: query_fn(cobj, c, k))(cs, kk)
        )(cobjs, cands, kq)
        traj, factor = gp.traj_extend_clients(traj, factor, cands, ys, hyper, deferred=True)
        states = states._replace(
            traj=traj, factor=factor, queries=states.queries + cfg.active_round_end
        )
    if cfg.rff_fit_exact:
        w_i = jax.vmap(lambda tr, fa: rfflib.fit_w_from_factor(rff, tr, fa))(traj, factor)
    else:
        # eq. 6 via blocked Cholesky with a branch-free exact-factor fallback
        # -- the ONLY eigh of the seed round body that defer_repair does not
        # merely defer, it removes (fit_w's clamped eigh was robustness, not
        # math: see rff.fit_w_chol).
        w_i = jax.vmap(lambda tr, fa: rfflib.fit_w_chol(rff, tr, hyper, fa))(traj, factor)
    return states._replace(w_local=w_i)


# ---------------------------------------------------------------------------
# One full communication round (Algo. 1 / Algo. 2)
# ---------------------------------------------------------------------------


def run_round(
    cfg: AlgoConfig,
    rff: Optional[rfflib.RFFParams],
    query_fn: QueryFn,
    cobjs,  # stacked per-client objective params (leading axis = local clients)
    states: ClientState,  # stacked states (leading axis = local clients)
    server_x: jax.Array,  # (d,)
    mean_fn: MeanFn,  # server aggregation over ALL clients
    diag_global_grad: Optional[Callable[[jax.Array], jax.Array]] = None,
    *,
    sum_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
    faults=None,  # Optional[faults.FaultConfig]
    round_idx: Optional[jax.Array] = None,
) -> tuple[ClientState, RoundStats]:
    """One communication round.

    With ``faults=None`` (the default) this is structurally the fault-free
    engine: no mask ops are traced and the output is bitwise what it was
    before the fault layer existed.  With a ``faults.FaultConfig``, fault
    draws for ``round_idx`` are injected and (when ``faults.tolerate``) the
    aggregations switch to masked participation-weighted means renormalized
    by the live-client count: the live mask and the quarantine count ride
    INSIDE the existing payload arrays (one extra row of the concatenated
    psum operand), so masking adds ZERO collectives to the round
    (analysis/contracts.py pins the census).  ``sum_fn`` must then be the
    un-normalized global sum (``federated.client_sum_fn`` on a mesh; plain
    axis-0 sum under vmap simulation).
    """
    opt_init, _ = make_optimizer(cfg.optimizer)
    draws = None
    if faults is not None:
        if sum_fn is None or round_idx is None:
            raise ValueError("faults injection requires sum_fn and round_idx")
        from repro.faults import draw_faults  # deferred: keep import DAG slim

        draws = draw_faults(faults, round_idx, states.client_id)

    # ---- prologue: broadcast x_r, reset local optimizers ----
    def prologue(st: ClientState, cobj) -> ClientState:
        st = st._replace(x=server_x, opt=opt_init(server_x), fd_accum=jnp.zeros_like(server_x))
        if cfg.name == "scaffold1":
            # c_i <- FD estimate at x_{r-1}; requires one extra transmission
            # (SCAFFOLD Type I per Appx. D).
            key, kd, kf = jax.random.split(st.key, 3)
            dirs = fdlib.sample_directions(kd, cfg.q, cfg.dim)
            c_i = fdlib.fd_grad(query_fn, cobj, server_x, kf, dirs, cfg.fd_lambda)
            st = st._replace(key=key, c_local=c_i, queries=st.queries + fdlib.fd_queries(cfg.q))
        return st

    states = jax.vmap(prologue)(states, cobjs)
    if cfg.name == "scaffold1":
        c_glob = mean_fn(states.c_local)
        states = states._replace(c_global=jnp.broadcast_to(c_glob, states.c_global.shape))

    # Post-prologue snapshot: faulted clients (dropped / straggling /
    # quarantined) roll their local state back to this point at round end --
    # a client that did not deliver an update must not advance.
    states0 = states if faults is not None else None

    # ---- T local steps on every client in parallel ----
    if cfg.deferred:
        # Deferred-repair engine: branch-free factor updates, client-batched
        # surrogate kernels (one launch per step for the whole batch).
        states, sum_cos, sum_disp = _local_phase_clients(
            cfg, rff, query_fn, cobjs, states, diag_global_grad
        )
    else:
        local = partial(_local_phase, cfg, rff, query_fn)
        states, sum_cos, sum_disp = jax.vmap(
            lambda cobj, st: local(cobj, st, server_x, diag_global_grad)
        )(cobjs, states)

    # ---- server aggregation of the iterates (line 7/9 of Algo. 1/2) ----
    zero = jnp.zeros((), jnp.float32)
    live = quar = n_live = n_quar = None
    if faults is None:
        new_server_x = mean_fn(states.x)
    else:
        # Inject the payload faults on the UPDATE, never on the state: the
        # client's own state stays finite and is rolled back below.
        x_up = states.x
        if faults.nan_rate > 0:
            x_up = jnp.where(draws.nan[:, None], jnp.float32(jnp.nan), x_up)
        if faults.inf_rate > 0:
            x_up = jnp.where(draws.inf[:, None], jnp.float32(jnp.inf), x_up)
        # straggler: the server sees its STALE iterate (this round's broadcast)
        x_up = jnp.where(draws.straggle[:, None], server_x, x_up)
        if faults.tolerate:
            # On-device liveness + health mask.  NOTE: jnp.where, never
            # multiply-by-mask -- NaN * 0 is NaN and would defeat the mask.
            finite = jnp.all(jnp.isfinite(x_up), axis=-1)
            quar = states.quarantined | (~finite & ~draws.drop)
            live = ~draws.drop & ~states.quarantined & finite
            # The live count and quarantine census ride as two extra rows of
            # the SAME psum operand: masking adds zero collectives.
            payload = jnp.concatenate(
                [jnp.where(live[:, None], x_up, 0.0),
                 live.astype(jnp.float32)[:, None],
                 quar.astype(jnp.float32)[:, None]], axis=1)
            tot = sum_fn(payload)
            n_live, n_quar = tot[cfg.dim], tot[cfg.dim + 1]
            new_server_x = jnp.where(
                n_live > 0, tot[: cfg.dim] / jnp.maximum(n_live, 1.0), server_x)
        else:
            # No tolerance: a dropped client is simply never heard from, and
            # the dense mean treats silence as NaN -- the poisoning failure
            # mode the masked path removes (and the rollback demo trigger).
            x_up = jnp.where(draws.drop[:, None], jnp.float32(jnp.nan), x_up)
            new_server_x = sum_fn(x_up) / cfg.n_clients

    # ---- post phase ----
    def post(st: ClientState, cobj) -> ClientState:
        st = st._replace(x=new_server_x)
        if cfg.is_fzoos:
            key, k_act = jax.random.split(st.key)
            st = st._replace(key=key)
            traj, factor = st.traj, st.factor
            hyper = _hyper_of(cfg)
            if cfg.active_round_end > 0:
                # Active queries around x_r (line 7 of Algo. 2) sharpen the
                # correction term (2) in Thm. 1 before w is fitted & shipped.
                if cfg.use_factor_cache:
                    cands = gp.select_active_queries_cached(
                        k_act, traj, factor, hyper, new_server_x, cfg.active_candidates,
                        cfg.active_round_end, cfg.active_radius, cfg.lo, cfg.hi,
                    )
                else:
                    cands = gp.select_active_queries(
                        k_act, traj, hyper, new_server_x, cfg.active_candidates,
                        cfg.active_round_end, cfg.active_radius, cfg.lo, cfg.hi,
                    )
                # key-flow: ok (k_act sample/fold streams audited; kept for
                # bitwise seed-replay compatibility)
                kq = jax.random.split(jax.random.fold_in(k_act, 2), cfg.active_round_end)
                ys = jax.vmap(lambda c, k: query_fn(cobj, c, k))(cands, kq)
                if cfg.use_factor_cache:
                    traj, factor = gp.traj_extend(traj, factor, cands, ys, hyper)
                else:
                    traj = gp.traj_append_batch(traj, cands, ys)
                st = st._replace(
                    traj=traj, factor=factor, queries=st.queries + cfg.active_round_end
                )
            if cfg.rff_fit_exact and cfg.use_factor_cache:
                w_i = rfflib.fit_w_from_factor(rff, traj, factor)
            else:
                w_i = rfflib.fit_w(rff, traj, hyper)
            st = st._replace(w_local=w_i)
        elif cfg.name == "scaffold2":
            st = st._replace(c_local=st.fd_accum / cfg.local_steps)
        return st

    if cfg.deferred:
        states = _post_phase_clients(cfg, rff, query_fn, cobjs, states, new_server_x)
    else:
        states = jax.vmap(post)(states, cobjs)

    # ---- fault response: roll faulted clients back to the round prologue ----
    if faults is not None and faults.tolerate:
        # A client that did not deliver (drop/straggle) or is quarantined
        # keeps its pre-round state -- its trajectory, factors, w and RNG
        # stream advance only on rounds it actually completes.
        frozen = draws.drop | draws.straggle | quar

        def _freeze(old, new):
            f = frozen.reshape(frozen.shape + (1,) * (new.ndim - 1))
            return jnp.where(f, old, new)

        states = jax.tree_util.tree_map(_freeze, states0, states)
        states = states._replace(quarantined=quar)

    # ---- second aggregation: w (FZooS) / control variates (scaffold2) ----
    if cfg.is_fzoos:
        if faults is not None and faults.tolerate:
            # stragglers contribute their (stale) w; quarantined and dropped
            # clients are masked out, count packed into the same psum operand
            m_w = ~draws.drop & ~quar
            w_pay = jnp.concatenate(
                [jnp.where(m_w[:, None], states.w_local, 0.0),
                 m_w.astype(jnp.float32)[:, None]], axis=1)
            w_tot = sum_fn(w_pay)
            w_glob = jnp.where(
                w_tot[-1] > 0, w_tot[:-1] / jnp.maximum(w_tot[-1], 1.0),
                # all clients dead: keep the previous global w (replicated
                # rows, so the LOCAL mean is the global value -- no psum)
                jnp.mean(states.w_global, axis=0))
        else:
            w_glob = mean_fn(states.w_local)
        states = states._replace(w_global=jnp.broadcast_to(w_glob, states.w_global.shape))
    elif cfg.name == "scaffold2":
        c_glob = mean_fn(states.c_local)
        states = states._replace(c_global=jnp.broadcast_to(c_glob, states.c_global.shape))

    # ---- round stats (masked means over live clients under faults) ----
    if faults is None:
        agg = mean_fn
        drop_rate, quarantine_rate = zero, zero
    elif faults.tolerate:
        denom = jnp.maximum(n_live, 1.0)
        agg = lambda v: sum_fn(jnp.where(live, v, 0.0)) / denom
        drop_rate = 1.0 - n_live / cfg.n_clients
        quarantine_rate = n_quar / cfg.n_clients
    else:
        agg = mean_fn
        drop_rate = sum_fn(draws.drop.astype(jnp.float32)) / cfg.n_clients
        quarantine_rate = zero

    stats = RoundStats(
        server_x=new_server_x,
        mean_cos=agg(sum_cos) / cfg.local_steps,
        mean_disparity=agg(sum_disp) / cfg.local_steps,
        queries_per_client=agg(states.queries.astype(jnp.float32)),
        refactor_rate=agg(
            states.factor.n_refactors.astype(jnp.float32)
            / jnp.maximum(states.factor.n_updates.astype(jnp.float32), 1.0)
        ),
        repair_rate=agg(states.factor.needs_repair.astype(jnp.float32)),
        drop_rate=drop_rate,
        quarantine_rate=quarantine_rate,
    )
    return states, stats


def make_quarantine_reset(cfg: AlgoConfig):
    """Build ``reset(states, server_x)``: re-initialize quarantined clients
    from the global iterate (chunk-boundary recovery, DESIGN.md Sec. 8).

    The fresh-client template (empty trajectory, its Gram factorization, the
    shared FD bank) is computed EAGERLY here -- it does not depend on the
    traced ``server_x`` -- so the compiled reset contains no cholesky/eigh at
    all (contract-checked).  A quarantined client keeps its identity, RNG
    stream, cumulative query count and the replicated ``w_global``;
    everything else (iterate, trajectory, factor, local weights, optimizer)
    restarts as a fresh client joining at ``server_x``.
    """
    template = init_client_state(cfg, jax.random.PRNGKey(0),
                                 jnp.zeros((cfg.dim,), jnp.float32))
    opt_init, _ = make_optimizer(cfg.optimizer)

    def reset(states: ClientState, server_x: jax.Array) -> ClientState:
        flag = states.quarantined
        fresh = template._replace(x=server_x, opt=opt_init(server_x))

        def sel(old, new):
            f = flag.reshape(flag.shape + (1,) * (old.ndim - 1))
            return jnp.where(f, jnp.broadcast_to(new, old.shape), old)

        merged = jax.tree_util.tree_map(sel, states, fresh)
        return merged._replace(
            key=states.key, client_id=states.client_id, queries=states.queries,
            w_global=states.w_global,
            quarantined=jnp.zeros_like(states.quarantined),
        )

    return reset


# ---------------------------------------------------------------------------
# Single-process simulation driver
# ---------------------------------------------------------------------------


class SimResult(NamedTuple):
    """Per-round history of a run.

    ``f_values[r]`` is F(x_r); with ``eval_every=k > 1`` only every k-th
    round (plus round 0 and the final round) is evaluated and the skipped
    rows hold NaN -- the objective curve degrades gracefully instead of
    paying an expensive global eval every round.
    """

    xs: jax.Array  # (R+1, d) server iterates
    f_values: jax.Array  # (R+1,) F(x_r); NaN rows = skipped by eval_every
    queries: jax.Array  # (R,) cumulative mean queries per client
    mean_cos: jax.Array  # (R,)
    mean_disparity: jax.Array  # (R,)
    refactor_rate: jax.Array  # (R,) factor-cache clamped-eigh fallback rate
    repair_rate: jax.Array  # (R,) fraction of clients flagged needs_repair
    drop_rate: jax.Array  # (R,) fraction of clients not contributing (faults)
    quarantine_rate: jax.Array  # (R,) fraction of clients quarantined (faults)


def simulate(
    cfg: AlgoConfig,
    key: jax.Array,
    cobjs,
    query_fn: QueryFn,
    global_value_fn: Callable[[Any, jax.Array], jax.Array],
    rounds: int,
    x0: Optional[jax.Array] = None,
    diag_global_grad: Optional[Callable[[jax.Array], jax.Array]] = None,
    rff_key: Optional[jax.Array] = None,
    chunk: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    eval_every: int = 1,
    async_checkpoint: bool = True,
    faults=None,  # Optional[faults.FaultConfig]
    max_rollbacks: int = 3,
    cohort: Optional[int] = None,
    cohort_seed: int = 0,
) -> SimResult:
    """Run R communication rounds in a single process (clients via vmap).

    ``chunk`` selects the round driver: ``None`` (default) scans rounds in
    chunks of ``rounds.DEFAULT_CHUNK`` on device (core/rounds.py -- one
    dispatch per chunk, ``global_value_fn`` evaluated inside the scan);
    ``chunk=k>0`` sets the chunk length; ``chunk=0`` keeps the seed
    one-dispatch-per-round Python loop as the equivalence oracle.
    ``checkpoint_dir`` (scan driver only) enables chunk-boundary
    checkpoint/resume of the run; ``async_checkpoint`` overlaps the file
    write with the next chunk (core/rounds.py).  ``eval_every=k`` evaluates
    the (possibly expensive) ``global_value_fn`` only every k-th round plus
    the final one; skipped ``f_values`` rows hold NaN (see SimResult).

    ``cohort=K`` selects PARTIAL PARTICIPATION (core/pool.py): the N =
    ``cfg.n_clients`` states live in a host-resident pool and each chunk a
    deterministic cohort of K clients (keyed ``cohort_seed``) is gathered,
    run through the scan engine, and scattered back, with the aggregation
    renormalized by the live cohort count.  ``cohort=None`` (default) keeps
    the dense all-clients engine; K = N is bitwise the dense engine.
    """
    if chunk is not None and chunk < 0:
        raise ValueError(f"chunk must be None, 0 (loop oracle) or positive, got {chunk}")
    if eval_every < 1:
        raise ValueError(f"eval_every must be >= 1, got {eval_every}")
    if x0 is None:
        x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    k_init, k_rff, k_rounds = jax.random.split(key, 3)
    rff = None
    if cfg.is_fzoos:
        rff = rfflib.make_rff(rff_key if rff_key is not None else k_rff, cfg.n_features, cfg.dim, cfg.lengthscale)

    if cohort is not None:
        if chunk == 0:
            raise ValueError("cohort (partial participation) requires the "
                             "scan driver (chunk != 0); the dense engine at "
                             "cohort == n_clients is the equivalence oracle")
        from repro.core import pool as pool_mod  # deferred: avoids cycle
        from repro.core import rounds as rounds_mod

        pool = pool_mod.init_pool(cfg, k_init, x0)
        _, res = pool_mod.run_pooled_rounds(
            cfg, rff, query_fn, cobjs, pool, x0, global_value_fn,
            rounds, chunk if chunk is not None else rounds_mod.DEFAULT_CHUNK,
            cohort=cohort, cohort_seed=cohort_seed,
            diag_global_grad=diag_global_grad,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            eval_every=eval_every, async_checkpoint=async_checkpoint,
            faults=faults, max_rollbacks=max_rollbacks,
        )
        return res

    states = init_states(cfg, k_init, x0)

    if chunk is None or chunk > 0:
        from repro.core import rounds as rounds_mod  # deferred: avoids cycle

        if chunk is None:
            chunk = rounds_mod.DEFAULT_CHUNK
        _, res = rounds_mod.run_rounds(
            cfg, rff, query_fn, cobjs, states, x0, global_value_fn,
            rounds, chunk, diag_global_grad=diag_global_grad,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            eval_every=eval_every, async_checkpoint=async_checkpoint,
            faults=faults, max_rollbacks=max_rollbacks,
        )
        return res

    if checkpoint_dir:
        raise ValueError("checkpoint_dir requires the scan driver (chunk != 0)")
    if faults is not None:
        # Loop oracle matches the scan engine: a never-active window runs
        # the faults-free body (see rounds.run_rounds).
        from repro.faults.injector import effective_config
        faults = effective_config(faults, rounds)
    mean_fn = lambda tree: jax.tree_util.tree_map(lambda a: jnp.mean(a, axis=0), tree)

    if faults is None:
        round_jit = jax.jit(
            lambda states, sx: run_round(cfg, rff, query_fn, cobjs, states, sx, mean_fn, diag_global_grad)
        )
    else:
        sum_fn = lambda a: jnp.sum(a, axis=0)
        round_jit = jax.jit(
            lambda states, sx, r: run_round(
                cfg, rff, query_fn, cobjs, states, sx, mean_fn, diag_global_grad,
                sum_fn=sum_fn, faults=faults, round_idx=r,
            )
        )

    if cfg.deferred or faults is not None:
        from repro.core import rounds as rounds_mod  # deferred: avoids cycle

    xs = [x0]
    fvals = [global_value_fn(cobjs, x0)]
    queries, coss, disps, rrs, reps = [], [], [], [], []
    drops, quars = [], []
    sx = x0
    for r in range(rounds):
        if faults is None:
            states, stats = round_jit(states, sx)
        else:
            states, stats = round_jit(states, sx, jnp.asarray(r, jnp.int32))
        if cfg.deferred:
            # Loop oracle for the scan engine's chunk boundary: repair after
            # every round (the chunk=1 degenerate case of the deferred
            # contract -- flags never persist across rounds here).
            states, _ = rounds_mod.repair_flagged_clients(states, cfg)
        sx = stats.server_x
        if faults is not None and faults.tolerate:
            # Loop oracle for the boundary quarantine reset (host-read flag,
            # chunk=1 degenerate cadence -- see rounds.quarantine_reset_flagged)
            states, _ = rounds_mod.quarantine_reset_flagged(states, cfg, sx)
        xs.append(sx)
        r1 = r + 1
        if r1 % eval_every == 0 or r1 == rounds:
            fvals.append(global_value_fn(cobjs, sx))
        else:
            fvals.append(jnp.full((), jnp.nan, jnp.float32))
        queries.append(stats.queries_per_client)
        coss.append(stats.mean_cos)
        disps.append(stats.mean_disparity)
        rrs.append(stats.refactor_rate)
        reps.append(stats.repair_rate)
        drops.append(stats.drop_rate)
        quars.append(stats.quarantine_rate)

    return SimResult(
        xs=jnp.stack(xs),
        f_values=jnp.stack([jnp.asarray(f, jnp.float32) for f in fvals]),
        queries=jnp.stack(queries),
        mean_cos=jnp.stack(coss),
        mean_disparity=jnp.stack(disps),
        refactor_rate=jnp.stack(rrs),
        repair_rate=jnp.stack(reps),
        drop_rate=jnp.stack(drops),
        quarantine_rate=jnp.stack(quars),
    )


def optimal_gamma_star(
    grad_f_global: jax.Array, g_local: jax.Array, correction: jax.Array
) -> jax.Array:
    """Prop. 1 closed-form optimal correction length gamma*."""
    drift = grad_f_global - g_local
    denom = jnp.sum(correction * correction)
    return jnp.dot(drift, correction) / jnp.maximum(denom, 1e-30)


def disparity(ghat: jax.Array, grad_f_global: jax.Array) -> jax.Array:
    """Xi = ||ghat - grad F||^2 (Sec. 3.2)."""
    return jnp.sum((ghat - grad_f_global) ** 2)
