"""On-device multi-round scan engine (DESIGN.md Sec. 3).

PR 1 made one local step ~6x cheaper, which moved the bottleneck up a level:
the seed drivers (`algorithms.simulate`, `federated.run_distributed`) ran a
Python `for` loop that re-dispatched one jitted round per iteration and
synced to host every round to evaluate an un-jitted ``global_value_fn``.
Query-parsimonious federated ZOO wants MANY cheap rounds (FedZeN; the
Hessian-informed FedZOO line), so the round loop itself must stop paying
per-round dispatch + host-roundtrip tax.

This module scans ``run_round`` over K-round *chunks*:

  * one ``lax.scan`` per chunk -> one compile (per chunk length), one
    dispatch per chunk, zero host syncs mid-chunk;
  * ``global_value_fn`` is evaluated INSIDE the scanned body, so the
    F(x_r) curve is produced on device instead of round-tripping x_r;
  * per-round history (server iterates, F values, query counters,
    diagnostics) is written into preallocated on-device arrays with
    ``dynamic_update_slice`` at a traced round offset -- chunk length and
    history length are decoupled, so every full chunk reuses ONE executable;
  * the stacked ``ClientState`` and the history buffers are DONATED to the
    chunk executable, so the engine runs in place: no per-chunk copy of the
    (N, cap, d) trajectory/Gram buffers;
  * at chunk boundaries the engine can checkpoint {states, history} through
    ``checkpoint.io`` and resume from the latest checkpoint, so long
    federated runs survive preemption (the resume contract is
    round-granular: a checkpoint at round r restarts at round r).

Both front doors route here: ``algorithms.simulate`` (clients vmapped) and
``federated.run_distributed`` (clients sharded).  The distributed path scans
INSIDE ``shard_map`` so the per-round ``psum`` aggregation (plus one scalar
``pmean`` for the F curve) remains the only collective traffic; chunk
boundaries add no communication.

``chunk=0`` keeps the seed Python-loop driver in both front doors -- that
path is the equivalence oracle for the tests.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import io as ckpt_io
from repro.core import algorithms as alg
from repro.core import federated as fed
from repro.core import rff as rfflib

GlobalValueFn = Callable[[Any, jax.Array], jax.Array]

#: Auto chunk length used when a front door is called with ``chunk=None``.
#: Large enough to amortize dispatch, small enough that a preempted run
#: loses little work and the first result arrives quickly.
DEFAULT_CHUNK = 16


def history_init(rounds: int, x0: jax.Array, f0: jax.Array) -> alg.SimResult:
    """Preallocated on-device per-round history.  The buffers ARE the
    eventual SimResult (same NamedTuple), filled in place chunk by chunk."""
    return alg.SimResult(
        xs=jnp.zeros((rounds + 1, x0.shape[-1]), x0.dtype).at[0].set(x0),
        f_values=jnp.zeros((rounds + 1,), jnp.float32).at[0].set(
            jnp.asarray(f0, jnp.float32)
        ),
        queries=jnp.zeros((rounds,), jnp.float32),
        mean_cos=jnp.zeros((rounds,), jnp.float32),
        mean_disparity=jnp.zeros((rounds,), jnp.float32),
        refactor_rate=jnp.zeros((rounds,), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Chunk bodies
# ---------------------------------------------------------------------------


def _round_body(cfg, rff, query_fn, cobjs, mean_fn, eval_fn, diag_global_grad):
    """One scanned round: run_round + on-device F(x_{r+1}) evaluation."""

    def body(carry, _):
        states, sx = carry
        states, stats = alg.run_round(
            cfg, rff, query_fn, cobjs, states, sx, mean_fn, diag_global_grad
        )
        f = jnp.asarray(eval_fn(cobjs, stats.server_x), jnp.float32)
        ys = (
            stats.server_x,
            f,
            stats.queries_per_client,
            stats.mean_cos,
            stats.mean_disparity,
            stats.refactor_rate,
        )
        return (states, stats.server_x), ys

    return body


def sim_chunk_fn(
    cfg: alg.AlgoConfig,
    rff: Optional[rfflib.RFFParams],
    query_fn: alg.QueryFn,
    global_value_fn: GlobalValueFn,
    diag_global_grad,
    length: int,
):
    """K scanned rounds with clients vmapped (single-process simulation)."""
    mean_fn = lambda tree: jax.tree_util.tree_map(
        lambda a: jnp.mean(a, axis=0), tree
    )

    def chunk(states, cobjs, sx):
        body = _round_body(
            cfg, rff, query_fn, cobjs, mean_fn, global_value_fn, diag_global_grad
        )
        (states, sx), ys = jax.lax.scan(body, (states, sx), None, length=length)
        return states, sx, ys

    return chunk


def dist_chunk_fn(
    cfg: alg.AlgoConfig,
    mesh: Mesh,
    rff: Optional[rfflib.RFFParams],
    query_fn: alg.QueryFn,
    global_value_fn: GlobalValueFn,
    length: int,
):
    """K scanned rounds INSIDE shard_map: the per-round psum aggregation
    (plus one scalar pmean for F) stays the only collective."""
    axes, mean_fn = fed.client_mean_fn(cfg, mesh)
    cspec, rspec = P(axes), P()

    # Each shard sees an equal-size slice of the stacked cobjs, so the mean
    # of per-shard means IS the global mean F(x).
    def eval_fn(cobjs, x):
        return jax.lax.pmean(global_value_fn(cobjs, x), axes)

    def local_chunk(states, cobjs, sx):
        body = _round_body(cfg, rff, query_fn, cobjs, mean_fn, eval_fn, None)
        (states, sx), ys = jax.lax.scan(body, (states, sx), None, length=length)
        return states, sx, ys

    return shard_map(
        local_chunk,
        mesh=mesh,
        in_specs=(cspec, cspec, rspec),
        out_specs=(cspec, rspec, rspec),
        check_rep=False,
    )


def _hist_write(hist: alg.SimResult, ys, offset: jax.Array) -> alg.SimResult:
    """Write a chunk's stacked per-round outputs at round ``offset``."""
    xs_k, f_k, q_k, cos_k, disp_k, rr_k = ys
    dus = jax.lax.dynamic_update_slice
    return alg.SimResult(
        xs=dus(hist.xs, xs_k.astype(hist.xs.dtype), (offset + 1, 0)),
        f_values=dus(hist.f_values, f_k, (offset + 1,)),
        queries=dus(hist.queries, q_k, (offset,)),
        mean_cos=dus(hist.mean_cos, cos_k, (offset,)),
        mean_disparity=dus(hist.mean_disparity, disp_k, (offset,)),
        refactor_rate=dus(hist.refactor_rate, rr_k, (offset,)),
    )


def make_chunk_step(chunk_fn):
    """Jit one chunk step.  The client states and the history buffers are
    donated: the engine mutates them in place across the whole run."""

    @partial(jax.jit, donate_argnums=(0, 1))
    def step(states, hist, cobjs, sx, offset):
        states, sx, ys = chunk_fn(states, cobjs, sx)
        return states, _hist_write(hist, ys, offset), sx

    return step


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def run_rounds(
    cfg: alg.AlgoConfig,
    rff: Optional[rfflib.RFFParams],
    query_fn: alg.QueryFn,
    cobjs,
    states: alg.ClientState,
    x0: jax.Array,
    global_value_fn: GlobalValueFn,
    rounds: int,
    chunk: int,
    *,
    mesh: Optional[Mesh] = None,
    diag_global_grad=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    resume: bool = True,
) -> tuple[alg.ClientState, alg.SimResult]:
    """Run ``rounds`` communication rounds in chunks of ``chunk`` scanned
    iterations.  Returns (final stacked ClientState, SimResult history).

    With ``mesh=None`` clients run vmapped in-process; with a mesh they are
    sharded over the client axes and the scan runs inside shard_map.
    ``checkpoint_dir`` enables chunk-boundary checkpointing of
    {states, history} every ``checkpoint_every`` chunks (and at the end);
    when a checkpoint exists and ``resume`` is True the run restarts from
    the latest saved round.
    """
    if rounds < 0:
        raise ValueError(f"rounds must be >= 0, got {rounds}")
    if chunk < 1:
        raise ValueError("run_rounds requires chunk >= 1 (chunk=0 selects the "
                         "Python-loop oracle in the front doors)")
    if mesh is not None and diag_global_grad is not None:
        raise ValueError("diag_global_grad is only supported on the vmap path "
                         "(mesh=None); the distributed round body runs without "
                         "diagnostics, so passing one would silently return zeros")
    chunk = min(chunk, max(rounds, 1))
    x0 = jnp.asarray(x0)

    # Resume identity: {rounds, AlgoConfig repr} are recorded at save time
    # and must match at resume time, so a stale/reused checkpoint dir fails
    # loudly instead of splicing two different experiments into one history.
    # (The initial iterate and RNG key live in the restored state itself and
    # so cannot drift; x0 passed here is ignored on resume.)
    run_meta = {"rounds": rounds, "chunk": chunk, "cfg": repr(cfg)}
    start, hist = 0, None
    if checkpoint_dir and resume:
        latest = ckpt_io.latest_step(checkpoint_dir)
        if latest is not None:
            saved = (ckpt_io.load_meta(checkpoint_dir, latest).get("extra") or {})
            for field in ("rounds", "cfg"):
                if saved.get(field) not in (None, run_meta[field]):
                    raise ValueError(
                        f"checkpoint_dir {checkpoint_dir!r} holds a run with "
                        f"{field}={saved[field]!r}, cannot resume it with "
                        f"{field}={run_meta[field]!r}; point at a fresh directory"
                    )
            # Resume path: the checkpointed history already holds f(x_0),
            # so the (possibly expensive) initial eval is skipped.
            hist_like = history_init(rounds, x0, jnp.zeros((), jnp.float32))
            states, hist, start = ckpt_io.restore_round_state(
                checkpoint_dir, states, hist_like, step=latest
            )
            start = min(start, rounds)
            if mesh is not None:
                states = fed.shard_clients(mesh, states)
    if hist is None:
        hist = history_init(rounds, x0, global_value_fn(cobjs, x0))

    sx = hist.xs[start]
    steps: dict[int, Any] = {}

    def step_for(k: int):
        if k not in steps:
            if mesh is None:
                cf = sim_chunk_fn(cfg, rff, query_fn, global_value_fn,
                                  diag_global_grad, k)
            else:
                cf = dist_chunk_fn(cfg, mesh, rff, query_fn, global_value_fn, k)
            steps[k] = make_chunk_step(cf)
        return steps[k]

    done, chunks_done = start, 0
    while done < rounds:
        k = min(chunk, rounds - done)
        states, hist, sx = step_for(k)(
            states, hist, cobjs, sx, jnp.asarray(done, jnp.int32)
        )
        done += k
        chunks_done += 1
        if checkpoint_dir and (
            chunks_done % max(checkpoint_every, 1) == 0 or done == rounds
        ):
            ckpt_io.save_round_state(checkpoint_dir, done, states, hist,
                                     extra_meta=run_meta)

    return states, hist
