"""On-device multi-round scan engine (DESIGN.md Sec. 3).

PR 1 made one local step ~6x cheaper, which moved the bottleneck up a level:
the seed drivers (`algorithms.simulate`, `federated.run_distributed`) ran a
Python `for` loop that re-dispatched one jitted round per iteration and
synced to host every round to evaluate an un-jitted ``global_value_fn``.
Query-parsimonious federated ZOO wants MANY cheap rounds (FedZeN; the
Hessian-informed FedZOO line), so the round loop itself must stop paying
per-round dispatch + host-roundtrip tax.

This module scans ``run_round`` over K-round *chunks*:

  * one ``lax.scan`` per chunk -> one compile (per chunk length), one
    dispatch per chunk, zero host syncs mid-chunk;
  * ``global_value_fn`` is evaluated INSIDE the scanned body, so the
    F(x_r) curve is produced on device instead of round-tripping x_r;
  * per-round history (server iterates, F values, query counters,
    diagnostics) is written into preallocated on-device arrays with
    ``dynamic_update_slice`` at a traced round offset -- chunk length and
    history length are decoupled, so every full chunk reuses ONE executable;
  * the stacked ``ClientState`` and the history buffers are DONATED to the
    chunk executable, so the engine runs in place: no per-chunk copy of the
    (N, cap, d) trajectory/Gram buffers;
  * at chunk boundaries the engine can checkpoint {states, history} through
    ``checkpoint.io`` and resume from the latest checkpoint, so long
    federated runs survive preemption (the resume contract is
    round-granular: a checkpoint at round r restarts at round r).

Both front doors route here: ``algorithms.simulate`` (clients vmapped) and
``federated.run_distributed`` (clients sharded).  The distributed path scans
INSIDE ``shard_map`` so the per-round ``psum`` aggregation (plus one scalar
``pmean`` for the F curve) remains the only collective traffic; chunk
boundaries add no communication.

``chunk=0`` keeps the seed Python-loop driver in both front doors -- that
path is the equivalence oracle for the tests.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import io as ckpt_io
from repro.core import algorithms as alg
from repro.core import federated as fed
from repro.core import gp_surrogate as gp
from repro.core import rff as rfflib

GlobalValueFn = Callable[[Any, jax.Array], jax.Array]

#: Auto chunk length used when a front door is called with ``chunk=None``.
#: Large enough to amortize dispatch, small enough that a preempted run
#: loses little work and the first result arrives quickly.
DEFAULT_CHUNK = 16


def history_init(rounds: int, x0: jax.Array, f0: jax.Array) -> alg.SimResult:
    """Preallocated on-device per-round history.  The buffers ARE the
    eventual SimResult (same NamedTuple), filled in place chunk by chunk."""
    return alg.SimResult(
        xs=jnp.zeros((rounds + 1, x0.shape[-1]), x0.dtype).at[0].set(x0),
        f_values=jnp.zeros((rounds + 1,), jnp.float32).at[0].set(
            jnp.asarray(f0, jnp.float32)
        ),
        queries=jnp.zeros((rounds,), jnp.float32),
        mean_cos=jnp.zeros((rounds,), jnp.float32),
        mean_disparity=jnp.zeros((rounds,), jnp.float32),
        refactor_rate=jnp.zeros((rounds,), jnp.float32),
        repair_rate=jnp.zeros((rounds,), jnp.float32),
        drop_rate=jnp.zeros((rounds,), jnp.float32),
        quarantine_rate=jnp.zeros((rounds,), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Chunk bodies
# ---------------------------------------------------------------------------


def _round_body(cfg, rff, query_fn, cobjs, mean_fn, eval_fn, diag_global_grad,
                eval_every: int, rounds_total: Optional[int],
                sum_fn=None, faults=None):
    """One scanned round: run_round + on-device F(x_{r+1}) evaluation.

    The scanned xs is the in-chunk round index; the carry holds the traced
    absolute offset so ``eval_every`` gates the (possibly expensive) global
    eval on the ABSOLUTE completed-round count: rows for skipped rounds hold
    NaN, round ``rounds_total`` is always evaluated.  ``lax.cond`` is safe
    here -- the scan carry is unbatched, so the untaken eval is skipped for
    real (that is the whole point for LM-backbone objectives).

    With ``faults`` the fault-masked ``run_round`` path runs instead: the
    traced absolute round index ``offset + i`` keys the deterministic fault
    draws, and ``sum_fn`` supplies the un-normalized payload aggregation the
    mask renormalizes.  ``faults=None`` traces the seed body UNCHANGED (the
    bitwise faults-off guarantee).
    """

    def body(carry, i):
        states, sx, offset = carry
        if faults is None:
            states, stats = alg.run_round(
                cfg, rff, query_fn, cobjs, states, sx, mean_fn, diag_global_grad
            )
        else:
            states, stats = alg.run_round(
                cfg, rff, query_fn, cobjs, states, sx, mean_fn, diag_global_grad,
                sum_fn=sum_fn, faults=faults, round_idx=offset + i,
            )

        def do_eval():
            return jnp.asarray(eval_fn(cobjs, stats.server_x), jnp.float32)

        if eval_every == 1:
            f = do_eval()
        else:
            r1 = offset + i + 1  # 1-based absolute completed-round index
            want = r1 % eval_every == 0
            if rounds_total is not None:
                want = want | (r1 == rounds_total)
            f = jax.lax.cond(want, do_eval, lambda: jnp.full((), jnp.nan, jnp.float32))
        ys = (
            stats.server_x,
            f,
            stats.queries_per_client,
            stats.mean_cos,
            stats.mean_disparity,
            stats.refactor_rate,
            stats.repair_rate,
            stats.drop_rate,
            stats.quarantine_rate,
        )
        return (states, stats.server_x, offset), ys

    return body


def sim_chunk_fn(
    cfg: alg.AlgoConfig,
    rff: Optional[rfflib.RFFParams],
    query_fn: alg.QueryFn,
    global_value_fn: GlobalValueFn,
    diag_global_grad,
    length: int,
    eval_every: int = 1,
    rounds_total: Optional[int] = None,
    faults=None,
):
    """K scanned rounds with clients vmapped (single-process simulation)."""
    mean_fn = lambda tree: jax.tree_util.tree_map(
        lambda a: jnp.mean(a, axis=0), tree
    )
    sum_fn = (lambda a: jnp.sum(a, axis=0)) if faults is not None else None

    def chunk(states, cobjs, sx, offset):
        body = _round_body(
            cfg, rff, query_fn, cobjs, mean_fn, global_value_fn, diag_global_grad,
            eval_every, rounds_total, sum_fn=sum_fn, faults=faults,
        )
        (states, sx, _), ys = jax.lax.scan(
            body, (states, sx, offset), jnp.arange(length)
        )
        return states, sx, ys

    return chunk


def dist_chunk_fn(
    cfg: alg.AlgoConfig,
    mesh: Mesh,
    rff: Optional[rfflib.RFFParams],
    query_fn: alg.QueryFn,
    global_value_fn: GlobalValueFn,
    length: int,
    eval_every: int = 1,
    rounds_total: Optional[int] = None,
    faults=None,
):
    """K scanned rounds INSIDE shard_map: the per-round psum aggregation
    (plus one scalar pmean for F) stays the only collective.  The faulted
    body packs its live/quarantine counts INTO the psummed payload, so
    masking adds no collective either."""
    axes, mean_fn = fed.client_mean_fn(cfg, mesh)
    sum_fn = fed.client_sum_fn(mesh) if faults is not None else None
    cspec, rspec = P(axes), P()

    # Each shard sees an equal-size slice of the stacked cobjs, so the mean
    # of per-shard means IS the global mean F(x).  (The eval-every cond
    # predicate is a pure function of the replicated round offset, so every
    # device takes the same branch and the pmean inside stays matched.)
    def eval_fn(cobjs, x):
        return jax.lax.pmean(global_value_fn(cobjs, x), axes)

    def local_chunk(states, cobjs, sx, offset):
        body = _round_body(cfg, rff, query_fn, cobjs, mean_fn, eval_fn, None,
                           eval_every, rounds_total, sum_fn=sum_fn,
                           faults=faults)
        (states, sx, _), ys = jax.lax.scan(
            body, (states, sx, offset), jnp.arange(length)
        )
        return states, sx, ys

    return shard_map(
        local_chunk,
        mesh=mesh,
        in_specs=(cspec, cspec, rspec, rspec),
        out_specs=(cspec, rspec, rspec),
        check_rep=False,
    )


def _hist_write(hist: alg.SimResult, ys, offset: jax.Array) -> alg.SimResult:
    """Write a chunk's stacked per-round outputs at round ``offset``."""
    xs_k, f_k, q_k, cos_k, disp_k, rr_k, rep_k, dr_k, qr_k = ys
    dus = jax.lax.dynamic_update_slice
    return alg.SimResult(
        xs=dus(hist.xs, xs_k.astype(hist.xs.dtype), (offset + 1, 0)),
        f_values=dus(hist.f_values, f_k, (offset + 1,)),
        queries=dus(hist.queries, q_k, (offset,)),
        mean_cos=dus(hist.mean_cos, cos_k, (offset,)),
        mean_disparity=dus(hist.mean_disparity, disp_k, (offset,)),
        refactor_rate=dus(hist.refactor_rate, rr_k, (offset,)),
        repair_rate=dus(hist.repair_rate, rep_k, (offset,)),
        drop_rate=dus(hist.drop_rate, dr_k, (offset,)),
        quarantine_rate=dus(hist.quarantine_rate, qr_k, (offset,)),
    )


def make_chunk_step(chunk_fn):
    """Jit one chunk step.  The client states and the history buffers are
    donated: the engine mutates them in place across the whole run."""

    @partial(jax.jit, donate_argnums=(0, 1))
    def step(states, hist, cobjs, sx, offset):
        states, sx, ys = chunk_fn(states, cobjs, sx, offset)
        return states, _hist_write(hist, ys, offset), sx

    return step


# ---------------------------------------------------------------------------
# Deferred-repair pass (chunk boundaries; DESIGN.md Sec. 2.6 / 3)
# ---------------------------------------------------------------------------


#: jitted per-(mesh, capacity) shard_map repair executables (rare-event path).
_DIST_REPAIR_CACHE: dict = {}

#: jitted per-(mesh, capacity) DEVICE-decided boundary repair executables.
_DEVICE_REPAIR_CACHE: dict = {}


def boundary_repair_on_device(
    states: alg.ClientState,
    cfg: alg.AlgoConfig,
    mesh: Optional[Mesh] = None,
) -> alg.ClientState:
    """Zero-host-sync chunk boundary: the repair DECISION stays on device.

    One extra (async) dispatch per chunk running
    ``gp.factor_repair_gated`` -- a masked all-client repair under a
    ``lax.cond`` gated on the device-side flag-count scalar -- so the
    steady-state deferred boundary issues NO ``device_get`` of the flag
    vector and the Python driver never stalls the dispatch pipeline.  The
    common all-flags-clear case costs an O(N) reduction; when clients ARE
    flagged the taken branch is the same batched clamped-eigh
    ``factor_repair_masked`` the host-read path runs, so repaired state is
    identical to ``repair_flagged_clients`` (tested).  On a mesh the gate
    runs per shard inside ``shard_map`` (each shard conds on its LOCAL
    count; no collectives).  The factor buffers are donated: the boundary
    runs in place like the chunk step itself.
    """
    if not cfg.deferred:
        return states
    jitter = jnp.maximum(jnp.asarray(cfg.noise, jnp.float32), 1e-4)
    key = (mesh, states.factor.gram.shape)
    if key not in _DEVICE_REPAIR_CACHE:
        if mesh is None:
            fn = jax.jit(gp.factor_repair_gated, donate_argnums=0)
        else:
            axes = fed.client_axes(mesh)
            cspec = P(axes)
            fn = jax.jit(
                shard_map(
                    gp.factor_repair_gated,
                    mesh=mesh,
                    in_specs=(cspec, P()),
                    out_specs=cspec,
                    check_rep=False,
                ),
                donate_argnums=0,
            )
        _DEVICE_REPAIR_CACHE[key] = fn
    return states._replace(factor=_DEVICE_REPAIR_CACHE[key](states.factor, jitter))


def repair_flagged_clients(
    states: alg.ClientState,
    cfg: alg.AlgoConfig,
    mesh: Optional[Mesh] = None,
) -> tuple[alg.ClientState, int]:
    """Repair every client flagged ``needs_repair`` by the deferred engine.

    HOST-read decision path: reads the (N,)-bool flag vector to host and
    returns unchanged states when nothing is flagged (the overwhelmingly
    common case: the flag fires only on genuine f32 indefiniteness, measured
    rate ~0).  Since the zero-sync boundary landed this is the ORACLE used by
    the ``chunk=0`` loop drivers and the tests; the scan driver's steady
    state uses ``boundary_repair_on_device`` instead, which makes the same
    decision on device and therefore costs no sync.  When clients ARE
    flagged:

      * vmap path (``mesh=None``): gather the flagged subset and run ONE
        batched clamped-eigh over exactly those Grams -- the eigh amortizes
        from per-step-per-client to per-chunk-per-flagged-client;
      * distributed path: a jitted ``shard_map`` masked repair over the
        local clients of each shard (flag counts are not static under jit,
        so every local client's Gram enters the batched eigh and only
        flagged ones adopt).  No collectives: the per-round psum stays the
        only communication.

    Returns (states, number of clients repaired).
    """
    if not cfg.deferred:
        return states, 0
    flags = np.asarray(jax.device_get(states.factor.needs_repair))
    n_flagged = int(flags.sum())
    if n_flagged == 0:
        return states, 0
    jitter = jnp.maximum(jnp.asarray(cfg.noise, jnp.float32), 1e-4)

    if mesh is None:
        # Gather the flagged subset, repair it (ONE batched eigh over exactly
        # those Grams -- the same masked primitive the shard path uses, so
        # the repair semantics live in one place), scatter it back.
        idx = jnp.asarray(np.nonzero(flags)[0])
        sub = jax.tree_util.tree_map(lambda a: a[idx], states.factor)
        rep = gp.factor_repair_masked(sub, jitter)
        factor = jax.tree_util.tree_map(
            lambda full, r: full.at[idx].set(r), states.factor, rep
        )
        return states._replace(factor=factor), n_flagged

    key = (mesh, states.factor.gram.shape)
    if key not in _DIST_REPAIR_CACHE:
        axes = fed.client_axes(mesh)
        cspec = P(axes)
        _DIST_REPAIR_CACHE[key] = jax.jit(
            shard_map(
                lambda fac, jit_: gp.factor_repair_masked(fac, jit_),
                mesh=mesh,
                in_specs=(cspec, P()),
                out_specs=cspec,
                check_rep=False,
            )
        )
    factor = _DIST_REPAIR_CACHE[key](states.factor, jitter)
    return states._replace(factor=factor), n_flagged


# ---------------------------------------------------------------------------
# Quarantine reset (fault-tolerant chunk boundaries; DESIGN.md Sec. 8)
# ---------------------------------------------------------------------------


#: jitted per-(mesh, cfg, shape) DEVICE-decided quarantine-reset executables.
_QUARANTINE_RESET_CACHE: dict = {}


def _quarantine_reset_exec(cfg: alg.AlgoConfig, mesh: Optional[Mesh], shape):
    key = (mesh, repr(cfg), shape)
    if key not in _QUARANTINE_RESET_CACHE:
        reset = alg.make_quarantine_reset(cfg)

        def gated(sts, sx):
            n = jnp.sum(sts.quarantined.astype(jnp.int32))
            return jax.lax.cond(n > 0, lambda: reset(sts, sx), lambda: sts)

        if mesh is None:
            fn = jax.jit(gated, donate_argnums=0)
        else:
            axes = fed.client_axes(mesh)
            cspec = P(axes)
            fn = jax.jit(
                shard_map(
                    gated,
                    mesh=mesh,
                    in_specs=(cspec, P()),
                    out_specs=cspec,
                    check_rep=False,
                ),
                donate_argnums=0,
            )
        _QUARANTINE_RESET_CACHE[key] = fn
    return _QUARANTINE_RESET_CACHE[key]


def boundary_quarantine_reset(
    states: alg.ClientState,
    cfg: alg.AlgoConfig,
    server_x: jax.Array,
    mesh: Optional[Mesh] = None,
) -> alg.ClientState:
    """Zero-host-sync chunk boundary: re-admit quarantined clients ON DEVICE.

    The fault-tolerant sibling of ``boundary_repair_on_device``: one extra
    (async) dispatch per chunk that ``lax.cond``s on the device-side
    quarantine count and, when any client is quarantined, rebuilds those
    clients from the current global iterate (``alg.make_quarantine_reset``;
    the reset template is computed eagerly at build time so no init-time
    linear algebra enters the compiled gate).  The common all-clear case
    costs an O(N) reduction; no flag vector is read to host, no collectives
    are issued (each shard conds on its LOCAL count), and the stacked state
    is donated so the boundary runs in place.
    """
    fn = _quarantine_reset_exec(cfg, mesh, states.x.shape)
    return fn(states, jnp.asarray(server_x))


def quarantine_reset_flagged(
    states: alg.ClientState,
    cfg: alg.AlgoConfig,
    server_x: jax.Array,
    mesh: Optional[Mesh] = None,
) -> tuple[alg.ClientState, int]:
    """Host-read quarantine reset: the ``chunk=0`` loop-driver ORACLE.

    Reads the (N,)-bool quarantine flags to host and returns unchanged
    states when nothing is flagged, exactly like ``repair_flagged_clients``;
    when clients ARE quarantined it runs the same gated executable as
    ``boundary_quarantine_reset``, so the reset semantics live in one place
    and the oracle/steady-state equivalence is tested.  Returns
    (states, number of clients re-admitted).
    """
    flags = np.asarray(jax.device_get(states.quarantined))
    n_flagged = int(flags.sum())
    if n_flagged == 0:
        return states, 0
    return boundary_quarantine_reset(states, cfg, server_x, mesh=mesh), n_flagged


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def _restore_newest_good(
    checkpoint_dir: str,
    run_meta: dict,
    rounds: int,
    x0: jax.Array,
    states_like: alg.ClientState,
    mesh: Optional[Mesh],
):
    """Restore from the newest COMPLETE, uncorrupted checkpoint step.

    Steps whose meta is unreadable or whose arrays fail the integrity checks
    (truncated zip, checksum mismatch -- ``ckpt_io.CorruptCheckpointError``)
    are skipped with a warning and the next-older step is tried, so a torn
    or bit-flipped newest step degrades to losing one checkpoint interval
    instead of the whole run.  A step from a DIFFERENT run identity still
    raises: silently splicing two experiments is worse than failing.

    Returns ``(states, hist, start)``; ``hist is None`` means nothing
    restorable exists under ``checkpoint_dir``.
    """
    for step in sorted(ckpt_io.list_steps(checkpoint_dir), reverse=True):
        try:
            saved = (ckpt_io.load_meta(checkpoint_dir, step).get("extra") or {})
        except (OSError, ValueError) as e:
            print(f"[repro.rounds] checkpoint step {step}: unreadable meta "
                  f"({e}); trying an older step")
            continue
        for field in ("rounds", "cfg", "eval_every", "faults"):
            if saved.get(field) not in (None, run_meta[field]):
                raise ValueError(
                    f"checkpoint_dir {checkpoint_dir!r} holds a run with "
                    f"{field}={saved[field]!r}, cannot resume it with "
                    f"{field}={run_meta[field]!r}; point at a fresh directory"
                )
        hist_like = history_init(rounds, x0, jnp.zeros((), jnp.float32))
        try:
            states, hist, start = ckpt_io.restore_round_state(
                checkpoint_dir, states_like, hist_like, step=step, mesh=mesh
            )
        except (ckpt_io.CorruptCheckpointError, OSError) as e:
            print(f"[repro.rounds] checkpoint step {step}: corrupt "
                  f"({e}); trying an older step")
            continue
        return states, hist, min(start, rounds)
    return states_like, None, 0


def run_rounds(
    cfg: alg.AlgoConfig,
    rff: Optional[rfflib.RFFParams],
    query_fn: alg.QueryFn,
    cobjs,
    states: alg.ClientState,
    x0: jax.Array,
    global_value_fn: GlobalValueFn,
    rounds: int,
    chunk: int,
    *,
    mesh: Optional[Mesh] = None,
    diag_global_grad=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    resume: bool = True,
    eval_every: int = 1,
    async_checkpoint: bool = True,
    faults=None,  # Optional[faults.FaultConfig]
    max_rollbacks: int = 3,
) -> tuple[alg.ClientState, alg.SimResult]:
    """Run ``rounds`` communication rounds in chunks of ``chunk`` scanned
    iterations.  Returns (final stacked ClientState, SimResult history).

    With ``mesh=None`` clients run vmapped in-process; with a mesh they are
    sharded over the client axes and the scan runs inside shard_map.
    ``checkpoint_dir`` enables chunk-boundary checkpointing of
    {states, history} every ``checkpoint_every`` chunks (and at the end);
    when a checkpoint exists and ``resume`` is True the run restarts from
    the latest saved round.  On a mesh, checkpoints use the per-shard layout
    (one file per process from process-local data, no full ClientState
    gather; legacy single-file checkpoints still restore).  ``eval_every=k``
    evaluates ``global_value_fn`` inside the scan only every k-th round
    (plus the final one); skipped ``f_values`` rows hold NaN.

    The steady-state chunk boundary is HOST-SYNC-FREE: with ``cfg.deferred``
    the repair decision runs on device (``boundary_repair_on_device``, one
    extra async dispatch per chunk), and checkpoint writes are split into a
    synchronous host snapshot (required before the buffers are donated to
    the next chunk) plus a background file write overlapped with the next
    chunk's compute (``async_checkpoint=False`` forces the legacy blocking
    write).  Between boundaries the Python loop therefore runs ahead of the
    device, queueing chunk k+1 while chunk k executes.

    ``faults`` (a ``repro.faults.FaultConfig``) turns on the fault-tolerant
    engine (DESIGN.md Sec. 8): the scanned body masks dropped/poisoned
    clients out of the aggregation on device, quarantined clients are
    re-admitted from the global iterate at chunk boundaries by a
    device-decided gate, and the boundary gains ONE documented host sync --
    a finiteness check of the (d,)-vector server iterate -- that triggers
    chunk ROLLBACK: restore the newest good checkpoint (corrupt steps fall
    back to older ones) and re-run the lost rounds with tolerance forced
    on, at most ``max_rollbacks`` times.  A failed checkpoint write rolls
    back the same way.  ``faults=None`` leaves every code path above
    byte-identical to the faults-free engine.
    """
    if rounds < 0:
        raise ValueError(f"rounds must be >= 0, got {rounds}")
    if chunk < 1:
        raise ValueError("run_rounds requires chunk >= 1 (chunk=0 selects the "
                         "Python-loop oracle in the front doors)")
    if faults is not None:
        # A config whose window can never fire inside [0, rounds) must not
        # select the faulted engine (different compile key, extra psum
        # columns, insurance checkpoint, per-boundary finiteness sync): the
        # bitwise faults-off guarantee covers never-active windows too.
        from repro.faults.injector import effective_config  # deferred import
        faults = effective_config(faults, rounds)
    if eval_every < 1:
        raise ValueError(f"eval_every must be >= 1, got {eval_every}")
    if mesh is not None and diag_global_grad is not None:
        raise ValueError("diag_global_grad is only supported on the vmap path "
                         "(mesh=None); the distributed round body runs without "
                         "diagnostics, so passing one would silently return zeros")
    chunk = min(chunk, max(rounds, 1))
    x0 = jnp.asarray(x0)

    # Resume identity: {rounds, AlgoConfig repr, eval_every} are recorded at
    # save time and must match at resume time, so a stale/reused checkpoint
    # dir fails loudly instead of splicing two different experiments -- or
    # two different f_values NaN patterns -- into one history.  ``chunk`` is
    # recorded but deliberately NOT validated: it only sets dispatch
    # granularity and boundary-repair cadence, both inside the
    # bounded-divergence equivalence contract, so resuming with a different
    # chunk length (e.g. shorter chunks on a slower machine) is legitimate.
    # (The initial iterate and RNG key live in the restored state itself and
    # so cannot drift; x0 passed here is ignored on resume.)
    run_meta = {"rounds": rounds, "chunk": chunk, "cfg": repr(cfg),
                "eval_every": eval_every, "faults": repr(faults)}
    start, hist = 0, None
    if checkpoint_dir and resume and ckpt_io.latest_step(checkpoint_dir) is not None:
        # Resume path: the checkpointed history already holds f(x_0), so the
        # (possibly expensive) initial eval is skipped.  Corrupt newest steps
        # fall back to older ones (the restore half of the fault model).
        r_states, r_hist, start = _restore_newest_good(
            checkpoint_dir, run_meta, rounds, x0, states, mesh
        )
        if r_hist is not None:
            states, hist = r_states, r_hist
            if mesh is not None:
                # No-op re-placement for shard-restored state; places legacy
                # single-file restores (host arrays) onto the mesh.
                states = fed.shard_clients(mesh, states)
    if hist is None:
        hist = history_init(rounds, x0, global_value_fn(cobjs, x0))

    sx = hist.xs[start]
    fcfg = faults
    steps: dict[tuple, Any] = {}

    def step_for(k: int):
        # Keyed on (length, fault config): a rollback flips ``tolerate`` and
        # must get a fresh executable, not the non-tolerant one.
        skey = (k, fcfg)
        if skey not in steps:
            if mesh is None:
                cf = sim_chunk_fn(cfg, rff, query_fn, global_value_fn,
                                  diag_global_grad, k, eval_every, rounds,
                                  faults=fcfg)
            else:
                cf = dist_chunk_fn(cfg, mesh, rff, query_fn, global_value_fn,
                                   k, eval_every, rounds, faults=fcfg)
            steps[skey] = make_chunk_step(cf)
        return steps[skey]

    # Multi-process pods force the blocking write: the sharded layout's
    # cross-process barrier (io._sync) is a collective, and issuing it from
    # the writer thread while the main thread dispatches the next chunk's
    # psum could interleave collectives in inconsistent cross-process order.
    # io._sync enforces the same invariant defensively (RuntimeError off the
    # main thread on a multi-process mesh).
    if checkpoint_dir and async_checkpoint and jax.process_count() > 1:
        if jax.process_index() == 0:
            print(
                "[repro.rounds] async_checkpoint requested on a "
                f"{jax.process_count()}-process mesh: FORCING blocking "
                "per-shard writes (async writer would issue the _sync "
                "collective off the main thread and deadlock the pod)."
            )
        async_checkpoint = False
    writer = (
        ckpt_io.AsyncCheckpointWriter()
        if (checkpoint_dir and async_checkpoint and jax.process_count() == 1)
        else None
    )
    if fcfg is not None and checkpoint_dir and ckpt_io.latest_step(checkpoint_dir) is None:
        # Rollback insurance: guarantee a restore target exists BEFORE the
        # first faulted chunk runs (one blocking write per fresh directory).
        payload = ckpt_io.prepare_round_state(states, hist, mesh=mesh)
        ckpt_io.write_round_state(checkpoint_dir, start, payload,
                                  extra_meta=run_meta)
    done, chunks_done, rollbacks = start, 0, 0
    try:
        while done < rounds:
            k = min(chunk, rounds - done)
            states, hist, sx = step_for(k)(
                states, hist, cobjs, sx, jnp.asarray(done, jnp.int32)
            )
            done += k
            chunks_done += 1
            # Deferred-repair pass BETWEEN scan dispatches, decided ON
            # DEVICE: no flag read, no host sync -- the loop keeps running
            # ahead of the device (DESIGN.md Sec. 3).
            states = boundary_repair_on_device(states, cfg, mesh=mesh)
            if fcfg is not None and fcfg.tolerate:
                # Re-admit quarantined clients from the global iterate;
                # decided on device like the repair gate above.
                states = boundary_quarantine_reset(states, cfg, sx, mesh=mesh)
            ok = True
            if fcfg is not None:
                # THE one documented host sync of the faulted boundary: a
                # finiteness check of the (d,) server iterate, gating the
                # checkpoint write so a poisoned state is never persisted.
                ok = bool(np.isfinite(np.asarray(jax.device_get(sx))).all())
            wrote_ok = True
            if ok and checkpoint_dir and (
                chunks_done % max(checkpoint_every, 1) == 0 or done == rounds
            ):
                # Snapshot to host BEFORE the next chunk donates these
                # buffers; the file write itself overlaps the next chunk's
                # compute on the writer thread.
                payload = ckpt_io.prepare_round_state(states, hist, mesh=mesh)
                try:
                    if writer is not None:
                        # A submit surfaces the PREVIOUS boundary's write
                        # error; rolling back to the last good step handles
                        # both boundaries identically.
                        writer.submit(partial(
                            ckpt_io.write_round_state, checkpoint_dir, done,
                            payload, run_meta,
                        ))
                        if done >= rounds:
                            # FINAL boundary: there is no next submit to
                            # surface this write's error, and raising it from
                            # the post-loop drain would escape the rollback
                            # machinery entirely.  Drain NOW so a failed last
                            # write rolls back like any other boundary.
                            writer.wait()
                    else:
                        ckpt_io.write_round_state(checkpoint_dir, done, payload,
                                                  extra_meta=run_meta)
                except OSError as e:
                    if fcfg is None:
                        raise
                    print(f"[repro.rounds] checkpoint write failed at round "
                          f"{done}: {e}")
                    wrote_ok = False
            if fcfg is not None and (not ok or not wrote_ok):
                reason = ("non-finite server iterate" if not ok
                          else "checkpoint write failure")
                if not checkpoint_dir:
                    raise FloatingPointError(
                        f"{reason} at round {done} with no checkpoint_dir to "
                        "roll back to (chunk rollback needs checkpointing)"
                    )
                if rollbacks >= max_rollbacks:
                    raise FloatingPointError(
                        f"{reason} at round {done}: rollback budget "
                        f"max_rollbacks={max_rollbacks} exhausted"
                    )
                rollbacks += 1
                if writer is not None:
                    try:
                        writer.wait()
                    except OSError:
                        pass  # the failed write IS the fault being rolled back
                print(f"[repro.rounds] ROLLBACK {rollbacks}/{max_rollbacks} at "
                      f"round {done} ({reason}): restoring last good checkpoint")
                r_states, r_hist, r_start = _restore_newest_good(
                    checkpoint_dir, run_meta, rounds, x0, states, mesh
                )
                if r_hist is None:
                    raise FloatingPointError(
                        f"rollback at round {done} failed: no restorable "
                        f"checkpoint under {checkpoint_dir!r}"
                    )
                states, hist, done = r_states, r_hist, r_start
                if mesh is not None:
                    states = fed.shard_clients(mesh, states)
                sx = hist.xs[done]
                if not fcfg.tolerate:
                    print("[repro.rounds] re-running with fault tolerance "
                          "FORCED ON")
                    fcfg = dataclasses.replace(fcfg, tolerate=True)
                chunks_done = 0
    finally:
        if writer is not None:
            writer.wait()

    return states, hist
