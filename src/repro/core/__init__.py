"""The paper's contribution: FZooS -- federated zeroth-order optimization with
trajectory-informed surrogate gradients -- plus the baselines it compares to.
"""

from repro.core.algorithms import (  # noqa: F401
    ALGORITHMS,
    AlgoConfig,
    ClientState,
    RoundStats,
    SimResult,
    disparity,
    init_states,
    optimal_gamma_star,
    run_round,
    simulate,
)
from repro.core.gp_surrogate import (  # noqa: F401
    GPHyper,
    Trajectory,
    default_hyper,
    grad_mean,
    grad_mean_batch,
    grad_uncertainty_batch,
    grad_uncertainty_trace,
    select_active_queries,
    sqexp,
    traj_append,
    traj_append_batch,
    traj_init,
)
from repro.core.rounds import (  # noqa: F401
    DEFAULT_CHUNK,
    run_rounds,
)
from repro.core.rff import (  # noqa: F401
    RFFParams,
    approx_kernel,
    features,
    fit_w,
    grad_features_t_w,
    grad_features_t_w_batch,
    make_rff,
)
