"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1-5-0-5b \
        --variant smoke --batch-size 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import decode_step, init_train_state, prefill
from repro.sharding.rules import ShardingPolicy, mesh_context


def sample_token(logits, temperature: float, key) -> jax.Array:
    """Next token ids from (B, V) logits: greedy argmax at temperature 0,
    temperature-scaled categorical otherwise.  -> (B, 1) int32."""
    if temperature > 0:
        return jax.random.categorical(key, logits / temperature)[:, None].astype(jnp.int32)
    return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def generate(cfg, params, batch, policy, gen_len: int, cache_len: int, temperature: float, key):
    """Greedy/temperature sampling loop over decode_step.

    The PREFILL logits go through the same sampling rule as every decode
    step -- the first generated token used to be hard-wired to greedy
    argmax, so ``--temperature > 0`` runs all started with the same token.
    """
    logits, cache = jax.jit(lambda p, b: prefill(p, cfg, b, policy, cache_len=cache_len))(
        params, batch
    )
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, policy))
    toks = []
    key, sub = jax.random.split(key)
    tok = sample_token(logits, temperature, sub)
    for i in range(gen_len):
        toks.append(tok)
        logits, cache = step(params, cache, tok)
        key, sub = jax.random.split(key)
        tok = sample_token(logits, temperature, sub)
    return jnp.concatenate(toks, axis=1), cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b",
                    choices=[a.replace("_", "-") for a in ARCH_IDS] + list(ARCH_IDS))
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch.replace("-", "_"), args.variant)
    policy = ShardingPolicy(remat=False)
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(args.seed)
    params, _ = init_train_state(key, cfg)

    batch = {"tokens": jax.random.randint(key, (args.batch_size, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["patches"] = 0.02 * jax.random.normal(
            key, (args.batch_size, cfg.n_patches, cfg.d_model), jnp.float32
        )
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len)[None, :, None], (args.batch_size, args.prompt_len, 3)
        ).astype(jnp.int32)
    if cfg.arch_type == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (args.batch_size, cfg.enc_seq, cfg.d_model), jnp.float32
        )

    with mesh_context(mesh):
        t0 = time.time()
        out, cache = generate(
            cfg, params, batch, policy, args.gen_len,
            args.prompt_len + args.gen_len + 1, args.temperature, key,
        )
        dt = time.time() - t0
    n_tok = out.shape[0] * out.shape[1]
    print(f"generated {tuple(out.shape)} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
