"""Shared AlgoConfig plumbing for launchers and benchmarks.

The launchers and the benchmark modules each used to assemble
``AlgoConfig`` by hand, so every new algorithm knob (lengthscale, gamma
schedule, factor cache, deferred repair, ...) had to be wired in N places
and the flag sets drifted (ROADMAP item).  This module is the single
mapping from CLI flags / benchmark overrides to ``AlgoConfig``:

  * ``add_algo_flags(parser)``  -- install the algorithm flag set on an
    argparse parser (used by ``launch.fedzoo``);
  * ``config_from_args(args, dim=..., n_clients=...)`` -- build the config
    from parsed flags;
  * ``make_config(name, dim=..., n_clients=..., **overrides)`` -- the same
    builder for programmatic callers (benchmarks, tests), so benchmark
    configs go through exactly the code path the launcher exercises.

Engine-selection knobs that are NOT per-algorithm (``--chunk``,
``--ckpt-dir``, ``--eval-every``) ride along in ``add_engine_flags`` so the
benchmark harness and the launcher stay in sync there too.
"""

from __future__ import annotations

import argparse

from repro.core import algorithms as alg

#: argparse flag -> AlgoConfig field for the plain value flags.
_FLAG_FIELDS = {
    "algo": "name",
    "eta": "eta",
    "local_steps": "local_steps",
    "q": "q",
    "features": "n_features",
    "traj_cap": "traj_capacity",
    "lengthscale": "lengthscale",
    "gp_noise": "noise",
    "gamma_mode": "gamma_mode",
    "gamma_const": "gamma_const",
}


def add_algo_flags(ap: argparse.ArgumentParser) -> None:
    """Install the shared per-algorithm flag set (AlgoConfig surface)."""
    ap.add_argument("--algo", default="fzoos", choices=list(alg.ALGORITHMS))
    ap.add_argument("--local-steps", type=int, default=10, help="T")
    ap.add_argument("--eta", type=float, default=0.01)
    ap.add_argument("--q", type=int, default=20, help="FD directions per step")
    ap.add_argument("--features", type=int, default=1000, help="RFF features M")
    ap.add_argument("--traj-cap", type=int, default=192)
    ap.add_argument("--lengthscale", type=float, default=0.5,
                    help="GP/RFF kernel lengthscale (AlgoConfig.lengthscale)")
    ap.add_argument("--gp-noise", "--noise", dest="gp_noise", type=float, default=1e-5,
                    help="GP observation-noise variance (AlgoConfig.noise)")
    ap.add_argument("--gamma-mode", default="inv_t", choices=["inv_t", "const"],
                    help="correction-length schedule (Cor. C.1 practical choice)")
    ap.add_argument("--gamma-const", type=float, default=1.0,
                    help="gamma value when --gamma-mode const")
    ap.add_argument("--no-factor-cache", action="store_true",
                    help="seed eigh-from-scratch surrogate path (equivalence oracle)")
    ap.add_argument("--no-defer-repair", action="store_true",
                    help="inline clamped-eigh fallback per append event "
                         "(PR 2 engine, the deferred-repair equivalence oracle)")


def add_engine_flags(ap: argparse.ArgumentParser) -> None:
    """Round-driver knobs shared by the launcher and benchmark configs."""
    ap.add_argument("--chunk", type=int, default=None,
                    help="rounds per on-device scan chunk (core/rounds.py); "
                         "0 = legacy one-dispatch-per-round loop")
    ap.add_argument("--ckpt-dir", default="",
                    help="chunk-boundary checkpoint/resume dir (scan driver); "
                         "distributed runs write one shard file per process")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="checkpoint every k-th chunk boundary (plus the end)")
    ap.add_argument("--sync-ckpt", action="store_true",
                    help="write checkpoints synchronously at the boundary "
                         "(default: background write overlapped with the "
                         "next chunk's compute)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="evaluate global F only every k-th round (+ final); "
                         "skipped history rows hold NaN")
    add_pool_flags(ap)
    add_fault_flags(ap)


def add_pool_flags(ap: argparse.ArgumentParser) -> None:
    """Partial-participation knobs (core/pool.py client pool)."""
    ap.add_argument("--pool-size", type=int, default=None,
                    help="total client population N held in the host-resident "
                         "pool (overrides --clients; requires --cohort)")
    ap.add_argument("--cohort", type=int, default=None,
                    help="clients gathered onto the mesh per chunk (K <= N); "
                         "enables the partial-participation engine")
    ap.add_argument("--cohort-seed", type=int, default=0,
                    help="PRNG seed of the deterministic cohort sampler "
                         "(fold_in(seed, round) keying)")


def pool_from_args(args: argparse.Namespace) -> tuple[int | None, int | None]:
    """(n_clients override, cohort) from flags installed by
    ``add_pool_flags``, validated loudly."""
    if args.pool_size is not None:
        if args.cohort is None:
            raise SystemExit("--pool-size requires --cohort (K clients per "
                             "round out of the N pooled)")
        if args.pool_size < 1:
            raise SystemExit(f"--pool-size {args.pool_size} must be >= 1")
    if args.cohort is not None and args.cohort < 1:
        raise SystemExit(f"--cohort {args.cohort} must be >= 1")
    return args.pool_size, args.cohort


def add_fault_flags(ap: argparse.ArgumentParser) -> None:
    """Deterministic fault-injection knobs (repro.faults.FaultConfig)."""
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="PRNG seed of the deterministic fault schedule")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="per-(round, client) dropout probability")
    ap.add_argument("--straggle-rate", type=float, default=0.0,
                    help="per-(round, client) straggler (stale update) prob.")
    ap.add_argument("--nan-rate", type=float, default=0.0,
                    help="per-(round, client) NaN-payload probability")
    ap.add_argument("--inf-rate", type=float, default=0.0,
                    help="per-(round, client) Inf-payload probability")
    ap.add_argument("--fault-from", type=int, default=0,
                    help="first absolute round faults are active (default 0)")
    ap.add_argument("--fault-until", type=int, default=None,
                    help="faults stop at this round (half-open; default: never)")
    ap.add_argument("--no-fault-tolerance", action="store_true",
                    help="inject WITHOUT the masking/quarantine response "
                         "(demonstrates the poisoning failure mode; the "
                         "engine recovers via chunk rollback)")
    ap.add_argument("--fault-tolerance", action="store_true",
                    help="enable the fault-tolerant engine even with all "
                         "fault rates 0 (measures pure masking overhead)")
    ap.add_argument("--max-rollbacks", type=int, default=3,
                    help="chunk-rollback budget before the run fails loudly")


def faults_from_args(args: argparse.Namespace):
    """FaultConfig from flags installed by ``add_fault_flags``; ``None``
    (the bitwise faults-off engine) unless a rate is nonzero or
    ``--fault-tolerance`` explicitly opts in."""
    from repro.faults import FaultConfig

    fcfg = FaultConfig(
        seed=args.fault_seed,
        drop_rate=args.drop_rate,
        straggle_rate=args.straggle_rate,
        nan_rate=args.nan_rate,
        inf_rate=args.inf_rate,
        first_round=args.fault_from,
        last_round=args.fault_until,
        tolerate=not args.no_fault_tolerance,
    )
    if not fcfg.injects and not args.fault_tolerance:
        return None
    return fcfg


def config_from_args(args: argparse.Namespace, *, dim: int,
                     n_clients: int) -> alg.AlgoConfig:
    """Build AlgoConfig from flags installed by ``add_algo_flags``."""
    kw = {field: getattr(args, flag) for flag, field in _FLAG_FIELDS.items()}
    if getattr(args, "no_factor_cache", False):
        kw["use_factor_cache"] = False
    if getattr(args, "no_defer_repair", False):
        kw["defer_repair"] = False
    return make_config(kw.pop("name"), dim=dim, n_clients=n_clients, **kw)


def make_config(name: str, *, dim: int, n_clients: int,
                **overrides) -> alg.AlgoConfig:
    """Programmatic twin of ``config_from_args`` (benchmarks, tests).

    Unknown override keys raise immediately (AlgoConfig is frozen), so a
    benchmark config cannot silently drift from the AlgoConfig surface.
    """
    return alg.AlgoConfig(name=name, dim=dim, n_clients=n_clients, **overrides)
