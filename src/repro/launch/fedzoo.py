"""Paper-experiment launcher: run FZooS / baselines on any objective,
single-process (vmap) or distributed (shard_map over the device mesh).

    # paper Fig. 1 setting (synthetic quadratics, d=300, N=5)
    PYTHONPATH=src python -m repro.launch.fedzoo --objective quadratic \
        --algo fzoos --dim 300 --clients 5 --het 5.0 --rounds 50

    # federated black-box adversarial attack (Sec. 6.2)
    PYTHONPATH=src python -m repro.launch.fedzoo --objective attack --clients 10

    # non-differentiable metric optimization (Sec. 6.3)
    PYTHONPATH=src python -m repro.launch.fedzoo --objective metric --clients 7

    # FZooS over an architecture-zoo backbone (framework integration)
    PYTHONPATH=src python -m repro.launch.fedzoo --objective lm --arch mamba2-370m

    # distributed engine over the local device mesh
    PYTHONPATH=src python -m repro.launch.fedzoo --objective quadratic --distributed
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import algorithms as alg
from repro.core import model_objectives as mobj
from repro.core import objectives as obj
from repro.core.federated import run_distributed
from repro.launch import common
from repro.launch.mesh import make_host_mesh


def build_objective(args, key):
    if args.objective == "quadratic":
        cobjs = obj.make_quadratic(key, args.clients, args.dim, args.het, args.noise_std)
        return cobjs, obj.quadratic_query, obj.quadratic_global_value, args.dim
    if args.objective == "sinquad":
        cobjs = obj.make_sinquad(key, args.clients, args.dim, args.het, args.noise_std)
        return cobjs, obj.sinquad_query, obj.sinquad_global_value, args.dim
    if args.objective == "attack":
        cobjs, _ = mobj.make_attack_objective(key, args.clients, p_shared=args.p_shared)
        return cobjs, mobj.attack_query, mobj.attack_global_value, cobjs.z.shape[-1]
    if args.objective == "metric":
        cobjs, d = mobj.make_metric_objective(key, args.clients, p_shared=args.p_shared)
        return cobjs, mobj.metric_query, mobj.metric_global_value, d
    if args.objective == "lm":
        cfg = get_config(args.arch.replace("-", "_"), "smoke")
        from repro.models.model import init_train_state

        params, _ = init_train_state(key, cfg)
        cobjs = mobj.make_lm_objective(key, cfg, args.clients)
        query, global_value, d, _ = mobj.make_lm_query(cfg, params)
        return cobjs, query, global_value, d
    raise ValueError(args.objective)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--objective", default="quadratic",
                    choices=["quadratic", "sinquad", "attack", "metric", "lm"])
    ap.add_argument("--arch", default="qwen1_5_0_5b",
                    choices=[a.replace("_", "-") for a in ARCH_IDS] + list(ARCH_IDS))
    ap.add_argument("--dim", type=int, default=300)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--het", type=float, default=5.0, help="C for synthetic objectives")
    ap.add_argument("--p-shared", type=float, default=0.5, help="P for attack/metric")
    ap.add_argument("--noise-std", type=float, default=0.001)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="shard clients over the local device mesh via shard_map")
    common.add_algo_flags(ap)  # the shared AlgoConfig flag surface
    common.add_engine_flags(ap)  # --chunk / --ckpt-dir / --eval-every / pool
    args = ap.parse_args()

    pool_size, cohort = common.pool_from_args(args)
    if pool_size is not None:
        # The pool IS the population: objectives and AlgoConfig are built
        # for N clients; only the K-client cohort ever touches the mesh.
        args.clients = pool_size

    key = jax.random.PRNGKey(args.seed)
    kobj, krun = jax.random.split(key)
    cobjs, query, global_value, dim = build_objective(args, kobj)
    print(f"objective={args.objective} dim={dim} clients={args.clients} algo={args.algo}"
          + (f" cohort={cohort}" if cohort is not None else ""))

    cfg = common.config_from_args(args, dim=dim, n_clients=args.clients)
    print(f"queries/round/client = {cfg.queries_per_round()}  "
          f"uplink floats/round/client = {cfg.comm_floats_per_round()}")
    faults = common.faults_from_args(args)
    if faults is not None:
        print(f"faults: {faults}")

    t0 = time.time()
    ckpt = args.ckpt_dir or None
    if args.distributed:
        mesh = make_host_mesh()
        res = run_distributed(cfg, mesh, krun, cobjs, query, global_value,
                              args.rounds, chunk=args.chunk, checkpoint_dir=ckpt,
                              checkpoint_every=args.ckpt_every,
                              eval_every=args.eval_every,
                              async_checkpoint=not args.sync_ckpt,
                              faults=faults, max_rollbacks=args.max_rollbacks,
                              cohort=cohort, cohort_seed=args.cohort_seed)
    else:
        res = alg.simulate(cfg, krun, cobjs, query, global_value, args.rounds,
                           chunk=args.chunk, checkpoint_dir=ckpt,
                           checkpoint_every=args.ckpt_every,
                           eval_every=args.eval_every,
                           async_checkpoint=not args.sync_ckpt,
                           faults=faults, max_rollbacks=args.max_rollbacks,
                           cohort=cohort, cohort_seed=args.cohort_seed)
    dt = time.time() - t0

    if jax.process_index() != 0:
        return  # one progress table per job, not one per host

    f = res.f_values
    best = float(jnp.nanmin(f))  # eval-every leaves NaN rows for skipped rounds
    print(f"F(x_0) = {float(f[0]):+.5f}   F(x_R) = {float(f[-1]):+.5f}   "
          f"best = {best:+.5f}   ({dt:.1f}s, "
          f"{args.rounds / max(dt, 1e-9):.1f} rounds/s)")
    if faults is not None:
        print(f"mean drop_rate = {float(jnp.mean(res.drop_rate)):.3f}   "
              f"mean quarantine_rate = {float(jnp.mean(res.quarantine_rate)):.3f}")
    stride = max(args.rounds // 10, 1)
    shown = sorted(set(range(0, args.rounds + 1, stride)) | {args.rounds})
    for r in shown:
        q = int(res.queries[r - 1]) if r > 0 else 0
        print(f"  round {r:4d}  F = {float(f[r]):+.5f}  queries/client = {q}")


if __name__ == "__main__":
    main()
