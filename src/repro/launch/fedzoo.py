"""Paper-experiment launcher: run FZooS / baselines on any objective,
single-process (vmap) or distributed (shard_map over the device mesh).

    # paper Fig. 1 setting (synthetic quadratics, d=300, N=5)
    PYTHONPATH=src python -m repro.launch.fedzoo --objective quadratic \
        --algo fzoos --dim 300 --clients 5 --het 5.0 --rounds 50

    # federated black-box adversarial attack (Sec. 6.2)
    PYTHONPATH=src python -m repro.launch.fedzoo --objective attack --clients 10

    # non-differentiable metric optimization (Sec. 6.3)
    PYTHONPATH=src python -m repro.launch.fedzoo --objective metric --clients 7

    # FZooS over an architecture-zoo backbone (framework integration)
    PYTHONPATH=src python -m repro.launch.fedzoo --objective lm --arch mamba2-370m

    # distributed engine over the local device mesh
    PYTHONPATH=src python -m repro.launch.fedzoo --objective quadratic --distributed
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import algorithms as alg
from repro.core import model_objectives as mobj
from repro.core import objectives as obj
from repro.core.federated import run_distributed
from repro.launch.mesh import make_host_mesh


def build_objective(args, key):
    if args.objective == "quadratic":
        cobjs = obj.make_quadratic(key, args.clients, args.dim, args.het, args.noise_std)
        return cobjs, obj.quadratic_query, obj.quadratic_global_value, args.dim
    if args.objective == "sinquad":
        cobjs = obj.make_sinquad(key, args.clients, args.dim, args.het, args.noise_std)
        return cobjs, obj.sinquad_query, obj.sinquad_global_value, args.dim
    if args.objective == "attack":
        cobjs, _ = mobj.make_attack_objective(key, args.clients, p_shared=args.p_shared)
        return cobjs, mobj.attack_query, mobj.attack_global_value, cobjs.z.shape[-1]
    if args.objective == "metric":
        cobjs, d = mobj.make_metric_objective(key, args.clients, p_shared=args.p_shared)
        return cobjs, mobj.metric_query, mobj.metric_global_value, d
    if args.objective == "lm":
        cfg = get_config(args.arch.replace("-", "_"), "smoke")
        from repro.models.model import init_train_state

        params, _ = init_train_state(key, cfg)
        cobjs = mobj.make_lm_objective(key, cfg, args.clients)
        query, global_value, d, _ = mobj.make_lm_query(cfg, params)
        return cobjs, query, global_value, d
    raise ValueError(args.objective)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--objective", default="quadratic",
                    choices=["quadratic", "sinquad", "attack", "metric", "lm"])
    ap.add_argument("--algo", default="fzoos", choices=list(alg.ALGORITHMS))
    ap.add_argument("--arch", default="qwen1_5_0_5b",
                    choices=[a.replace("_", "-") for a in ARCH_IDS] + list(ARCH_IDS))
    ap.add_argument("--dim", type=int, default=300)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--het", type=float, default=5.0, help="C for synthetic objectives")
    ap.add_argument("--p-shared", type=float, default=0.5, help="P for attack/metric")
    ap.add_argument("--noise-std", type=float, default=0.001)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--eta", type=float, default=0.01)
    ap.add_argument("--q", type=int, default=20)
    ap.add_argument("--features", type=int, default=1000)
    ap.add_argument("--traj-cap", type=int, default=192)
    ap.add_argument("--lengthscale", type=float, default=0.5,
                    help="GP/RFF kernel lengthscale (AlgoConfig.lengthscale)")
    ap.add_argument("--gp-noise", "--noise", dest="gp_noise", type=float, default=1e-5,
                    help="GP observation-noise variance (AlgoConfig.noise)")
    ap.add_argument("--gamma-mode", default="inv_t", choices=["inv_t", "const"],
                    help="correction-length schedule (Cor. C.1 practical choice)")
    ap.add_argument("--gamma-const", type=float, default=1.0,
                    help="gamma value when --gamma-mode const")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="shard clients over the local device mesh via shard_map")
    ap.add_argument("--chunk", type=int, default=None,
                    help="rounds per on-device scan chunk (core/rounds.py); "
                         "0 = legacy one-dispatch-per-round loop")
    ap.add_argument("--ckpt-dir", default="",
                    help="chunk-boundary checkpoint/resume dir (scan driver)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(args.seed)
    kobj, krun = jax.random.split(key)
    cobjs, query, global_value, dim = build_objective(args, kobj)
    print(f"objective={args.objective} dim={dim} clients={args.clients} algo={args.algo}")

    cfg = alg.AlgoConfig(
        name=args.algo, dim=dim, n_clients=args.clients, eta=args.eta,
        local_steps=args.local_steps, q=args.q, n_features=args.features,
        traj_capacity=args.traj_cap, lengthscale=args.lengthscale,
        noise=args.gp_noise, gamma_mode=args.gamma_mode,
        gamma_const=args.gamma_const,
    )
    print(f"queries/round/client = {cfg.queries_per_round()}  "
          f"uplink floats/round/client = {cfg.comm_floats_per_round()}")

    t0 = time.time()
    ckpt = args.ckpt_dir or None
    if args.distributed:
        mesh = make_host_mesh()
        res = run_distributed(cfg, mesh, krun, cobjs, query, global_value,
                              args.rounds, chunk=args.chunk, checkpoint_dir=ckpt)
    else:
        res = alg.simulate(cfg, krun, cobjs, query, global_value, args.rounds,
                           chunk=args.chunk, checkpoint_dir=ckpt)
    dt = time.time() - t0

    f = res.f_values
    best = float(jnp.min(f))
    print(f"F(x_0) = {float(f[0]):+.5f}   F(x_R) = {float(f[-1]):+.5f}   "
          f"best = {best:+.5f}   ({dt:.1f}s, "
          f"{args.rounds / max(dt, 1e-9):.1f} rounds/s)")
    stride = max(args.rounds // 10, 1)
    shown = sorted(set(range(0, args.rounds + 1, stride)) | {args.rounds})
    for r in shown:
        q = int(res.queries[r - 1]) if r > 0 else 0
        print(f"  round {r:4d}  F = {float(f[r]):+.5f}  queries/client = {q}")


if __name__ == "__main__":
    main()
