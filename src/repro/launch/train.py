"""LM training driver (first-order substrate).

Runs on whatever devices exist (CPU smoke -> real mesh): builds the mesh,
places params per the sharding rules, streams the synthetic pipeline and
checkpoints periodically.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1-5-0-5b \
        --variant smoke --steps 50 --batch-size 8 --seq-len 128
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import latest_step, restore_train_state, save_train_state
from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticTextConfig, synthetic_batch
from repro.launch.mesh import make_host_mesh
from repro.models.model import train_step
from repro.models.model import init_train_state
from repro.optim import warmup_cosine_schedule
from repro.sharding.rules import ShardingPolicy, mesh_context


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b",
                    choices=[a.replace("_", "-") for a in ARCH_IDS] + list(ARCH_IDS))
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch.replace("-", "_"), args.variant)
    policy = ShardingPolicy(remat=args.variant == "full")
    mesh = make_host_mesh()
    sched = warmup_cosine_schedule(args.lr, args.warmup, args.steps)

    params, opt = init_train_state(jax.random.PRNGKey(args.seed), cfg)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        params, opt, start = restore_train_state(args.ckpt_dir, params, opt)
        print(f"restored step {start} from {args.ckpt_dir}")

    dcfg = SyntheticTextConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len, batch_size=args.batch_size,
        seed=args.seed,
    )
    step_fn = jax.jit(lambda p, o, b, lr: train_step(p, o, cfg, b, policy, lr))

    if start >= args.steps:
        # Restored checkpoint is already at (or past) the target step: the
        # loop body would never run, so there are no metrics to save and
        # nothing to do -- re-saving here used to hit an unbound `metrics`.
        print(f"nothing to do: restored step {start} >= --steps {args.steps}")
        return

    with mesh_context(mesh):
        t0 = time.time()
        metrics = None
        for step in range(start, args.steps):
            batch = synthetic_batch(dcfg, step, cfg)
            params, opt, metrics = step_fn(params, opt, batch, sched(step))
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dt = time.time() - t0
                print(f"step {step:5d}  loss {loss:.4f}  grad_norm {gn:.2f}  "
                      f"({dt:.1f}s elapsed)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_train_state(args.ckpt_dir, step + 1, params, opt,
                                 {"loss": float(metrics["loss"])})
        if args.ckpt_dir and metrics is not None:
            save_train_state(args.ckpt_dir, args.steps, params, opt,
                             {"loss": float(metrics["loss"])})
    print("done.")


if __name__ == "__main__":
    main()
