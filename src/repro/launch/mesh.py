"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init -- the dry-run must
set XLA_FLAGS before any jax call).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Target: TPU v5e, 256 chips/pod.

    single-pod: (data=16, model=16); multi-pod: (pod=2, data=16, model=16).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (CPU smoke / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


# v5e hardware constants for the roofline (see system spec).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
