"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init -- the dry-run must
set XLA_FLAGS before any jax call).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Target: TPU v5e, 256 chips/pod.

    single-pod: (data=16, model=16); multi-pod: (pod=2, data=16, model=16).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (CPU smoke / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


# v5e hardware constants for the roofline (see system spec).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_PER_CHIP = 16e9  # bytes

#: Per-backend roofline constants -- the SINGLE source for both
#: ``benchmarks/roofline.py`` (communication/FLOP envelopes) and
#: ``repro.kernels.autotune`` (block-size selection), so the numbers the
#: bench reports and the numbers the kernels tune against cannot drift.
#: ``vmem_bytes`` is the fast on-chip working-set budget the kernel tiles
#: must fit in (v5e VMEM; for CPU an L2-sized stand-in so interpret-mode
#: block choices stay moderate).  ``_default`` is the conservative entry
#: used for backends not listed here (see ``autotune.measure_blocks`` for
#: the measured-sweep escape hatch).
BACKEND_ROOFLINE = {
    "tpu": {
        "peak_flops": PEAK_FLOPS_BF16,
        "hbm_bw": HBM_BW,
        "hbm_bytes": HBM_PER_CHIP,
        "vmem_bytes": 16 * 2**20,
    },
    "cpu": {
        "peak_flops": 100e9,
        "hbm_bw": 20e9,
        "hbm_bytes": 16e9,
        "vmem_bytes": 16 * 2**20,
    },
    "_default": {
        "peak_flops": 100e9,
        "hbm_bw": 20e9,
        "hbm_bytes": 16e9,
        "vmem_bytes": 16 * 2**20,
    },
}
