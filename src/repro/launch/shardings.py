"""Input/output sharding builders for the dry-run and the real launchers.

Placement policy (DESIGN.md Sec. 6):
  * batch dims over ("pod","data") (pod axis only when present),
  * params per the logical axes declared in models/params.py,
  * optimizer moments additionally ZeRO-1-sharded over 'data',
  * decode KV caches: batch over data + sequence over 'model'; the
    batch=1 long_500k shape instead shards the cache SEQUENCE over
    (pod, data, model) so all 256/512 chips hold a slice.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.model import AttnCache, DecodeCache, SsmStack, init_cache
from repro.models.params import param_defs
from repro.optim.optimizers import AdamState, OptState
from repro.sharding.rules import ShardingPolicy, spec_with_fallback, zero1_extend


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def param_shardings(
    cfg: ModelConfig, mesh: Mesh, policy: ShardingPolicy | None = None
) -> dict[str, NamedSharding]:
    """Param placement.  With policy.fsdp the tensor-parallel spec from the
    logical axes is EXTENDED with a 'data' shard on the largest replicated
    divisible dim (ZeRO-3 / FSDP): a ~800B-param arch is otherwise 100 GB
    per device on a 16-wide model axis (measured, EXPERIMENTS.md §Perf it.1).
    GSPMD inserts the per-layer weight all-gathers this implies."""
    fsdp = policy.fsdp if policy is not None else True
    out = {}
    for n, pd in param_defs(cfg).items():
        spec = spec_with_fallback(mesh, pd.shape, pd.axes)
        if fsdp:
            spec = zero1_extend(mesh, pd.shape, spec, data_axes(mesh))
        out[n] = ns(mesh, spec)
    return out


def opt_shardings(cfg: ModelConfig, mesh: Mesh, policy: ShardingPolicy) -> OptState:
    """AdamW moments: follow the (FSDP-extended) param spec; with fsdp off,
    ZeRO-1 still extends the moments alone over 'data'."""
    moments = {}
    for n, pd in param_defs(cfg).items():
        spec = spec_with_fallback(mesh, pd.shape, pd.axes)
        if policy.fsdp or policy.zero1:
            spec = zero1_extend(mesh, pd.shape, spec, data_axes(mesh))
        moments[n] = ns(mesh, spec)
    scalar = ns(mesh, P())
    return OptState(inner=AdamState(mu=moments, nu=dict(moments), step=scalar))


def batch_shardings(cfg: ModelConfig, mesh: Mesh, shape_name: str) -> dict[str, NamedSharding]:
    """Shardings for the train/prefill batch dict."""
    from repro.models.model import INPUT_SHAPES, input_specs

    b_ax = data_axes(mesh)
    specs = input_specs(cfg, shape_name)
    out = {}
    for k, v in specs.items():
        if k in ("token", "cache"):
            continue
        out[k] = ns(mesh, spec_with_fallback(mesh, v.shape, (b_ax,) + (None,) * (len(v.shape) - 1)))
    return out


def cache_shardings(
    cfg: ModelConfig, mesh: Mesh, shape_name: str
) -> DecodeCache:
    """DecodeCache of NamedShardings for the decode shapes."""
    from repro.models.model import INPUT_SHAPES

    sh = INPUT_SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    b_ax = data_axes(mesh)
    n_dev_data = 1
    for a in b_ax:
        n_dev_data *= mesh.shape[a]

    if b % n_dev_data == 0:
        batch_ax: Any = b_ax
        seq_ax: Any = "model"
    else:
        # long_500k (batch=1): replicate batch, stripe the cache sequence
        # across EVERY mesh axis so each chip holds S / n_chips entries.
        batch_ax = None
        seq_ax = b_ax + ("model",)

    cache_struct = jax.eval_shape(lambda: init_cache(cfg, b, s))

    def attn_spec(arr, seq_dim_is_enc=False):
        if arr.ndim != 5:  # empty placeholder
            return ns(mesh, P())
        # (nb, B, S, KV, hd)
        s_ax = None if seq_dim_is_enc else seq_ax
        return ns(mesh, spec_with_fallback(mesh, arr.shape, (None, batch_ax, s_ax, None, None)))

    def ssm_state_spec(arr):
        if arr.ndim == 5:  # (nb, B, H, P, N)
            axes = (None, batch_ax, "model", None, None)
        elif arr.ndim == 6:  # hybrid (nb, n_ssm, B, H, P, N)
            axes = (None, None, batch_ax, "model", None, None)
        else:
            return ns(mesh, P())
        return ns(mesh, spec_with_fallback(mesh, arr.shape, axes))

    def ssm_conv_spec(arr):
        if arr.ndim == 4:  # (nb, B, K-1, C)
            axes = (None, batch_ax, None, "model")
        elif arr.ndim == 5:  # hybrid
            axes = (None, None, batch_ax, None, "model")
        else:
            return ns(mesh, P())
        return ns(mesh, spec_with_fallback(mesh, arr.shape, axes))

    return DecodeCache(
        attn=AttnCache(k=attn_spec(cache_struct.attn.k), v=attn_spec(cache_struct.attn.v)),
        ssm=SsmStack(
            conv=ssm_conv_spec(cache_struct.ssm.conv), state=ssm_state_spec(cache_struct.ssm.state)
        ),
        cross=AttnCache(
            k=attn_spec(cache_struct.cross.k, seq_dim_is_enc=True),
            v=attn_spec(cache_struct.cross.v, seq_dim_is_enc=True),
        ),
        pos=ns(mesh, P()),
    )


def token_sharding(cfg: ModelConfig, mesh: Mesh, shape_name: str) -> NamedSharding:
    from repro.models.model import INPUT_SHAPES

    b = INPUT_SHAPES[shape_name]["global_batch"]
    return ns(mesh, spec_with_fallback(mesh, (b, 1), (data_axes(mesh), None)))
