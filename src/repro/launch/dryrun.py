import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (architecture x input shape)
on the production mesh, and extract the roofline terms.

The two lines above MUST run before any other import (jax locks the device
count at first backend init); everything below assumes 512 host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Per combo it writes artifacts/dryrun/<arch>__<shape>__<mesh>.json with:
  flops / bytes from compiled.cost_analysis()  (per-device, post-SPMD),
  per-category collective output bytes parsed from the optimized HLO,
  memory_analysis (argument/output/temp/generated code bytes per device),
  and wall-clock lower/compile times.
benchmarks/roofline.py turns these into the three roofline terms.
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (
    batch_shardings,
    cache_shardings,
    opt_shardings,
    param_shardings,
    token_sharding,
)
from repro.models.model import INPUT_SHAPES, decode_step, input_specs, prefill, train_step
from repro.models.params import param_shapes
from repro.optim.optimizers import adamw_init
from repro.sharding.rules import ShardingPolicy, mesh_context

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(stext: str) -> int:
    """Sum byte sizes of every 'dtype[dims]' in a (possibly tuple) type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(stext):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-category totals of collective OUTPUT bytes (per device, since the
    module is post-SPMD) + op counts.  `*-start` async forms are counted via
    their start op; `*-done` is skipped to avoid double counting."""
    out = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+([\w-]+)", rhs)
        if not m:
            continue
        opcode = m.group(2)
        base = opcode.removesuffix("-start")
        if opcode.endswith("-done") or base not in _COLLECTIVES:
            continue
        out[base]["bytes"] += _shape_bytes(m.group(1))
        out[base]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    if ma is None:
        return {"error": "memory_analysis() returned None"}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
    )
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items() if isinstance(v, (int, float))}


def build_lowerable(cfg, mesh, shape_name: str, policy: ShardingPolicy):
    """Returns (jitted_fn, abstract_args)."""
    kind = INPUT_SHAPES[shape_name]["kind"]
    p_sh = param_shardings(cfg, mesh, policy)
    p_shapes = param_shapes(cfg)
    donate = (0, 1) if policy.donate else ()

    if kind == "train":
        o_sh = opt_shardings(cfg, mesh, policy)
        b_sh = batch_shardings(cfg, mesh, shape_name)
        o_shapes = jax.eval_shape(adamw_init, p_shapes)
        fn = lambda p, o, b: train_step(p, o, cfg, b, policy, lr=1e-4)
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None),
                      donate_argnums=donate)
        return jfn, (p_shapes, o_shapes, input_specs(cfg, shape_name))

    if kind == "prefill":
        b_sh = batch_shardings(cfg, mesh, shape_name)
        c_sh = cache_shardings(cfg, mesh, "decode_32k")
        fn = lambda p, b: prefill(p, cfg, b, policy)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh))
        return jfn, (p_shapes, input_specs(cfg, shape_name))

    # decode: the cache buffer is donated (in-place steady-state serving)
    specs = input_specs(cfg, shape_name)
    c_sh = cache_shardings(cfg, mesh, shape_name)
    t_sh = token_sharding(cfg, mesh, shape_name)
    fn = lambda p, c, t: decode_step(p, cfg, c, t, policy)
    jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh), out_shardings=(None, c_sh),
                  donate_argnums=(1,) if policy.donate else ())
    return jfn, (p_shapes, specs["cache"], specs["token"])


def applicable(cfg, shape_name: str) -> tuple[bool, str]:
    kind = INPUT_SHAPES[shape_name]["kind"]
    if kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch; long_500k needs sub-quadratic decode (DESIGN.md)"
    return True, ""


def depth_variant(cfg, k: int):
    """Same-family config with k scanned blocks (for cost extrapolation)."""
    import dataclasses

    if cfg.arch_type == "hybrid":
        return dataclasses.replace(cfg, n_layers=k * cfg.attn_every)
    if cfg.arch_type == "encdec":
        return dataclasses.replace(cfg, n_layers=k, n_enc_layers=k)
    return dataclasses.replace(cfg, n_layers=k)


def _measure(cfg, mesh, shape_name, policy, want_hlo: bool):
    with mesh_context(mesh):
        t0 = time.time()
        jfn, args = build_lowerable(cfg, mesh, shape_name, policy)
        lowered = jfn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        out = {
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": _mem_dict(compiled),
            "cost": _cost_dict(compiled),
        }
        if want_hlo:
            hlo = compiled.as_text()
            out["hlo_chars"] = len(hlo)
            out["collectives"] = parse_collectives(hlo)
            del hlo
        return out


_EXTRAP_KEYS = ("flops", "bytes accessed", "transcendentals", "optimal_seconds")


def _extrapolate(da: dict, db: dict, nb: int, ka: int = 1, kb: int = 2) -> dict:
    """Linear in block count: F(nb) = Fa + (nb-ka) * (Fb-Fa)/(kb-ka).

    Exact because every scanned block is shape-identical; the intercept
    carries the depth-independent embed/unembed/loss cost.
    """
    span = kb - ka
    out = {"cost": {}, "collectives": {}, "per_block": {}}
    for k in _EXTRAP_KEYS:
        if k in da["cost"] and k in db["cost"]:
            # per-block cost cannot be negative; depth-1 programs sometimes
            # get boundary-specialized shardings, so clamp at zero.
            per = max((db["cost"][k] - da["cost"][k]) / span, 0.0)
            out["cost"][k] = da["cost"][k] + (nb - ka) * per
            out["per_block"][k] = per
    ca, cb = da.get("collectives", {}), db.get("collectives", {})
    for cat in list(_COLLECTIVES) + ["total_bytes"]:
        va = ca.get(cat, {}).get("bytes", 0) if cat != "total_bytes" else ca.get(cat, 0)
        vb = cb.get(cat, {}).get("bytes", 0) if cat != "total_bytes" else cb.get(cat, 0)
        per = max((vb - va) / span, 0.0)
        out["collectives"][cat] = va + (nb - ka) * per
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, policy: ShardingPolicy, out_dir: str) -> dict:
    cfg = get_config(arch, "full")
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": 512 if multi_pod else 256,
        "params": None, "status": None,
        "policy": {
            "seq_parallel": policy.seq_parallel, "zero1": policy.zero1,
            "remat": policy.remat, "fsdp": policy.fsdp,
            "attn_chunk": policy.attn_chunk, "donate": policy.donate,
        },
    }
    ok, why = applicable(cfg, shape_name)
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models.params import count_params

    result["params"] = count_params(cfg)
    result["active_params"] = cfg.active_param_count()
    try:
        # PASS A -- the lowering/fit proof: full depth, rolled scan (the
        # deployable program; while-loop body reuses buffers, so
        # memory_analysis is the realistic per-device footprint).
        rolled = dataclasses_replace_policy(policy, scan_unroll=False)
        a = _measure(cfg, mesh, shape_name, rolled, want_hlo=False)
        result.update(lower_s=a["lower_s"], compile_s=a["compile_s"], memory=a["memory"])
        result["cost_rolled"] = a["cost"]

        # PASS B -- cost accounting: XLA counts a while body ONCE, so flops/
        # bytes/collectives come from depth-2 and depth-4 UNROLLED compiles,
        # extrapolated linearly (exact; blocks are shape-identical).
        # Single-pod only: the roofline table is single-pod by spec.
        if not multi_pod:
            unrolled = dataclasses_replace_policy(policy, scan_unroll=True)
            d1 = _measure(depth_variant(cfg, 1), mesh, shape_name, unrolled, want_hlo=True)
            d2 = _measure(depth_variant(cfg, 2), mesh, shape_name, unrolled, want_hlo=True)
            result["cost_depth"] = {"d1": d1["cost"], "d2": d2["cost"]}
            result["collectives_depth"] = {"d1": d1["collectives"], "d2": d2["collectives"]}
            ex = _extrapolate(d1, d2, cfg.n_blocks)
            result["cost"] = ex["cost"]
            result["collectives"] = ex["collectives"]
            result["per_block"] = ex["per_block"]
        result["status"] = "ok"
        print({k: result["memory"].get(k) for k in ("temp_size_in_bytes", "argument_size_in_bytes")})
        if "cost" in result:
            print({k: result["cost"].get(k) for k in ("flops", "bytes accessed")},
                  "coll:", result.get("collectives", {}).get("total_bytes"))
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    return result


def dataclasses_replace_policy(policy: ShardingPolicy, **kw) -> ShardingPolicy:
    import dataclasses

    return dataclasses.replace(policy, **kw)


def save_result(res: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{res['arch']}__{res['shape']}__{res['mesh']}.json"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[a.replace("_", "-") for a in ARCH_IDS] + list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out-dir", default=os.path.normpath(ARTIFACTS))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep layer scans rolled (faster compile, body-once flop counts)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=2048)
    ap.add_argument("--tag", default="", help="suffix for ablation artifacts")
    args = ap.parse_args()

    policy = ShardingPolicy(
        seq_parallel=not args.no_seq_parallel,
        zero1=not args.no_zero1,
        remat=not args.no_remat,
        scan_unroll=not args.no_unroll,
        fsdp=not args.no_fsdp,
        donate=not args.no_donate,
        attn_chunk=args.attn_chunk,
    )

    combos = []
    archs = ARCH_IDS if args.all else [args.arch.replace("-", "_")]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    n_fail = 0
    for a, s, mp in combos:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = f"__{args.tag}" if args.tag else ""
        fname = os.path.join(args.out_dir, f"{a}__{s}__{mesh_name}{tag}.json")
        if args.skip_existing and os.path.exists(fname):
            print(f"[skip existing] {a} {s} {mesh_name}")
            continue
        print(f"=== {a} | {s} | {mesh_name} ===", flush=True)
        res = run_one(a, s, mp, policy, args.out_dir)
        if args.tag:
            res["tag"] = args.tag
            res_path = fname
            os.makedirs(args.out_dir, exist_ok=True)
            with open(res_path, "w") as f:
                json.dump(res, f, indent=1)
        else:
            res_path = save_result(res, args.out_dir)
        print(f"[{res['status']}] -> {res_path}", flush=True)
        if res["status"] == "error":
            n_fail += 1
            print(res.get("error"), flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
