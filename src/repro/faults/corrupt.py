"""Host-side checkpoint corruption: the storage half of the fault model.

Round-state checkpoints are written atomically (tmp + rename), so the torn
writes that survive to a COMPLETE step directory are the storage-layer
kind: a truncated ``arrays.npz`` (filesystem lost the tail) or flipped
bytes inside it (medium corruption).  These helpers produce exactly those
states on a real checkpoint directory so tests and the faults benchmark can
drive the restore fallback + chunk-rollback machinery end to end
(checkpoint/io.py detects both via the per-leaf manifest checksums and the
zip-member CRCs and raises ``CorruptCheckpointError``).
"""

from __future__ import annotations

import os
import random


def _step_dir(root: str, step: int) -> str:
    path = os.path.join(root, f"step_{step:08d}")
    if not os.path.isdir(path):
        raise FileNotFoundError(f"no checkpoint step {step} under {root!r}")
    return path


def _npz_paths(root: str, step: int, shard: int | None) -> list[str]:
    """The arrays.npz file(s) of one step: the single-layout file, or the
    given shard's (``shard=None`` = every shard)."""
    path = _step_dir(root, step)
    single = os.path.join(path, "arrays.npz")
    if os.path.isfile(single):
        return [single]
    shards = sorted(
        d for d in os.listdir(path)
        if d.startswith("shard_") and os.path.isdir(os.path.join(path, d))
    )
    if shard is not None:
        shards = [s for s in shards if s == f"shard_{shard:05d}"]
    out = [os.path.join(path, s, "arrays.npz") for s in shards]
    if not out:
        raise FileNotFoundError(f"no arrays.npz under {path!r} (shard={shard})")
    return out


def truncate_npz(root: str, step: int, shard: int | None = None,
                 keep_fraction: float = 0.5) -> list[str]:
    """Tear a checkpoint's array file(s): keep only the leading fraction.

    Truncation destroys the zip central directory at the END of the file,
    which is how a real torn write presents; restore must reject the step
    instead of loading garbage.  Returns the paths corrupted."""
    if not 0.0 <= keep_fraction < 1.0:
        raise ValueError(f"keep_fraction={keep_fraction} outside [0, 1)")
    paths = _npz_paths(root, step, shard)
    for p in paths:
        size = os.path.getsize(p)
        with open(p, "r+b") as f:
            f.truncate(max(int(size * keep_fraction), 1))
    return paths


def flip_bytes(root: str, step: int, shard: int | None = None,
               n_bytes: int = 8, seed: int = 0) -> list[str]:
    """Flip ``n_bytes`` random payload bytes in a checkpoint's array file(s).

    The file length and zip directory stay intact, so only content checks
    (the manifest's per-leaf checksums / the member CRCs) can catch it.
    Returns the paths corrupted."""
    paths = _npz_paths(root, step, shard)
    rng = random.Random(seed)
    for p in paths:
        size = os.path.getsize(p)
        with open(p, "r+b") as f:
            for _ in range(n_bytes):
                # skip the first 1KB: headers there fail fast anyway and the
                # point is to corrupt CONTENT that parses
                off = rng.randrange(min(1024, size - 1), size)
                f.seek(off)
                b = f.read(1)
                f.seek(off)
                f.write(bytes([b[0] ^ 0xFF]))
    return paths
