"""Deterministic PRNG-scheduled fault draws (DESIGN.md Sec. 8).

The schedule is a pure function of ``(FaultConfig.seed, round, client_id)``
through ``jax.random.fold_in`` chains, so

  * the same config reproduces the same fault pattern on every run, every
    topology (vmap simulation and shard_map distribution draw bitwise the
    same masks -- client identity comes from the ``ClientState.client_id``
    leaf, not from device placement), and
  * the draws trace into the scanned round body as ordinary device code:
    no host RNG, no callbacks, nothing the zero-sync contract can see.

Fault kinds (each an independent Bernoulli per round x client, with
dropout taking precedence -- a dropped client cannot also straggle or send
a payload):

  * ``drop``      client misses the round entirely (no update, no queries);
  * ``straggle``  client's update arrives too late: the server sees its
                  STALE iterate (the round's broadcast x) and the client's
                  local state does not advance;
  * ``nan`` / ``inf``  the client's update payload is poisoned with
                  non-finite values (diverged client, corrupted uplink).

Rates set to ``0.0`` are STATIC no-ops: no bernoulli op enters the traced
program for that kind, so an all-zero config measures the pure masking
overhead and a ``faults=None`` run contains no fault code at all (the
bitwise faults-off guarantee).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

#: fold_in tags per fault kind -- disjoint streams off the per-(round,
#: client) base key, so enabling one kind never perturbs another's draws.
_KIND_DROP = 0
_KIND_STRAGGLE = 1
_KIND_NAN = 2
_KIND_INF = 3


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static (hashable) fault schedule: safe as a jit closure / cache key.

    ``first_round``/``last_round`` window the injection on the absolute
    round index (``last_round=None`` = until the end; the window is
    half-open ``[first_round, last_round)``).  ``tolerate=True`` enables
    the engine's masking + quarantine response; ``tolerate=False`` injects
    WITHOUT masking, so one poisoned client visibly poisons the dense psum
    mean -- the failure mode the tolerant engine exists to remove, and the
    trigger for the chunk-rollback path in ``run_rounds``.
    """

    seed: int = 0
    drop_rate: float = 0.0
    straggle_rate: float = 0.0
    nan_rate: float = 0.0
    inf_rate: float = 0.0
    first_round: int = 0
    last_round: Optional[int] = None
    tolerate: bool = True

    def __post_init__(self):
        for field in ("drop_rate", "straggle_rate", "nan_rate", "inf_rate"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field}={v} outside [0, 1]")

    @property
    def injects(self) -> bool:
        """True when any fault kind can ever fire: a nonzero rate AND a
        non-empty injection window.  A statically empty window
        (``last_round <= first_round``) never injects regardless of run
        length; run-length-dependent emptiness (``first_round`` past the
        end of the run) is handled by ``effective_config``."""
        if self.last_round is not None and self.last_round <= self.first_round:
            return False
        return (self.drop_rate > 0 or self.straggle_rate > 0
                or self.nan_rate > 0 or self.inf_rate > 0)

    def active_in(self, rounds: int, start: int = 0) -> bool:
        """True when the injection window ``[first_round, last_round)``
        intersects the run's round range ``[start, rounds)``."""
        if not self.injects:
            return False
        if self.first_round >= rounds:
            return False
        if self.last_round is not None and self.last_round <= max(start, 0):
            return False
        return True


def effective_config(fcfg: Optional[FaultConfig], rounds: int) -> Optional[FaultConfig]:
    """The config the engine should actually run with for a ``rounds``-round
    run.  A config whose rates can never fire inside ``[0, rounds)`` is
    normalized to ``None`` so the run keeps the bitwise faults-off
    guarantee: same compile cache key, no extra psum columns, no insurance
    step-0 checkpoint, no per-boundary finiteness sync.

    A zero-rate config is passed through UNCHANGED: that is the explicit
    opt-in to the masked engine (``--fault-tolerance`` with no injection),
    used to measure masking overhead.
    """
    if fcfg is None or not fcfg.injects:
        return fcfg
    return fcfg if fcfg.active_in(rounds) else None


class FaultDraw(NamedTuple):
    """Per-client fault indicators for one round (bool, shape (N,))."""

    drop: jax.Array
    straggle: jax.Array
    nan: jax.Array
    inf: jax.Array


def _client_draw(fcfg: FaultConfig, round_idx: jax.Array, client_id: jax.Array) -> FaultDraw:
    base = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(fcfg.seed), round_idx), client_id
    )

    def bern(kind: int, rate: float) -> jax.Array:
        if rate <= 0.0:
            return jnp.zeros((), bool)  # static: no RNG op traced
        return jax.random.bernoulli(jax.random.fold_in(base, kind), rate)

    drop = bern(_KIND_DROP, fcfg.drop_rate)
    # precedence: a dropped client sends nothing, so it cannot also
    # straggle or poison; nan wins over inf when both fire
    straggle = bern(_KIND_STRAGGLE, fcfg.straggle_rate) & ~drop
    nan = bern(_KIND_NAN, fcfg.nan_rate) & ~drop
    inf = bern(_KIND_INF, fcfg.inf_rate) & ~drop & ~nan
    return FaultDraw(drop=drop, straggle=straggle, nan=nan, inf=inf)


def draw_faults(fcfg: FaultConfig, round_idx: jax.Array, client_ids: jax.Array) -> FaultDraw:
    """Fault indicators for one round over a batch of clients.

    ``round_idx`` is the ABSOLUTE 0-based round (traced int32 inside the
    scanned body); ``client_ids`` is the (N,) int32 global-identity leaf of
    the stacked ``ClientState``.  Deterministic in (seed, round, client) and
    independent of batch order or sharding.
    """
    round_idx = jnp.asarray(round_idx, jnp.int32)
    draws = jax.vmap(lambda cid: _client_draw(fcfg, round_idx, cid))(client_ids)
    if fcfg.first_round <= 0 and fcfg.last_round is None:
        return draws  # trivial window: no gate ops traced
    active = round_idx >= fcfg.first_round
    if fcfg.last_round is not None:
        active = active & (round_idx < fcfg.last_round)
    return FaultDraw(*(m & active for m in draws))


def schedule_table(fcfg: FaultConfig, rounds: int, n_clients: int):
    """Host-side (rounds, N) view of the schedule per kind, for inspection.

    Returns a dict of numpy bool arrays keyed by fault kind.  Computed with
    the same jax draws the engine traces, so the table IS what the engine
    will inject (tested)."""
    import numpy as np

    ids = jnp.arange(n_clients, dtype=jnp.int32)
    rs = jnp.arange(rounds, dtype=jnp.int32)
    # One vmapped dispatch over the round axis + one transfer, instead of
    # `rounds` sequential jit calls each followed by a device_get.  fold_in
    # is elementwise over the batched round index, so the table is bitwise
    # identical to the per-round draws the engine traces (tested).
    table = jax.jit(jax.vmap(lambda r: draw_faults(fcfg, r, ids)))(rs)
    host = jax.device_get(table)
    return {k: np.asarray(getattr(host, k), dtype=bool)
            for k in FaultDraw._fields}
