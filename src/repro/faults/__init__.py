"""Deterministic fault injection for the federated round engine.

``injector``: the PRNG-scheduled per-(round, client) fault draws and the
``FaultConfig`` knob surface consumed by ``core.algorithms.run_round`` /
``core.rounds.run_rounds``.  ``corrupt``: host-side checkpoint corruption
utilities (torn writes, bit flips) -- the storage-fault half of the fault
model, used by tests and the faults benchmark.
"""

from repro.faults.injector import (
    FaultConfig,
    FaultDraw,
    draw_faults,
    effective_config,
    schedule_table,
)
from repro.faults import corrupt

__all__ = ["FaultConfig", "FaultDraw", "draw_faults", "effective_config",
           "schedule_table", "corrupt"]
