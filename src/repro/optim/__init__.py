"""Pure-JAX optimizers (no optax in the environment).

Used both by the federated-ZOO local updates (paper Appx. E uses Adam with
lr 0.01) and by the first-order LM-training substrate (examples/train driver).
Everything is a pytree-in / pytree-out pure function so it vmaps, scans and
shard_maps cleanly.
"""

from repro.optim.optimizers import (  # noqa: F401
    AdamState,
    OptState,
    adam_init,
    adam_update,
    adamw_init,
    adamw_update,
    make_optimizer,
    sgd_init,
    sgd_update,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule,
    cosine_decay_schedule,
    warmup_cosine_schedule,
)
