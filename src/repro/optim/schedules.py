"""Learning-rate schedules for the LM-training substrate."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(value: float):
    def fn(step):
        return jnp.asarray(value, jnp.float32)

    return fn


def cosine_decay_schedule(peak: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))

    return fn


def warmup_cosine_schedule(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    cos = cosine_decay_schedule(peak, max(total_steps - warmup_steps, 1), floor)

    def fn(step):
        warm = peak * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return fn
