"""SGD / Adam / AdamW over arbitrary pytrees."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    step: jax.Array  # () int32


class OptState(NamedTuple):
    """Generic wrapper so callers can switch optimizers without re-plumbing."""

    inner: Any


def _zeros_like(tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


# -- SGD ---------------------------------------------------------------------


def sgd_init(params: Pytree) -> OptState:
    del params
    return OptState(inner=())


def _keep_dtype(p: jax.Array, new_p: jax.Array) -> jax.Array:
    """Updated leaf cast back to the PARAM dtype.

    ``p - lr * (...)`` with an f32 ``lr`` silently promotes bf16 params to
    f32 on the first step -- the model then runs (and checkpoints) in the
    wrong precision and the restored-vs-init dtype validation fails.  The
    update math stays in the promoted precision; only the stored leaf is
    cast.  A no-op for f32 params (same-dtype astype is identity).
    """
    return new_p.astype(p.dtype)


def sgd_update(
    state: OptState, grads: Pytree, params: Pytree, lr: float | jax.Array, momentum: float = 0.0
) -> tuple[Pytree, OptState]:
    if momentum and state.inner == ():
        raise ValueError("momentum SGD requires sgd_momentum_init")
    new_params = jax.tree_util.tree_map(
        lambda p, g: _keep_dtype(p, p - lr * g), params, grads
    )
    return new_params, state


# -- Adam --------------------------------------------------------------------


def adam_init(params: Pytree) -> OptState:
    return OptState(inner=AdamState(mu=_zeros_like(params), nu=_zeros_like(params), step=jnp.zeros((), jnp.int32)))


def adam_update(
    state: OptState,
    grads: Pytree,
    params: Pytree,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[Pytree, OptState]:
    st: AdamState = state.inner
    step = st.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, st.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * (g * g), st.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: _keep_dtype(p, p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)),
        params, mu, nu,
    )
    return new_params, OptState(inner=AdamState(mu=mu, nu=nu, step=step))


# -- AdamW (LM training substrate) -------------------------------------------


def adamw_init(params: Pytree) -> OptState:
    return adam_init(params)


def adamw_update(
    state: OptState,
    grads: Pytree,
    params: Pytree,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[Pytree, OptState]:
    st: AdamState = state.inner
    step = st.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, st.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * (g * g), st.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: _keep_dtype(
            p, p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p)
        ),
        params,
        mu,
        nu,
    )
    return new_params, OptState(inner=AdamState(mu=mu, nu=nu, step=step))


# -- dispatch ------------------------------------------------------------------


def make_optimizer(name: str) -> tuple[Callable[..., OptState], Callable[..., tuple[Pytree, OptState]]]:
    if name == "sgd":
        return sgd_init, sgd_update
    if name == "adam":
        return adam_init, adam_update
    if name == "adamw":
        return adamw_init, adamw_update
    raise ValueError(f"unknown optimizer {name!r}")
