"""SGD / Adam / AdamW over arbitrary pytrees."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    step: jax.Array  # () int32


class OptState(NamedTuple):
    """Generic wrapper so callers can switch optimizers without re-plumbing."""

    inner: Any


def _zeros_like(tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


# -- SGD ---------------------------------------------------------------------


def sgd_init(params: Pytree) -> OptState:
    del params
    return OptState(inner=())


def sgd_update(
    state: OptState, grads: Pytree, params: Pytree, lr: float | jax.Array, momentum: float = 0.0
) -> tuple[Pytree, OptState]:
    if momentum and state.inner == ():
        raise ValueError("momentum SGD requires sgd_momentum_init")
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_params, state


# -- Adam --------------------------------------------------------------------


def adam_init(params: Pytree) -> OptState:
    return OptState(inner=AdamState(mu=_zeros_like(params), nu=_zeros_like(params), step=jnp.zeros((), jnp.int32)))


def adam_update(
    state: OptState,
    grads: Pytree,
    params: Pytree,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[Pytree, OptState]:
    st: AdamState = state.inner
    step = st.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, st.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * (g * g), st.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), params, mu, nu
    )
    return new_params, OptState(inner=AdamState(mu=mu, nu=nu, step=step))


# -- AdamW (LM training substrate) -------------------------------------------


def adamw_init(params: Pytree) -> OptState:
    return adam_init(params)


def adamw_update(
    state: OptState,
    grads: Pytree,
    params: Pytree,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[Pytree, OptState]:
    st: AdamState = state.inner
    step = st.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, st.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * (g * g), st.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p),
        params,
        mu,
        nu,
    )
    return new_params, OptState(inner=AdamState(mu=mu, nu=nu, step=step))


# -- dispatch ------------------------------------------------------------------


def make_optimizer(name: str) -> tuple[Callable[..., OptState], Callable[..., tuple[Pytree, OptState]]]:
    if name == "sgd":
        return sgd_init, sgd_update
    if name == "adam":
        return adam_init, adam_update
    if name == "adamw":
        return adamw_init, adamw_update
    raise ValueError(f"unknown optimizer {name!r}")
