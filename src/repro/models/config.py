"""Architecture configuration for the model zoo.

One frozen dataclass drives every family (dense / moe / ssm / hybrid /
encdec-audio / vlm).  Fields unused by a family stay at their zero default.
Configs for the ten assigned architectures live in ``repro/configs/``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention / embedding details
    mlp_act: str = "swiglu"  # swiglu | geglu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_mode: str = "standard"  # standard | mrope | none
    sliding_window: int = 0  # 0 = full attention; >0 = local window (decode)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # hybrid (jamba-style): one attention layer every `attn_every` layers
    attn_every: int = 0

    # encoder-decoder (whisper backbone; conv/mel frontend is a stub)
    n_enc_layers: int = 0
    enc_seq: int = 1500  # whisper frame positions after conv frontend
    frontend_dim: int = 0  # stub embedding dim (== d_model for whisper)
    dec_pos_len: int = 8192  # learned decoder position table size

    # vlm (qwen2-vl backbone; ViT frontend is a stub)
    n_patches: int = 0  # stub patch-embedding count for input_specs
    mrope_sections: tuple[int, ...] = (16, 24, 24)  # qwen2-vl t/h/w split

    # numerics
    dtype: str = "bfloat16"

    # capability flags
    supports_long_context: bool = False  # sub-quadratic decode available?
    has_decoder: bool = True  # encoder-only archs would be False

    # provenance
    source: str = ""  # citation for the config numbers

    def __post_init__(self):
        if self.arch_type not in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"):
            raise ValueError(f"bad arch_type {self.arch_type}")
        if self.arch_type in ("moe",) and self.n_experts <= 0:
            raise ValueError("moe arch needs n_experts")
        if self.arch_type == "hybrid" and self.attn_every <= 0:
            raise ValueError("hybrid arch needs attn_every")

    # -- derived sizes -------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def ssm_conv_channels(self) -> int:
        # conv runs over [x | B | C] streams as in Mamba2
        return self.ssm_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def ssm_in_proj_dim(self) -> int:
        # [z | x | B | C | dt]
        return 2 * self.ssm_inner + 2 * self.ssm_groups * self.ssm_state + self.ssm_heads

    @property
    def is_moe_mlp(self) -> bool:
        return self.n_experts > 0

    @property
    def n_blocks(self) -> int:
        """Scan length.  Hybrids scan super-blocks of `attn_every` layers."""
        if self.arch_type == "hybrid":
            assert self.n_layers % self.attn_every == 0
            return self.n_layers // self.attn_every
        return self.n_layers

    @property
    def block_kind(self) -> str:
        if self.arch_type == "ssm":
            return "ssm"
        if self.arch_type == "hybrid":
            return "hybrid"
        return "attn"

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D roofline bookkeeping)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

        if self.is_moe_mlp:
            mlp = self.n_experts * 3 * d * ff + d * self.n_experts
            mlp += self.n_shared_experts * 3 * d * ff
        else:
            mlp = 3 * d * ff

        ssm = (
            d * self.ssm_in_proj_dim
            + self.ssm_conv * self.ssm_conv_channels
            + 3 * self.ssm_heads
            + self.ssm_inner
            + self.ssm_inner * d
        )

        norms = 2 * d
        if self.arch_type == "ssm":
            per_layer = ssm + norms  # mamba2 blocks have no separate MLP
            total = self.n_layers * per_layer
        elif self.arch_type == "hybrid":
            n_attn = self.n_layers // self.attn_every
            n_ssm = self.n_layers - n_attn
            total = n_attn * (attn + mlp + norms) + n_ssm * (ssm + mlp + norms)
        elif self.arch_type == "encdec":
            dec = self.n_layers * (attn + attn + mlp + 3 * d)  # self+cross
            enc = self.n_enc_layers * (attn + mlp + norms)
            total = dec + enc
        else:
            total = self.n_layers * (attn + mlp + norms)
        return int(total + emb + d)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.is_moe_mlp:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        # subtract the inactive experts: each MLP site keeps top_k + shared.
        per_site_full = self.n_experts * 3 * d * ff
        per_site_active = (self.moe_top_k + self.n_shared_experts) * 3 * d * ff
        n_sites = self.n_layers  # every layer has an MLP in moe/hybrid archs
        return int(self.param_count() - n_sites * (per_site_full - per_site_active))
