"""Model assembly for every architecture family.

All stacks scan over homogeneous blocks (hybrids scan super-blocks of
``attn_every`` layers) so HLO size is depth-independent.  Three entry points
per architecture, matching the dry-run input shapes:

  train_step   -- full-sequence causal LM loss + AdamW update    (train_4k)
  prefill      -- full-sequence forward that fills the decode cache (prefill_32k)
  decode_step  -- ONE new token against a seq_len cache           (decode_32k,
                  long_500k for sub-quadratic archs)

Modality carve-outs (see DESIGN.md): whisper's mel+conv frontend and
qwen2-vl's ViT are stubs -- ``input_specs`` hands the backbone precomputed
frame/patch embeddings of the right shape.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update
from repro.sharding.rules import ShardingPolicy, batch_axes, constrain

Params = dict[str, jax.Array]


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


class AttnCache(NamedTuple):
    k: jax.Array  # (nb, B, S, KV, hd)
    v: jax.Array


class SsmStack(NamedTuple):
    conv: jax.Array  # (nb, [n_ssm,] B, K-1, C)
    state: jax.Array  # (nb, [n_ssm,] B, H, P, N)


class DecodeCache(NamedTuple):
    """Union cache; unused members are size-0 arrays to stay a pytree."""

    attn: AttnCache
    ssm: SsmStack
    cross: AttnCache  # encdec only: encoder K/V per decoder layer
    pos: jax.Array  # () int32 next write position


def _zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> DecodeCache:
    dtype = dtype or jnp.dtype(cfg.dtype)
    nb = cfg.n_blocks
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    e0 = lambda: AttnCache(_zeros((0,), dtype), _zeros((0,), dtype))
    s0 = lambda: SsmStack(_zeros((0,), dtype), _zeros((0,), jnp.float32))

    if cfg.arch_type == "ssm":
        attn = e0()
        ssmc = SsmStack(
            conv=_zeros((nb, batch, cfg.ssm_conv - 1, cfg.ssm_conv_channels), dtype),
            state=_zeros((nb, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        )
        cross = e0()
    elif cfg.arch_type == "hybrid":
        n_ssm = cfg.attn_every - 1
        attn = AttnCache(
            k=_zeros((nb, batch, seq, kv, hd), dtype), v=_zeros((nb, batch, seq, kv, hd), dtype)
        )
        ssmc = SsmStack(
            conv=_zeros((nb, n_ssm, batch, cfg.ssm_conv - 1, cfg.ssm_conv_channels), dtype),
            state=_zeros(
                (nb, n_ssm, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            ),
        )
        cross = e0()
    elif cfg.arch_type == "encdec":
        attn = AttnCache(
            k=_zeros((nb, batch, seq, kv, hd), dtype), v=_zeros((nb, batch, seq, kv, hd), dtype)
        )
        ssmc = s0()
        cross = AttnCache(
            k=_zeros((nb, batch, cfg.enc_seq, kv, hd), dtype),
            v=_zeros((nb, batch, cfg.enc_seq, kv, hd), dtype),
        )
    else:
        attn = AttnCache(
            k=_zeros((nb, batch, seq, kv, hd), dtype), v=_zeros((nb, batch, seq, kv, hd), dtype)
        )
        ssmc = s0()
        cross = e0()
    return DecodeCache(attn=attn, ssm=ssmc, cross=cross, pos=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# block bodies (full sequence)
# ---------------------------------------------------------------------------


def _block_params(p: Params, prefix: str = "blocks/") -> Params:
    return {k[len(prefix) :]: v for k, v in p.items() if k.startswith(prefix)}


def _mlp_or_moe(bp: Params, prefix: str, x: jax.Array, cfg: ModelConfig):
    if cfg.is_moe_mlp:
        return L.moe_block(bp, prefix, x, cfg, return_aux=True)
    return L.mlp_block(bp, prefix, x, cfg), jnp.zeros((), jnp.float32)


def _residual(x: jax.Array, policy: ShardingPolicy) -> jax.Array:
    ba = batch_axes(policy)
    seq_ax = "model" if policy.seq_parallel else None
    return constrain(x, ba, seq_ax, None)


def _scan(policy: ShardingPolicy, body, init, xs):
    """lax.scan over blocks; fully unrolled when policy.scan_unroll (the
    dry-run uses this so cost_analysis counts every layer, not the while-loop
    body once)."""
    return jax.lax.scan(body, init, xs, unroll=True if policy.scan_unroll else 1)


def _full_block(
    bp: Params, x: jax.Array, cfg: ModelConfig, positions, policy: ShardingPolicy, window: int
):
    """One scanned block, full-sequence mode.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.block_kind == "ssm":
        x = x + S.ssm_block_train(S.pick_ssm(bp, ""), x, cfg)
        return _residual(x, policy), aux
    if cfg.block_kind == "hybrid":
        # 1 attention layer ...
        x = x + L.attn_block(L.pick_attn(bp, "attn."), x, cfg, positions, window=window, chunk=policy.attn_chunk)
        d, a = _mlp_or_moe(_index_sub(bp, "mlp.", 0), "mlp.", x, cfg)
        x = _residual(x + d, policy)
        aux += a
        # ... then attn_every-1 mamba layers, each with its MLP.
        for i in range(cfg.attn_every - 1):
            x = x + S.ssm_block_train(S.pick_ssm(_index_sub(bp, "ssm.", i), "ssm."), x, cfg)
            d, a = _mlp_or_moe(_index_sub(bp, "mlp.", i + 1), "mlp.", x, cfg)
            x = _residual(x + d, policy)
            aux += a
        return x, aux
    # plain attention block (dense / moe / vlm / encoder-decoder handled apart)
    x = x + L.attn_block(L.pick_attn(bp, "attn."), x, cfg, positions, window=window, chunk=policy.attn_chunk)
    d, a = _mlp_or_moe(bp, "mlp.", x, cfg)
    return _residual(x + d, policy), aux + a


def _index_sub(bp: Params, prefix: str, i: int) -> Params:
    """Select the i-th inner layer of a super-block parameter group."""
    return {k: (v[i] if k.startswith(prefix) else v) for k, v in bp.items() if k.startswith(prefix)}


# ---------------------------------------------------------------------------
# forward (train / prefill trunk)
# ---------------------------------------------------------------------------


def _positions_for(cfg: ModelConfig, batch: dict, bsz: int, length: int) -> jax.Array:
    if cfg.rope_mode == "mrope":
        if "positions" in batch:
            return batch["positions"]  # (B, L, 3)
        base = jnp.arange(length)[None, :, None]
        return jnp.broadcast_to(base, (bsz, length, 3))
    return jnp.broadcast_to(jnp.arange(length)[None, :], (bsz, length))


def _embed(p: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embed"], tokens, axis=0)


def _merge_patches(x: jax.Array, batch: dict) -> jax.Array:
    """VLM: overwrite the first n_patches positions with the (stub) patch
    embeddings -- the projector output of the vision tower."""
    patches = batch.get("patches")
    if patches is None:
        return x
    return jax.lax.dynamic_update_slice(x, patches.astype(x.dtype), (0, 0, 0))


def _unembed(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = L.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = x @ head
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(logits, None, None, "model")


def _encode(p: Params, cfg: ModelConfig, frames: jax.Array, policy: ShardingPolicy) -> jax.Array:
    """Whisper-style encoder over (stub) frame embeddings (B, enc_seq, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype)) + p["enc_pos"][None, : frames.shape[1], :].astype(
        jnp.dtype(cfg.dtype)
    )
    bp_all = _block_params(p, "enc_blocks/")
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :], x.shape[:2])

    def body(carry, bp):
        x = carry
        x = x + L.attn_block(L.pick_attn(bp, "attn."), x, cfg, pos, causal=False)
        x = x + L.mlp_block(bp, "mlp.", x, cfg)
        return _residual(x, policy), None

    if policy.remat:
        body = jax.checkpoint(body)
    x, _ = _scan(policy, body, x, bp_all)
    return L.rmsnorm(x, p["enc_norm"], cfg.norm_eps)


def forward(
    p: Params,
    cfg: ModelConfig,
    batch: dict,
    policy: ShardingPolicy,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits (B, L, V), moe_aux)."""
    tokens = batch["tokens"]
    bsz, length = tokens.shape
    x = _embed(p, cfg, tokens)
    if cfg.arch_type == "vlm":
        x = _merge_patches(x, batch)
    positions = _positions_for(cfg, batch, bsz, length)
    x = _residual(x, policy)

    if cfg.arch_type == "encdec":
        enc_out = _encode(p, cfg, batch["frames"], policy)
        x = x + p["dec_pos"][None, :length, :].astype(x.dtype)
        bp_all = _block_params(p)

        def body(carry, bp):
            x = carry
            x = x + L.attn_block(L.pick_attn(bp, "self."), x, cfg, positions, causal=True, chunk=policy.attn_chunk)
            ca = L.pick_attn(bp, "cross.")
            # enc_out is already enc_norm'd by _encode; cross K/V project it raw
            # (kept identical to the prefill path -- decode-vs-forward tested).
            ck = (enc_out @ ca.wk).reshape(bsz, -1, cfg.n_kv_heads, cfg.resolved_head_dim)
            cv = (enc_out @ ca.wv).reshape(bsz, -1, cfg.n_kv_heads, cfg.resolved_head_dim)
            x = x + L.attn_block(ca, x, cfg, positions, cross_kv=(ck, cv))
            x = x + L.mlp_block(bp, "mlp.", x, cfg)
            return _residual(x, policy), jnp.zeros((), jnp.float32)

        if policy.remat:
            body = jax.checkpoint(body)
        x, auxs = _scan(policy, body, x, bp_all)
        return _unembed(p, cfg, x), jnp.sum(auxs)

    bp_all = _block_params(p)
    window = cfg.sliding_window

    def body(carry, bp):
        x = carry
        x, aux = _full_block(bp, x, cfg, positions, policy, window)
        return x, aux

    if policy.remat:
        body = jax.checkpoint(body)
    x, auxs = _scan(policy, body, x, bp_all)
    return _unembed(p, cfg, x), jnp.sum(auxs)


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------


def lm_loss(
    p: Params, cfg: ModelConfig, batch: dict, policy: ShardingPolicy
) -> tuple[jax.Array, dict]:
    logits, aux = forward(p, cfg, batch, policy)
    labels = batch["labels"]
    valid = labels >= 0
    labels_c = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    # Select the label logit with a fused masked reduce rather than
    # take_along_axis: a gather along the vocab-sharded axis would force
    # GSPMD to all-gather the full f32 logits (measured 40 GB/device on
    # qwen1.5 train_4k); the iota==label select fuses into the reduction.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == labels_c[..., None], lf, 0.0), axis=-1)
    nll = (lse - picked) * valid
    n = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / n
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "moe_aux": aux, "tokens": n}


def train_step(
    p: Params,
    opt_state,
    cfg: ModelConfig,
    batch: dict,
    policy: ShardingPolicy,
    lr: float | jax.Array = 1e-4,
):
    (total, metrics), grads = jax.value_and_grad(
        lambda pp: lm_loss(pp, cfg, batch, policy), has_aux=True
    )(p)
    new_p, new_opt = adamw_update(opt_state, grads, p, lr)
    metrics = dict(metrics, total=total, grad_norm=_global_norm(grads))
    return new_p, new_opt, metrics


def _global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def init_train_state(key: jax.Array, cfg: ModelConfig):
    from repro.models.params import init_params

    p = init_params(key, cfg)
    return p, adamw_init(p)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(
    p: Params, cfg: ModelConfig, batch: dict, policy: ShardingPolicy, cache_len: int = 0
) -> tuple[jax.Array, DecodeCache]:
    """Full-sequence forward that also fills the decode cache.

    Returns (last-token logits (B, V), cache with pos = L).
    """
    tokens = batch["tokens"]
    bsz, length = tokens.shape
    cache_len = cache_len or length
    cache = init_cache(cfg, bsz, cache_len)
    x = _embed(p, cfg, tokens)
    if cfg.arch_type == "vlm":
        x = _merge_patches(x, batch)
    positions = _positions_for(cfg, batch, bsz, length)
    x = _residual(x, policy)
    window = cfg.sliding_window
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def project_kv(ap: L.AttnParams, xin: jax.Array, rope: bool = True):
        _, k, v = L._project_qkv(ap, xin, cfg)
        if rope:
            k = L.apply_rope(k, positions, cfg.rope_theta, cfg.rope_mode, cfg.mrope_sections)
        return k, v

    def pad_cache(k: jax.Array) -> jax.Array:
        if cache_len == length:
            return k
        pad = cache_len - length
        return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))

    if cfg.arch_type == "encdec":
        enc_out = _encode(p, cfg, batch["frames"], policy)
        x = x + p["dec_pos"][None, :length, :].astype(x.dtype)
        bp_all = _block_params(p)

        def body(carry, bp):
            x = carry
            sa = L.pick_attn(bp, "self.")
            sk, sv = project_kv(sa, x, rope=cfg.rope_mode != "none")
            x = x + L.attn_block(sa, x, cfg, positions, causal=True, chunk=policy.attn_chunk)
            ca = L.pick_attn(bp, "cross.")
            ck = (enc_out @ ca.wk).reshape(bsz, -1, kv, hd)
            cv = (enc_out @ ca.wv).reshape(bsz, -1, kv, hd)
            x = x + L.attn_block(ca, x, cfg, positions, cross_kv=(ck, cv))
            x = x + L.mlp_block(bp, "mlp.", x, cfg)
            return _residual(x, policy), (pad_cache(sk), pad_cache(sv), ck, cv)

        x, (ks, vs, cks, cvs) = _scan(policy, body, x, bp_all)
        cache = cache._replace(
            attn=AttnCache(k=ks, v=vs),
            cross=AttnCache(k=cks, v=cvs),
            pos=jnp.asarray(length, jnp.int32),
        )
        logits = _unembed(p, cfg, x[:, -1:, :])[:, 0, :]
        return logits, cache

    bp_all = _block_params(p)

    if cfg.arch_type == "ssm":

        def body(carry, bp):
            x = carry
            sp = S.pick_ssm(bp, "")
            xn = L.rmsnorm(x, sp.ln, cfg.norm_eps)
            zxbcdt = xn @ sp.in_proj
            z, xbc, dt = S._split_in_proj(cfg, zxbcdt)
            conv_tail = jnp.concatenate(
                [jnp.zeros((bsz, cfg.ssm_conv - 1, xbc.shape[-1]), xbc.dtype), xbc], axis=1
            )[:, -(cfg.ssm_conv - 1) :, :]
            xbc = S._causal_conv_train(xbc, sp.conv_w, sp.conv_b)
            g, n = cfg.ssm_groups, cfg.ssm_state
            xs, bmat, cmat = jnp.split(xbc, [cfg.ssm_inner, cfg.ssm_inner + g * n], axis=-1)
            xs = xs.reshape(bsz, length, cfg.ssm_heads, cfg.ssm_head_dim)
            dtv = jax.nn.softplus(dt.astype(jnp.float32) + sp.dt_bias)
            a = -jnp.exp(sp.a_log.astype(jnp.float32))
            y, hfin = S.ssd_scan(cfg, xs, dtv, a, bmat.reshape(bsz, length, g, n), cmat.reshape(bsz, length, g, n))
            y = y + xs * sp.d_skip[None, None, :, None].astype(y.dtype)
            y = y.reshape(bsz, length, cfg.ssm_inner) * jax.nn.silu(z)
            y = L.rmsnorm(y, sp.out_norm, cfg.norm_eps)
            x = _residual(x + y @ sp.out_proj, policy)
            return x, (conv_tail, hfin)

        x, (convs, states) = _scan(policy, body, x, bp_all)
        cache = cache._replace(
            ssm=SsmStack(conv=convs, state=states), pos=jnp.asarray(length, jnp.int32)
        )
        return _unembed(p, cfg, x[:, -1:, :])[:, 0, :], cache

    if cfg.arch_type == "hybrid":
        n_ssm = cfg.attn_every - 1

        def body(carry, bp):
            x = carry
            ap = L.pick_attn(bp, "attn.")
            ak, av = project_kv(ap, x)
            x = x + L.attn_block(ap, x, cfg, positions, window=window, chunk=policy.attn_chunk)
            d, _ = _mlp_or_moe(_index_sub(bp, "mlp.", 0), "mlp.", x, cfg)
            x = _residual(x + d, policy)
            convs, states = [], []
            for i in range(n_ssm):
                sp = S.pick_ssm(_index_sub(bp, "ssm.", i), "ssm.")
                xn = L.rmsnorm(x, sp.ln, cfg.norm_eps)
                zxbcdt = xn @ sp.in_proj
                z, xbc, dt = S._split_in_proj(cfg, zxbcdt)
                conv_tail = jnp.concatenate(
                    [jnp.zeros((bsz, cfg.ssm_conv - 1, xbc.shape[-1]), xbc.dtype), xbc], axis=1
                )[:, -(cfg.ssm_conv - 1) :, :]
                xbc2 = S._causal_conv_train(xbc, sp.conv_w, sp.conv_b)
                g, n = cfg.ssm_groups, cfg.ssm_state
                xs, bmat, cmat = jnp.split(xbc2, [cfg.ssm_inner, cfg.ssm_inner + g * n], axis=-1)
                xs = xs.reshape(bsz, length, cfg.ssm_heads, cfg.ssm_head_dim)
                dtv = jax.nn.softplus(dt.astype(jnp.float32) + sp.dt_bias)
                a = -jnp.exp(sp.a_log.astype(jnp.float32))
                y, hfin = S.ssd_scan(
                    cfg, xs, dtv, a, bmat.reshape(bsz, length, g, n), cmat.reshape(bsz, length, g, n)
                )
                y = y + xs * sp.d_skip[None, None, :, None].astype(y.dtype)
                y = y.reshape(bsz, length, cfg.ssm_inner) * jax.nn.silu(z)
                y = L.rmsnorm(y, sp.out_norm, cfg.norm_eps)
                x = x + y @ sp.out_proj
                d, _ = _mlp_or_moe(_index_sub(bp, "mlp.", i + 1), "mlp.", x, cfg)
                x = _residual(x + d, policy)
                convs.append(conv_tail)
                states.append(hfin)
            return x, (pad_cache(ak), pad_cache(av), jnp.stack(convs), jnp.stack(states))

        x, (ks, vs, convs, states) = _scan(policy, body, x, bp_all)
        cache = cache._replace(
            attn=AttnCache(k=ks, v=vs),
            ssm=SsmStack(conv=convs, state=states),
            pos=jnp.asarray(length, jnp.int32),
        )
        return _unembed(p, cfg, x[:, -1:, :])[:, 0, :], cache

    # dense / moe / vlm
    def body2(carry, bp):
        x = carry
        ap = L.pick_attn(bp, "attn.")
        k, v = project_kv(ap, x)
        x = x + L.attn_block(ap, x, cfg, positions, window=window, chunk=policy.attn_chunk)
        d, _ = _mlp_or_moe(bp, "mlp.", x, cfg)
        return _residual(x + d, policy), (pad_cache(k), pad_cache(v))

    x, (ks, vs) = _scan(policy, body2, x, bp_all)
    cache = cache._replace(attn=AttnCache(k=ks, v=vs), pos=jnp.asarray(length, jnp.int32))
    return _unembed(p, cfg, x[:, -1:, :])[:, 0, :], cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_step(
    p: Params, cfg: ModelConfig, cache: DecodeCache, token: jax.Array, policy: ShardingPolicy
) -> tuple[jax.Array, DecodeCache]:
    """One-token decode.  token (B, 1) int32 -> (logits (B, V), cache)."""
    pos = cache.pos
    x = _embed(p, cfg, token)
    window = cfg.sliding_window
    bp_all = _block_params(p)

    if cfg.arch_type == "encdec":
        x = x + jax.lax.dynamic_slice(p["dec_pos"], (pos, 0), (1, cfg.d_model))[None].astype(x.dtype)

        def body(carry, xs):
            x = carry
            bp, kc, vc, ck, cv = xs
            d, kc, vc = L.attn_decode(L.pick_attn(bp, "self."), x, cfg, kc, vc, pos)
            x = x + d
            d, _, _ = L.attn_decode(L.pick_attn(bp, "cross."), x, cfg, ck, cv, pos, cross=True)
            x = x + d
            x = x + L.mlp_block(bp, "mlp.", x, cfg)
            return x, (kc, vc)

        x, (ks, vs) = _scan(
            policy, body, x, (bp_all, cache.attn.k, cache.attn.v, cache.cross.k, cache.cross.v)
        )
        new_cache = cache._replace(attn=AttnCache(k=ks, v=vs), pos=pos + 1)
        return _unembed(p, cfg, x)[:, 0, :], new_cache

    if cfg.arch_type == "ssm":

        def body(carry, xs):
            x = carry
            bp, conv, state = xs
            d, sc = S.ssm_block_decode(S.pick_ssm(bp, ""), x, cfg, S.SsmCache(conv, state))
            return x + d, (sc.conv, sc.state)

        x, (convs, states) = _scan(policy, body, x, (bp_all, cache.ssm.conv, cache.ssm.state))
        new_cache = cache._replace(ssm=SsmStack(conv=convs, state=states), pos=pos + 1)
        return _unembed(p, cfg, x)[:, 0, :], new_cache

    if cfg.arch_type == "hybrid":
        n_ssm = cfg.attn_every - 1

        def body(carry, xs):
            x = carry
            bp, kc, vc, convs, states = xs
            d, kc, vc = L.attn_decode(L.pick_attn(bp, "attn."), x, cfg, kc, vc, pos, window=window)
            x = x + d
            d, _ = _mlp_or_moe(_index_sub(bp, "mlp.", 0), "mlp.", x, cfg)
            x = x + d
            new_convs, new_states = [], []
            for i in range(n_ssm):
                sp = S.pick_ssm(_index_sub(bp, "ssm.", i), "ssm.")
                d, sc = S.ssm_block_decode(sp, x, cfg, S.SsmCache(convs[i], states[i]))
                x = x + d
                d, _ = _mlp_or_moe(_index_sub(bp, "mlp.", i + 1), "mlp.", x, cfg)
                x = x + d
                new_convs.append(sc.conv)
                new_states.append(sc.state)
            return x, (kc, vc, jnp.stack(new_convs), jnp.stack(new_states))

        x, (ks, vs, convs, states) = _scan(
            policy, body, x, (bp_all, cache.attn.k, cache.attn.v, cache.ssm.conv, cache.ssm.state)
        )
        new_cache = cache._replace(
            attn=AttnCache(k=ks, v=vs), ssm=SsmStack(conv=convs, state=states), pos=pos + 1
        )
        return _unembed(p, cfg, x)[:, 0, :], new_cache

    # dense / moe / vlm
    def body(carry, xs):
        x = carry
        bp, kc, vc = xs
        d, kc, vc = L.attn_decode(L.pick_attn(bp, "attn."), x, cfg, kc, vc, pos, window=window)
        x = x + d
        d, _ = _mlp_or_moe(bp, "mlp.", x, cfg)
        return x + d, (kc, vc)

    x, (ks, vs) = _scan(policy, body, x, (bp_all, cache.attn.k, cache.attn.v))
    new_cache = cache._replace(attn=AttnCache(k=ks, v=vs), pos=pos + 1)
    return _unembed(p, cfg, x)[:, 0, :], new_cache


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins, Sec. MULTI-POD DRY-RUN item 2)
# ---------------------------------------------------------------------------

INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a step function."""
    sh = INPUT_SHAPES[shape_name]
    b, l = sh["global_batch"], sh["seq_len"]
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct

    if sh["kind"] == "train":
        batch = {"tokens": sds((b, l), i32), "labels": sds((b, l), i32)}
        if cfg.arch_type == "vlm":
            batch["patches"] = sds((b, cfg.n_patches, cfg.d_model), f)
            batch["positions"] = sds((b, l, 3), i32)
        if cfg.arch_type == "encdec":
            batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), f)
        return batch
    if sh["kind"] == "prefill":
        batch = {"tokens": sds((b, l), i32)}
        if cfg.arch_type == "vlm":
            batch["patches"] = sds((b, cfg.n_patches, cfg.d_model), f)
            batch["positions"] = sds((b, l, 3), i32)
        if cfg.arch_type == "encdec":
            batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), f)
        return batch
    # decode: one token against a seq_len cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, l))
    return {"token": sds((b, 1), i32), "cache": cache}
