from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    INPUT_SHAPES,
    DecodeCache,
    decode_step,
    forward,
    init_cache,
    init_train_state,
    input_specs,
    lm_loss,
    prefill,
    train_step,
)
from repro.models.params import (  # noqa: F401
    count_params,
    init_params,
    param_defs,
    param_pspecs,
    param_shapes,
)
