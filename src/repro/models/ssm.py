"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: within-chunk quadratic
(attention-dual) matmuls + an inter-chunk linear recurrence over chunk
states, which is O(L) in sequence length and maps onto the MXU as batched
GEMMs.  Decode is the O(1) recurrent update  h <- exp(dt*A) h + dt * B x^T.

Layout: heads (H = expand*d/headdim) shard over 'model'; B/C use
``ssm_groups`` groups broadcast across heads (G=1 for mamba2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.sharding.rules import constrain


class SsmParams(NamedTuple):
    ln: jax.Array
    in_proj: jax.Array  # (d, 2*din + 2*G*N + H)
    conv_w: jax.Array  # (K, conv_channels)
    conv_b: jax.Array  # (conv_channels,)
    a_log: jax.Array  # (H,)
    d_skip: jax.Array  # (H,)
    dt_bias: jax.Array  # (H,)
    out_norm: jax.Array  # (din,)
    out_proj: jax.Array  # (din, d)


def pick_ssm(p: dict, prefix: str) -> SsmParams:
    return SsmParams(
        ln=p[f"{prefix}ln"],
        in_proj=p[f"{prefix}in_proj"],
        conv_w=p[f"{prefix}conv_w"],
        conv_b=p[f"{prefix}conv_b"],
        a_log=p[f"{prefix}a_log"],
        d_skip=p[f"{prefix}d_skip"],
        dt_bias=p[f"{prefix}dt_bias"],
        out_norm=p[f"{prefix}out_norm"],
        out_proj=p[f"{prefix}out_proj"],
    )


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    din = cfg.ssm_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    z, xbc, dt = jnp.split(zxbcdt, [din, din + din + 2 * gn], axis=-1)
    return z, xbc, dt  # z (…,din), xbc (…, din+2GN), dt (…, H)


def _causal_conv_train(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq.  xbc (B, L, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):  # K is 4; unrolled shifts beat conv layout shuffles on TPU
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _segsum_decay(a_chunk: jax.Array) -> jax.Array:
    """a (B, C, Q, H) log-decays -> L (B, C, H, Q, Q) with
    L[q, s] = exp(sum_{i=s+1..q} a_i) for q >= s else 0."""
    q = a_chunk.shape[2]
    cum = jnp.cumsum(a_chunk, axis=2)  # (B, C, Q, H)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,C,Q,S,H): sum_{s+1..q}
    mask = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    return jnp.exp(diff).transpose(0, 1, 4, 2, 3)  # (B, C, H, Q, S)


def ssd_scan(
    cfg: ModelConfig,
    x: jax.Array,  # (B, L, H, P) inputs (already dt-unscaled)
    dt: jax.Array,  # (B, L, H) positive step sizes
    a: jax.Array,  # (H,) negative decay rates (-exp(a_log))
    bmat: jax.Array,  # (B, L, G, N)
    cmat: jax.Array,  # (B, L, G, N)
    h0: jax.Array | None = None,  # (B, H, P, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    bsz, l_orig, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    q = min(cfg.ssm_chunk, l_orig)
    # Pad the sequence to a chunk multiple.  Padded steps use dt = 0, i.e.
    # identity decay and zero input -- they change neither outputs nor the
    # final state (property-tested).
    pad = (-l_orig) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l = l_orig + pad
    c = l // q
    rep = h // g

    xr = x.reshape(bsz, c, q, h, p)
    dtr = dt.reshape(bsz, c, q, h)
    br = jnp.repeat(bmat.reshape(bsz, c, q, g, n), rep, axis=3)  # (B,C,Q,H,N)
    cr = jnp.repeat(cmat.reshape(bsz, c, q, g, n), rep, axis=3)

    a_steps = dtr * a  # (B, C, Q, H) log-decay per step
    dtx = xr * dtr[..., None]  # (B, C, Q, H, P)

    # --- within-chunk (quadratic, attention-dual) ---
    lmask = _segsum_decay(a_steps)  # (B, C, H, Q, S)
    cb = jnp.einsum("bcqhn,bcshn->bchqs", cr, br, preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", cb * lmask, dtx.astype(jnp.float32))

    # --- chunk states ---
    cum = jnp.cumsum(a_steps, axis=2)  # (B, C, Q, H)
    total = cum[:, :, -1:, :]  # (B, C, 1, H)
    decay_to_end = jnp.exp(total - cum)  # (B, C, Q, H) decay from step q to chunk end
    states = jnp.einsum(
        "bcqhn,bcqh,bcqhp->bchpn", br.astype(jnp.float32), decay_to_end, dtx.astype(jnp.float32)
    )  # (B, C, H, P, N)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(total[:, :, 0, :])  # (B, C, H)

    def step(hprev, inputs):
        st, dec = inputs  # (B,H,P,N), (B,H)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev

    init = h0.astype(jnp.float32) if h0 is not None else jnp.zeros((bsz, h, p, n), jnp.float32)
    hfinal, hprevs = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)  # (B, C, H, P, N) state entering each chunk

    # --- off-chunk contribution ---
    in_decay = jnp.exp(cum)  # (B, C, Q, H) decay from chunk start to step q
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", cr.astype(jnp.float32), in_decay, hprevs)

    y = (y_diag + y_off).reshape(bsz, l, h, p)[:, :l_orig]
    return y.astype(x.dtype), hfinal


def ssm_block_train(
    sp: SsmParams, x: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Full-sequence Mamba2 block.  x (B, L, d) -> residual delta."""
    bsz, l, d = x.shape
    h, p, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    xn = rmsnorm(x, sp.ln, cfg.norm_eps)
    zxbcdt = constrain(xn @ sp.in_proj, None, None, "model")
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc = _causal_conv_train(xbc, sp.conv_w, sp.conv_b)
    xs, bmat, cmat = jnp.split(xbc, [cfg.ssm_inner, cfg.ssm_inner + g * n], axis=-1)
    xs = xs.reshape(bsz, l, h, p)
    bmat = bmat.reshape(bsz, l, g, n)
    cmat = cmat.reshape(bsz, l, g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + sp.dt_bias)  # (B, L, H)
    a = -jnp.exp(sp.a_log.astype(jnp.float32))  # (H,)
    y, _ = ssd_scan(cfg, xs, dtv, a, bmat, cmat)
    y = y + xs * sp.d_skip[None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, l, cfg.ssm_inner)
    y = y * jax.nn.silu(z)  # gated output
    y = rmsnorm(y, sp.out_norm, cfg.norm_eps)
    return constrain(y @ sp.out_proj, None, None, None)


class SsmCache(NamedTuple):
    conv: jax.Array  # (B, K-1, conv_channels) rolling conv inputs
    state: jax.Array  # (B, H, P, N) SSD recurrent state


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SsmCache:
    return SsmCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_conv_channels), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
    )


def ssm_block_decode(
    sp: SsmParams, x: jax.Array, cfg: ModelConfig, cache: SsmCache
) -> tuple[jax.Array, SsmCache]:
    """One-token recurrent update.  x (B, 1, d) -> (delta, cache)."""
    bsz = x.shape[0]
    h, p, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    xn = rmsnorm(x[:, 0, :], sp.ln, cfg.norm_eps)  # (B, d)
    zxbcdt = xn @ sp.in_proj
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)

    # rolling causal conv
    window = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, sp.conv_w) + sp.conv_b
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]

    xs, bmat, cmat = jnp.split(xbc, [cfg.ssm_inner, cfg.ssm_inner + g * n], axis=-1)
    xs = xs.reshape(bsz, h, p)
    bmat = jnp.repeat(bmat.reshape(bsz, g, n), h // g, axis=1)  # (B, H, N)
    cmat = jnp.repeat(cmat.reshape(bsz, g, n), h // g, axis=1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + sp.dt_bias)  # (B, H)
    a = -jnp.exp(sp.a_log.astype(jnp.float32))
    decay = jnp.exp(dtv * a)  # (B, H)

    dbx = jnp.einsum("bh,bhp,bhn->bhpn", dtv, xs.astype(jnp.float32), bmat.astype(jnp.float32))
    new_state = cache.state * decay[:, :, None, None] + dbx
    y = jnp.einsum("bhpn,bhn->bhp", new_state, cmat.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * sp.d_skip[None, :, None].astype(jnp.float32)
    y = y.reshape(bsz, cfg.ssm_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, sp.out_norm, cfg.norm_eps)
    delta = (y @ sp.out_proj)[:, None, :]
    return delta, SsmCache(conv=new_conv, state=new_state)
