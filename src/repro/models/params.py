"""Parameter declaration: one table of (shape, logical shard axes, init kind)
per architecture family.

Params are a FLAT dict ``{name: array}``.  Block-stacked params carry a
leading ``n_blocks`` dim and the prefix ``blocks/`` (scanned over in
models/model.py); encoder blocks use ``enc_blocks/``.  The same table yields
``init_params`` (materialized arrays, smoke tests), ``param_shapes``
(ShapeDtypeStructs, dry-run) and ``param_pspecs`` (PartitionSpecs, mesh
placement) -- a single source of truth.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.rules import spec_with_fallback


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]  # logical shard axes, len == len(shape)
    init: str  # normal | fan_in | zeros | ones | a_log | dt_bias


def _attn_defs(cfg: ModelConfig, lead: tuple[int, ...], prefix: str) -> dict[str, ParamDef]:
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    defs = {
        f"{prefix}ln": ParamDef(lead + (d,), (None,) * len(lead) + (None,), "ones"),
        f"{prefix}wq": ParamDef(lead + (d, q), (None,) * len(lead) + (None, "model"), "fan_in"),
        f"{prefix}wk": ParamDef(lead + (d, kv), (None,) * len(lead) + (None, "model"), "fan_in"),
        f"{prefix}wv": ParamDef(lead + (d, kv), (None,) * len(lead) + (None, "model"), "fan_in"),
        f"{prefix}wo": ParamDef(lead + (q, d), (None,) * len(lead) + ("model", None), "fan_in"),
    }
    if cfg.qkv_bias:
        defs |= {
            f"{prefix}bq": ParamDef(lead + (q,), (None,) * len(lead) + ("model",), "zeros"),
            f"{prefix}bk": ParamDef(lead + (kv,), (None,) * len(lead) + ("model",), "zeros"),
            f"{prefix}bv": ParamDef(lead + (kv,), (None,) * len(lead) + ("model",), "zeros"),
        }
    return defs


def _mlp_defs(cfg: ModelConfig, lead: tuple[int, ...], prefix: str) -> dict[str, ParamDef]:
    d, ff = cfg.d_model, cfg.d_ff
    nl = len(lead)
    if cfg.is_moe_mlp:
        e = cfg.n_experts
        defs = {
            f"{prefix}ln": ParamDef(lead + (d,), (None,) * nl + (None,), "ones"),
            f"{prefix}router": ParamDef(lead + (d, e), (None,) * nl + (None, None), "fan_in"),
            f"{prefix}we_gate": ParamDef(lead + (e, d, ff), (None,) * nl + ("model", None, None), "fan_in"),
            f"{prefix}we_up": ParamDef(lead + (e, d, ff), (None,) * nl + ("model", None, None), "fan_in"),
            f"{prefix}we_down": ParamDef(lead + (e, ff, d), (None,) * nl + ("model", None, None), "fan_in"),
        }
        if cfg.n_shared_experts:
            sf = ff * cfg.n_shared_experts
            defs |= {
                f"{prefix}ws_gate": ParamDef(lead + (d, sf), (None,) * nl + (None, "model"), "fan_in"),
                f"{prefix}ws_up": ParamDef(lead + (d, sf), (None,) * nl + (None, "model"), "fan_in"),
                f"{prefix}ws_down": ParamDef(lead + (sf, d), (None,) * nl + ("model", None), "fan_in"),
            }
        return defs
    return {
        f"{prefix}ln": ParamDef(lead + (d,), (None,) * nl + (None,), "ones"),
        f"{prefix}w_gate": ParamDef(lead + (d, ff), (None,) * nl + (None, "model"), "fan_in"),
        f"{prefix}w_up": ParamDef(lead + (d, ff), (None,) * nl + (None, "model"), "fan_in"),
        f"{prefix}w_down": ParamDef(lead + (ff, d), (None,) * nl + ("model", None), "fan_in"),
    }


def _ssm_defs(cfg: ModelConfig, lead: tuple[int, ...], prefix: str) -> dict[str, ParamDef]:
    d = cfg.d_model
    nl = len(lead)
    return {
        f"{prefix}ln": ParamDef(lead + (d,), (None,) * nl + (None,), "ones"),
        f"{prefix}in_proj": ParamDef(
            lead + (d, cfg.ssm_in_proj_dim), (None,) * nl + (None, "model"), "fan_in"
        ),
        f"{prefix}conv_w": ParamDef(
            lead + (cfg.ssm_conv, cfg.ssm_conv_channels), (None,) * nl + (None, "model"), "fan_in"
        ),
        f"{prefix}conv_b": ParamDef(
            lead + (cfg.ssm_conv_channels,), (None,) * nl + ("model",), "zeros"
        ),
        f"{prefix}a_log": ParamDef(lead + (cfg.ssm_heads,), (None,) * nl + ("model",), "a_log"),
        f"{prefix}d_skip": ParamDef(lead + (cfg.ssm_heads,), (None,) * nl + ("model",), "ones"),
        f"{prefix}dt_bias": ParamDef(lead + (cfg.ssm_heads,), (None,) * nl + ("model",), "dt_bias"),
        f"{prefix}out_norm": ParamDef(lead + (cfg.ssm_inner,), (None,) * nl + ("model",), "ones"),
        f"{prefix}out_proj": ParamDef(
            lead + (cfg.ssm_inner, d), (None,) * nl + ("model", None), "fan_in"
        ),
    }


def param_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    d, v = cfg.d_model, cfg.vocab_size
    nb = cfg.n_blocks
    defs: dict[str, ParamDef] = {
        "embed": ParamDef((v, d), ("model", None), "normal"),
        "final_norm": ParamDef((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), (None, "model"), "fan_in")

    lead = (nb,)
    if cfg.arch_type == "ssm":
        defs |= _ssm_defs(cfg, lead, "blocks/")
    elif cfg.arch_type == "hybrid":
        # Super-block = 1 attention layer + (attn_every - 1) mamba layers,
        # every layer followed by the (MoE) MLP.
        n_ssm = cfg.attn_every - 1
        defs |= _attn_defs(cfg, lead, "blocks/attn.")
        defs |= _ssm_defs(cfg, lead + (n_ssm,), "blocks/ssm.")
        defs |= _mlp_defs(cfg, lead + (cfg.attn_every,), "blocks/mlp.")
    elif cfg.arch_type == "encdec":
        defs |= _attn_defs(cfg, lead, "blocks/self.")
        defs |= _attn_defs(cfg, lead, "blocks/cross.")
        defs |= _mlp_defs(cfg, lead, "blocks/mlp.")
        enc_lead = (cfg.n_enc_layers,)
        defs |= _attn_defs(cfg, enc_lead, "enc_blocks/attn.")
        defs |= _mlp_defs(cfg, enc_lead, "enc_blocks/mlp.")
        defs["enc_norm"] = ParamDef((d,), (None,), "ones")
        defs["enc_pos"] = ParamDef((cfg.enc_seq, d), (None, None), "normal")
        defs["dec_pos"] = ParamDef((cfg.dec_pos_len, d), (None, None), "normal")
    else:  # dense | moe | vlm
        defs |= _attn_defs(cfg, lead, "blocks/attn.")
        defs |= _mlp_defs(cfg, lead, "blocks/mlp.")
    return defs


# -- materialization ----------------------------------------------------------


def _init_leaf(key: jax.Array, pd: ParamDef, dtype) -> jax.Array:
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dtype)
    if pd.init == "normal":
        return (0.02 * jax.random.normal(key, pd.shape)).astype(dtype)
    if pd.init == "fan_in":
        fan_in = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (scale * jax.random.normal(key, pd.shape)).astype(dtype)
    if pd.init == "a_log":
        # A in [1, 16] as in Mamba2; stored as log(A), used as -exp(a_log).
        u = jax.random.uniform(key, pd.shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(dtype)
    if pd.init == "dt_bias":
        # dt in [1e-3, 1e-1] through softplus-inverse.
        u = jax.random.uniform(key, pd.shape, minval=1e-3, maxval=1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    raise ValueError(pd.init)


def init_params(key: jax.Array, cfg: ModelConfig) -> dict[str, jax.Array]:
    dtype = jnp.dtype(cfg.dtype)
    defs = param_defs(cfg)
    keys = jax.random.split(key, len(defs))
    return {name: _init_leaf(k, pd, dtype) for (name, pd), k in zip(sorted(defs.items()), keys)}


def param_shapes(cfg: ModelConfig) -> dict[str, jax.ShapeDtypeStruct]:
    dtype = jnp.dtype(cfg.dtype)
    return {n: jax.ShapeDtypeStruct(pd.shape, dtype) for n, pd in param_defs(cfg).items()}


def param_pspecs(cfg: ModelConfig, mesh) -> dict:
    return {
        n: spec_with_fallback(mesh, pd.shape, pd.axes) for n, pd in param_defs(cfg).items()
    }


def count_params(cfg: ModelConfig) -> int:
    return sum(math.prod(pd.shape) for pd in param_defs(cfg).values())
