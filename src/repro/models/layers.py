"""Transformer layer primitives: RMSNorm, RoPE / M-RoPE, GQA attention
(training, prefill and cached decode), gated MLPs and the MoE layer.

Conventions
-----------
* activations default to the config dtype (bf16); norms, softmax and router
  math run in float32.
* attention params are stored flat ``(d, H*hd)`` so the tensor-parallel shard
  axis is always divisible (DESIGN.md Sec. 6); heads are reshaped inside.
* ``window > 0`` applies a local (sliding/chunked) attention mask -- the
  sub-quadratic mode used by llama4-style chunked attention and jamba's
  attention layers in long-context serving.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.rules import constrain


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., head_dim//2)."""
    half = head_dim // 2
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * inv_freq


def _mrope_angles(
    positions3: jax.Array, head_dim: int, theta: float, sections: tuple[int, ...]
) -> jax.Array:
    """Qwen2-VL M-RoPE: positions3 (..., 3) t/h/w -> angles (..., half).

    The half-dim frequency slots are split into `sections` (t, h, w); each
    slot rotates by the position component of its section [arXiv:2409.12191].
    """
    half = head_dim // 2
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sec_ids = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )
    assert sec_ids.shape[0] == half, (sections, half)
    pos_per_slot = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_ids, positions3.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # (..., half)
    return pos_per_slot * inv_freq


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    mode: str = "standard",
    sections: tuple[int, ...] = (16, 24, 24),
) -> jax.Array:
    """x (B, L, H, hd); positions (B, L) or (B, L, 3) for mrope."""
    if mode == "none":
        return x
    hd = x.shape[-1]
    if mode == "mrope":
        ang = _mrope_angles(positions, hd, theta, sections)  # (B, L, half)
    else:
        ang = _rope_angles(positions, hd, theta)  # (B, L, half)
    cos = jnp.cos(ang)[..., None, :]  # (B, L, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, l, _ = x.shape
    return x.reshape(b, l, n_heads, -1)


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by group replication."""
    kv = k.shape[2]
    rep = n_heads // kv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _attn_mask(
    q_len: int,
    kv_len: int,
    *,
    causal: bool,
    window: int,
    q_offset: jax.Array | int = 0,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Boolean (q_len, kv_len) (or broadcastable) attention mask."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    ki = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    return mask


def attention_chunked(
    q: jax.Array,  # (B, L, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,
    *,
    causal: bool,
    window: int,
    chunk: int,
) -> jax.Array:
    """Query-chunked attention: scan over L/chunk query blocks so the f32
    score matrix is only (B, H, chunk, S) at a time.  At 32k x 32k this cuts
    the attention temp from O(L*S) to O(chunk*S) -- measured 120-320 GB ->
    a few GB on the prefill_32k shapes (EXPERIMENTS.md §Perf it.2).
    Semantics identical to attention_core with a causal/window mask.
    """
    b, l, h, hd = q.shape
    s = k.shape[1]
    assert l % chunk == 0, (l, chunk)
    kr = _repeat_kv(k, h)
    vr = _repeat_kv(v, h)
    qc = q.reshape(b, l // chunk, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    ki = jnp.arange(s)

    def body(_, inputs):
        qb, off = inputs  # (B, chunk, H, hd), ()
        scores = jnp.einsum("blhd,bshd->bhls", qb, kr, preferred_element_type=jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(hd))
        qi = jnp.arange(chunk)[:, None] + off
        m = jnp.ones((chunk, s), bool)
        if causal:
            m &= ki[None, :] <= qi
        if window > 0:
            m &= ki[None, :] > qi - window
        scores = jnp.where(m, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhls,bshd->blhd", probs.astype(qb.dtype), vr)
        return None, out

    offs = jnp.arange(l // chunk) * chunk
    _, outs = jax.lax.scan(body, None, (qc, offs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, l, h, hd)


def attention_core(
    q: jax.Array,  # (B, Lq, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    mask: jax.Array,  # broadcastable to (B, H, Lq, S)
    softcap: float = 0.0,
) -> jax.Array:
    h = q.shape[2]
    hd = q.shape[3]
    kr = _repeat_kv(k, h)
    vr = _repeat_kv(v, h)
    scores = jnp.einsum("blhd,bshd->bhls", q, kr, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhls,bshd->blhd", probs.astype(q.dtype), vr)
    return out


class AttnParams(NamedTuple):
    ln: jax.Array
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None


def pick_attn(p: dict, prefix: str) -> AttnParams:
    return AttnParams(
        ln=p[f"{prefix}ln"],
        wq=p[f"{prefix}wq"],
        wk=p[f"{prefix}wk"],
        wv=p[f"{prefix}wv"],
        wo=p[f"{prefix}wo"],
        bq=p.get(f"{prefix}bq"),
        bk=p.get(f"{prefix}bk"),
        bv=p.get(f"{prefix}bv"),
    )


def _project_qkv(ap: AttnParams, x: jax.Array, cfg: ModelConfig, tp_constrain: bool = True):
    xn = rmsnorm(x, ap.ln, cfg.norm_eps)
    q = xn @ ap.wq
    k = xn @ ap.wk
    v = xn @ ap.wv
    if ap.bq is not None:
        q = q + ap.bq
        k = k + ap.bk
        v = v + ap.bv
    if tp_constrain:
        # tensor-parallel heads: right for full-sequence compute.  Decode
        # passes tp_constrain=False: head-sharding a 1-token q forces GSPMD
        # to all-gather the sequence-sharded KV cache every layer (measured
        # ~200 GB/token on scout decode_32k -- EXPERIMENTS.md §Perf it.4b);
        # leaving q unconstrained keeps attention sequence-parallel with
        # psum-combined softmax partials instead.
        q = constrain(q, None, None, "model")
        k = constrain(k, None, None, "model")
        v = constrain(v, None, None, "model")
    return (
        _split_heads(q, cfg.n_heads),
        _split_heads(k, cfg.n_kv_heads),
        _split_heads(v, cfg.n_kv_heads),
    )


def attn_block(
    ap: AttnParams,
    x: jax.Array,  # (B, L, d) residual stream
    cfg: ModelConfig,
    positions: jax.Array,  # (B, L) or (B, L, 3)
    *,
    causal: bool = True,
    window: int = 0,
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,
    chunk: int = 0,
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder).  Returns the
    residual delta (caller adds).  ``chunk > 0`` enables query-chunked
    attention when the sequence is long enough to benefit."""
    q, k, v = _project_qkv(ap, x, cfg)
    if cross_kv is not None:
        k, v = cross_kv  # encoder-side keys/values (already headed)
        mask = jnp.ones((q.shape[1], k.shape[1]), bool)
        out = attention_core(q, k, v, mask)
    else:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_mode, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_mode, cfg.mrope_sections)
        if chunk > 0 and q.shape[1] % chunk == 0 and q.shape[1] >= 2 * chunk:
            out = attention_chunked(q, k, v, causal=causal, window=window, chunk=chunk)
        else:
            mask = _attn_mask(q.shape[1], k.shape[1], causal=causal, window=window)
            out = attention_core(q, k, v, mask)
    out = out.reshape(out.shape[0], out.shape[1], -1)
    return constrain(out @ ap.wo, None, None, None)


def attn_decode(
    ap: AttnParams,
    x: jax.Array,  # (B, 1, d)
    cfg: ModelConfig,
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,
    pos: jax.Array,  # () int32 current position
    *,
    window: int = 0,
    cross: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token cached attention.  Returns (delta, k_cache, v_cache)."""
    q, k, v = _project_qkv(ap, x, cfg, tp_constrain=False)
    if cross:
        # cross-attention: cache holds encoder K/V; nothing to update
        mask = jnp.ones((1, k_cache.shape[1]), bool)
    else:
        posb = jnp.broadcast_to(pos[None], (x.shape[0], 1))
        if cfg.rope_mode == "mrope":
            posb = jnp.broadcast_to(pos[None, None], (x.shape[0], 1, 3))
        q = apply_rope(q, posb, cfg.rope_theta, cfg.rope_mode, cfg.mrope_sections)
        k = apply_rope(k, posb, cfg.rope_theta, cfg.rope_mode, cfg.mrope_sections)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
        s = k_cache.shape[1]
        ki = jnp.arange(s)
        mask = (ki <= pos)
        if window > 0:
            mask &= ki > pos - window
        mask = mask[None, :]
    out = attention_core(q, k_cache, v_cache, mask)
    out = out.reshape(out.shape[0], 1, -1)
    return out @ ap.wo, k_cache, v_cache


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "swiglu":
        return jax.nn.silu(x)
    if name == "geglu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def mlp_block(p: dict, prefix: str, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Gated MLP (swiglu / geglu).  Returns residual delta."""
    xn = rmsnorm(x, p[f"{prefix}ln"], cfg.norm_eps)
    gate = constrain(xn @ p[f"{prefix}w_gate"], None, None, "model")
    up = constrain(xn @ p[f"{prefix}w_up"], None, None, "model")
    h = _act(cfg.mlp_act, gate) * up
    return constrain(h @ p[f"{prefix}w_down"], None, None, None)


# ---------------------------------------------------------------------------
# Mixture of Experts (expert-parallel, capacity-based dispatch)
# ---------------------------------------------------------------------------


def moe_block(
    p: dict, prefix: str, x: jax.Array, cfg: ModelConfig, *, return_aux: bool = False
):
    """Top-k MoE with per-expert capacity and scatter dispatch.

    Compute cost is O(T * top_k * capacity_factor) expert-MLP FLOPs (NOT
    O(T * E)): tokens are scattered into an (E, C, d) buffer sharded
    expert-parallel over 'model', batched expert GEMMs run, results gather
    back.  GSPMD turns the scatter/gather into the all-to-all pattern of
    expert parallelism.  Overflowing tokens beyond capacity are dropped
    (Switch-style); the shared experts (llama4) run densely.
    """
    b, l, d = x.shape
    xn = rmsnorm(x, p[f"{prefix}ln"], cfg.norm_eps)
    t = b * l
    k = cfg.moe_top_k
    e = cfg.n_experts
    xt = xn.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p[f"{prefix}router"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # flatten the k slots
    slot_expert = expert_idx.reshape(-1)  # (T*k,)
    slot_gate = gate_vals.reshape(-1)
    slot_src = jnp.repeat(jnp.arange(t), k)

    capacity = int(max(cfg.moe_capacity_factor * t * k / e, 4))
    capacity = min(capacity + (-capacity) % 4, t * k)

    onehot = jax.nn.one_hot(slot_expert, e, dtype=jnp.int32)  # (T*k, E)
    rank = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(t * k), slot_expert]  # (T*k,)
    keep = rank < capacity
    rank_c = jnp.where(keep, rank, 0)

    # dispatch: (E, C, d)
    buf = jnp.zeros((e, capacity, d), xt.dtype)
    contrib = jnp.where(keep[:, None], xt[slot_src], 0)
    buf = buf.at[slot_expert, rank_c].add(contrib)
    buf = constrain(buf, "model", None, None)

    # batched expert GEMMs (E sharded over 'model' -> expert parallelism)
    g = _act(cfg.mlp_act, jnp.einsum("ecd,edf->ecf", buf, p[f"{prefix}we_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p[f"{prefix}we_up"])
    h = constrain(g * u, "model", None, None)
    out_e = jnp.einsum("ecf,efd->ecd", h, p[f"{prefix}we_down"])  # (E, C, d)

    # combine
    y_slots = out_e[slot_expert, rank_c] * jnp.where(keep, slot_gate, 0.0)[:, None].astype(xt.dtype)
    y = jnp.zeros((t, d), xt.dtype).at[slot_src].add(y_slots)

    # shared (dense) experts -- llama4-style
    if cfg.n_shared_experts:
        sg = _act(cfg.mlp_act, xt @ p[f"{prefix}ws_gate"])
        su = xt @ p[f"{prefix}ws_up"]
        y = y + (sg * su) @ p[f"{prefix}ws_down"]

    y = y.reshape(b, l, d)
    if not return_aux:
        return y
    # Switch-style load-balance auxiliary loss.
    frac_tokens = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y, aux
