"""Sharding policy and logical-axis rules.

Parameters declare *logical* shard axes (e.g. ``(None, "model")``) next to
their shapes in ``models/params.py`` -- a single source of truth.  The rules
here turn them into concrete ``PartitionSpec``s with a divisibility fallback
(a dim that does not divide the mesh axis is replicated instead, and the
fallback is recorded so EXPERIMENTS.md can report it).

Activation constraints go through :func:`constrain`, which is a no-op unless
a mesh has been installed via :func:`set_mesh` -- so the exact same model code
runs in single-device CPU smoke tests and under the 512-device dry-run.

``ShardingPolicy`` carries the performance knobs that the §Perf hillclimb
flips (sequence-parallel residuals, ZeRO-1 optimizer sharding, remat).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Performance-relevant distribution knobs (hillclimb levers)."""

    seq_parallel: bool = True  # residual stream seq-sharded over 'model' between blocks
    shard_heads: bool = True  # attention projections column-sharded over 'model'
    zero1: bool = True  # optimizer moments additionally sharded over 'data'
    remat: bool = True  # activation checkpointing on the layer scan
    fsdp: bool = True  # shard params (and moments) over 'data' too (ZeRO-3-style)
    attn_chunk: int = 2048  # query-chunked attention for long sequences (0 = off)
    donate: bool = True  # donate train state / decode cache buffers (aliasing)
    cache_seq_axis: Optional[str] = "model"  # decode KV-cache sequence shard axis
    scan_unroll: bool = False  # fully unroll layer scans (dry-run cost accounting)
    batch_axes: tuple[str, ...] = ("data",)  # expanded to ("pod","data") multi-pod


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


class mesh_context:
    """``with mesh_context(mesh): ...`` installs the mesh for constrain()."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        self.prev = get_mesh()
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)
        return False


def _prune_absent(mesh: Mesh, axis):
    """Drop axis names the mesh does not define from a logical axis entry,
    so logical specs naming 'model' degrade to replicated on data-only
    meshes (the host mesh train.py/fedzoo.py build on CPU).  A tuple entry
    keeps only its present names -- emitting an absent name inside a
    PartitionSpec would fail at NamedSharding placement."""
    if axis is None or not isinstance(axis, (tuple, list)):
        return axis if (axis is None or axis in mesh.axis_names) else None
    kept = tuple(a for a in axis if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def _axis_size(mesh: Mesh, axis) -> int:
    """Product of mesh-axis sizes; absent axis names count as size 1."""
    axis = _prune_absent(mesh, axis)
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= _axis_size(mesh, a)
        return n
    return mesh.shape[axis]


def spec_with_fallback(mesh: Mesh, shape: tuple[int, ...], axes: tuple[Any, ...]) -> P:
    """Logical axes -> PartitionSpec, replicating any non-divisible dim."""
    out = []
    for dim, ax in zip(shape, axes):
        ax = _prune_absent(mesh, ax)
        if ax is None:
            out.append(None)
            continue
        n = _axis_size(mesh, ax)
        out.append(ax if (n > 1 and dim % n == 0) else None)
    return P(*out)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the installed mesh (no-op without).

    IMPORTANT semantics: a ``None`` entry here means UNCONSTRAINED (leave the
    dim to GSPMD propagation), NOT replicated.  Pinning activations to
    replicated on the batch dim was measured to cost 80 GB/device of
    all-gathered attention temporaries on qwen1.5 train_4k (EXPERIMENTS.md
    §Perf).  Input/param shardings (spec_with_fallback) keep None=replicated.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    unc = P.UNCONSTRAINED
    full = tuple(axes) + (None,) * (x.ndim - len(axes))
    out = []
    for dim, ax in zip(x.shape, full):
        ax = _prune_absent(mesh, ax)
        if ax is None:
            out.append(unc)
            continue
        n = _axis_size(mesh, ax)
        out.append(ax if (n > 1 and dim % n == 0) else unc)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*out)))


def batch_axes(policy: ShardingPolicy, mesh: Optional[Mesh] = None) -> tuple[str, ...]:
    """Client/batch data axes; includes 'pod' when the mesh has one."""
    mesh = mesh or get_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod",) + tuple(policy.batch_axes)
    return tuple(policy.batch_axes)


def param_pspecs_from_axes(mesh: Mesh, shape: tuple[int, ...], axes: tuple[Any, ...]) -> P:
    """Single-leaf convenience alias of :func:`spec_with_fallback`."""
    return spec_with_fallback(mesh, shape, axes)


def zero1_extend(mesh: Mesh, shape: tuple[int, ...], spec: P, data_axes: tuple[str, ...] = ("data",)) -> P:
    """ZeRO-1: extend a param spec with a 'data' shard on the largest
    still-replicated divisible dim.  Applied to optimizer moments so the
    Adam state never replicates across the data axis (DESIGN.md Sec. 6).
    """
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    st = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    free = [
        (dim, i)
        for i, (dim, s) in enumerate(zip(shape, st))
        if s is None and n_data > 1 and dim % n_data == 0 and dim >= n_data
    ]
    if not free:
        return P(*st)
    _, idx = max(free)
    new = list(st)
    new[idx] = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(*new)
