from repro.sharding.rules import (  # noqa: F401
    ShardingPolicy,
    batch_axes,
    constrain,
    get_mesh,
    mesh_context,
    param_pspecs_from_axes,
    set_mesh,
    spec_with_fallback,
    zero1_extend,
)
