"""Recursive jaxpr linter (DESIGN.md Sec. 7).

Walks a (closed) jaxpr through every sub-jaxpr -- scan/cond/while bodies,
pjit calls, shard_map bodies, custom_jvp/vjp call jaxprs -- and checks
structural contracts that executing the program cannot reveal cheaply:

  * **forbidden primitives** (``find_forbidden``): e.g. no ``eigh`` in the
    scanned deferred-repair round body (the PR 3 acceptance criterion);
  * **host ops** (``find_host_ops``): callbacks and host transfers have no
    business inside a scanned round body -- any of them turns the
    zero-sync chunk into a per-iteration host round-trip;
  * **carry promotions** (``find_carry_promotions``): a
    ``convert_element_type`` that WIDENS a scan carry leaf is the
    structural signature of the PR 4 bf16->f32 optimizer bug class (the
    promoted value flows back into the carry, so the param's precision
    silently changes after step 1);
  * **i/o dtype preservation** (``check_io_dtypes``): paired input/output
    leaves (param in, updated param out) must keep their dtype;
  * **collective census** (``psum_census``): count ``psum`` equations by
    payload shape.  The paper's communication claim is per-round payload
    ``d + M`` floats; the census pins the number of array-payload psums
    (iterate + RFF weights) and scalar-payload psums (stats + eval pmean;
    ``lax.pmean`` lowers to a psum at jaxpr level) so a new collective
    cannot slip into the round body unnoticed.

Every violation carries the jaxpr source location of the offending
equation (``jax``'s own traceback summary), so ``python -m repro.analysis``
reports point at repo code, not at lowered soup.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Iterator, Optional, Sequence

import jax.numpy as jnp
from jax import core as jcore

try:  # jaxpr source locations (jax internal, but stable across 0.4.x)
    from jax._src import source_info_util as _src_info
except Exception:  # pragma: no cover - degrade to location-less reports
    _src_info = None

#: Callback primitives: every one of these re-enters Python from inside the
#: compiled program (and serializes the dispatch pipeline).
HOST_CALLBACK_PRIMITIVES = frozenset({
    "pure_callback",
    "io_callback",
    "debug_callback",
    "outside_call",  # legacy host_callback
})

#: Placement/transfer primitives that pin or move buffers mid-program.
TRANSFER_PRIMITIVES = frozenset({"device_put", "copy"})

#: Names the eigendecomposition lowers to at jaxpr level.
EIGH_PRIMITIVES = frozenset({"eigh"})


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract violation, locatable in repo source."""

    rule: str  # e.g. "no-eigh", "carry-promotion"
    message: str
    source: str = "<unknown>"  # jaxpr source location of the equation
    path: tuple[str, ...] = ()  # primitive path, e.g. ("scan", "cond")

    def __str__(self) -> str:
        ctx = "/".join(self.path) or "<top>"
        return f"[{self.rule}] {self.message}  (in {ctx}; at {self.source})"


def source_of(eqn) -> str:
    """Best-effort source location of a jaxpr equation."""
    if _src_info is None or eqn.source_info is None:
        return "<unknown>"
    try:
        return _src_info.summarize(eqn.source_info)
    except Exception:  # pragma: no cover
        return "<unknown>"


def _as_jaxpr(obj) -> Optional[jcore.Jaxpr]:
    if isinstance(obj, jcore.ClosedJaxpr):
        return obj.jaxpr
    if isinstance(obj, jcore.Jaxpr):
        return obj
    return None


def subjaxprs(eqn) -> Iterator[jcore.Jaxpr]:
    """All sub-jaxprs reachable from one equation's params."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            j = _as_jaxpr(v)
            if j is not None:
                yield j


def iter_eqns(jaxpr, path: tuple[str, ...] = ()):
    """Yield ``(eqn, path)`` for every equation, recursing into sub-jaxprs.

    ``path`` is the chain of enclosing primitives, e.g. ``("scan", "cond")``
    for an equation inside a cond branch inside a scanned body.
    """
    j = _as_jaxpr(jaxpr)
    if j is None:
        raise TypeError(f"expected a (Closed)Jaxpr, got {type(jaxpr)!r}")
    for eqn in j.eqns:
        yield eqn, path
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub, path + (eqn.primitive.name,))


def count_primitives(jaxpr, names: Sequence[str]) -> Counter:
    """Occurrence count of each primitive name, recursively."""
    wanted = frozenset(names)
    c: Counter = Counter()
    for eqn, _ in iter_eqns(jaxpr):
        if eqn.primitive.name in wanted:
            c[eqn.primitive.name] += 1
    return c


def find_forbidden(jaxpr, forbidden: Sequence[str], rule: str = "forbidden-primitive") -> list[Violation]:
    """Every occurrence of a forbidden primitive, with source + context."""
    bad = frozenset(forbidden)
    out = []
    for eqn, path in iter_eqns(jaxpr):
        if eqn.primitive.name in bad:
            out.append(Violation(
                rule=rule,
                message=f"primitive '{eqn.primitive.name}' is forbidden here",
                source=source_of(eqn),
                path=path,
            ))
    return out


def find_host_ops(jaxpr, *, include_transfers: bool = True) -> list[Violation]:
    """Host callbacks (and optionally placement/transfer ops) anywhere in
    the program.  ``include_transfers=False`` permits ``device_put`` for
    programs that legitimately re-place buffers (top-level drivers)."""
    names = set(HOST_CALLBACK_PRIMITIVES)
    if include_transfers:
        names |= TRANSFER_PRIMITIVES
    return find_forbidden(jaxpr, sorted(names), rule="host-op")


def _is_widening_float_convert(in_aval, out_aval) -> bool:
    din, dout = jnp.dtype(in_aval.dtype), jnp.dtype(out_aval.dtype)
    if not (jnp.issubdtype(din, jnp.floating) and jnp.issubdtype(dout, jnp.floating)):
        return False
    return dout.itemsize > din.itemsize


def find_carry_promotions(jaxpr) -> list[Violation]:
    """Widening ``convert_element_type`` applied DIRECTLY to a scan carry
    leaf, in any scan body at any depth.

    This is the structural signature of the PR 4 bug class: ``p - lr * g``
    with an f32 ``lr`` emits ``convert_element_type(p: bf16) -> f32`` on
    the carried param before the arithmetic, and the promoted result flows
    back into the carry -- training silently switches precision after the
    first step.  jax itself enforces carry-in == carry-out dtype, so the
    promotion always appears as this in-body convert, never as a carry
    dtype mismatch.
    """
    out = []
    for eqn, path in iter_eqns(jaxpr):
        if eqn.primitive.name != "scan":
            continue
        body = eqn.params["jaxpr"].jaxpr
        nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
        carry_vars = set(body.invars[nc:nc + ncar])
        for beqn, bpath in iter_eqns(body, path + ("scan",)):
            if beqn.primitive.name != "convert_element_type":
                continue
            (src_var,) = beqn.invars
            if isinstance(src_var, jcore.Var) and src_var in carry_vars \
                    and _is_widening_float_convert(src_var.aval, beqn.outvars[0].aval):
                out.append(Violation(
                    rule="carry-promotion",
                    message=(
                        f"scan carry leaf {src_var.aval.str_short()} widened to "
                        f"{beqn.outvars[0].aval.str_short()} inside the body "
                        "(param-precision drift: the promoted value flows back "
                        "into the carry)"
                    ),
                    source=source_of(beqn),
                    path=bpath,
                ))
    return out


def check_io_dtypes(closed: jcore.ClosedJaxpr, pairs: Sequence[tuple[int, int]]) -> list[Violation]:
    """Paired (input leaf index, output leaf index) must share a dtype.

    Use for param-like leaves of non-scan functions (e.g. optimizer
    updates: params in -> new params out), where there is no scan carry
    for jax to enforce the invariant on.
    """
    j = closed.jaxpr
    out = []
    for i, o in pairs:
        din = jnp.dtype(j.invars[i].aval.dtype)
        dout = jnp.dtype(j.outvars[o].aval.dtype)
        if din != dout:
            out.append(Violation(
                rule="dtype-drift",
                message=(
                    f"input leaf {i} ({din.name}) returns as output leaf {o} "
                    f"({dout.name}); param-like leaves must preserve dtype"
                ),
            ))
    return out


def psum_census(jaxpr) -> dict[str, int]:
    """Collective census at jaxpr level.

    Returns ``{"psum_array": ..., "psum_scalar": ..., <other collectives>}``.
    ``lax.pmean`` is psum + a static divide, so it contributes one psum;
    scalar vs array payload is what the communication claim cares about
    (the array psums ARE the per-round ``d + M``-float payload).
    """
    census = {"psum_array": 0, "psum_scalar": 0}
    others = ("ppermute", "all_gather", "all_to_all", "reduce_scatter", "pgather")
    for eqn, _ in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "psum":
            for v in eqn.invars:
                if getattr(v.aval, "shape", ()) == ():
                    census["psum_scalar"] += 1
                else:
                    census["psum_array"] += 1
        elif name in others:
            census[name] = census.get(name, 0) + 1
    return census


def check_psum_census(jaxpr, expected: dict[str, int]) -> list[Violation]:
    """Census must match EXACTLY (missing expected keys count as 0)."""
    got = psum_census(jaxpr)
    out = []
    keys = set(got) | set(expected)
    for k in sorted(keys):
        g, e = got.get(k, 0), expected.get(k, 0)
        if g != e:
            out.append(Violation(
                rule="collective-census",
                message=f"{k}: expected {e} but the body lowers {g} "
                        "(a collective was added or removed from the round body)",
            ))
    return out


def eigh_only_behind_cond(jaxpr) -> list[Violation]:
    """Every ``eigh`` must sit behind a ``cond`` (rare-event gating): the
    boundary-repair executable may CARRY the repair eigh, but the
    all-healthy steady state must never execute it."""
    out = []
    for eqn, path in iter_eqns(jaxpr):
        if eqn.primitive.name in EIGH_PRIMITIVES and "cond" not in path:
            out.append(Violation(
                rule="eigh-not-gated",
                message="eigh outside any cond branch: the steady state would "
                        "pay the factorization unconditionally",
                source=source_of(eqn),
                path=path,
            ))
    return out
