"""Static analysis of the repo's compiled programs (DESIGN.md Sec. 7).

The round engine's correctness story rests on properties of the COMPILED
program, not just numerics: the deferred-repair body must stay eigh-free,
the distributed body must keep the declared collective census (the paper's
communication claim), optimizer updates must preserve param dtypes (the
PR 4 bf16->f32 bug class), and the buffers `rounds.py` donates must really
be aliased in the executable.  This package turns those one-off test
assertions into declared contracts linted WITHOUT executing anything:

  * ``jaxpr_lint``  -- recursive jaxpr walker: forbidden primitives,
    carry-dtype promotions, host callbacks, collective census;
  * ``hlo_audit``   -- lowered-HLO auditor: backend custom-call
    fingerprints (eigh/syev, cholesky/potrf), collective census,
    input-output aliasing (donation);
  * ``kernel_audit`` -- static Pallas launch verifier over the declarative
    ``kernels.spec.KernelSpec`` geometry: write races, output coverage,
    out-of-bounds index maps, accumulator init/dtype discipline, per-cell
    VMEM budget -- proven by grid enumeration, below the jaxpr, without
    lowering;
  * ``key_flow``    -- PRNG key dataflow lint over entry-point jaxprs:
    a key consumed by two primitives, threaded unsplit through a scan
    carry, or hard-coded (with ``# key-flow: ok`` source suppression);
  * ``contracts``   -- the per-engine contract registry + the steady-state
    recompile/sync guard;
  * ``runner``      -- ``python -m repro.analysis``: lower every registered
    (algorithm, engine-flag) combination and report violations with
    jaxpr source locations (``--json`` for the machine-readable report).
"""

from repro.analysis.jaxpr_lint import Violation  # noqa: F401
from repro.analysis.contracts import (  # noqa: F401
    CONTRACTS,
    SteadyStateViolation,
    check_contract,
    no_recompiles,
    steady_state_guard,
)
from repro.analysis.kernel_audit import (  # noqa: F401
    audit_spec,
    check_geometry,
    check_vmem,
)
from repro.analysis.key_flow import (  # noqa: F401
    KeyFlowReport,
    analyze_key_flow,
    check_key_flow,
)
from repro.analysis.runner import check_all, main  # noqa: F401
