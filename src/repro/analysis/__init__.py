"""Static analysis of the repo's compiled programs (DESIGN.md Sec. 7).

The round engine's correctness story rests on properties of the COMPILED
program, not just numerics: the deferred-repair body must stay eigh-free,
the distributed body must keep the declared collective census (the paper's
communication claim), optimizer updates must preserve param dtypes (the
PR 4 bf16->f32 bug class), and the buffers `rounds.py` donates must really
be aliased in the executable.  This package turns those one-off test
assertions into declared contracts linted WITHOUT executing anything:

  * ``jaxpr_lint``  -- recursive jaxpr walker: forbidden primitives,
    carry-dtype promotions, host callbacks, collective census;
  * ``hlo_audit``   -- lowered-HLO auditor: backend custom-call
    fingerprints (eigh/syev, cholesky/potrf), collective census,
    input-output aliasing (donation);
  * ``contracts``   -- the per-engine contract registry + the steady-state
    recompile/sync guard;
  * ``runner``      -- ``python -m repro.analysis``: lower every registered
    (algorithm, engine-flag) combination and report violations with
    jaxpr source locations.
"""

from repro.analysis.jaxpr_lint import Violation  # noqa: F401
from repro.analysis.contracts import (  # noqa: F401
    CONTRACTS,
    SteadyStateViolation,
    check_contract,
    no_recompiles,
    steady_state_guard,
)
from repro.analysis.runner import check_all, main  # noqa: F401
