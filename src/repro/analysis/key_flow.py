"""PRNG key-flow lint: jaxpr dataflow over key values (DESIGN.md Sec. 7).

Under jax's counter-mode PRNG, *deriving* from a key (``split`` /
``fold_in``) and *sampling* from it (``random_bits``, the primitive every
``jax.random`` sampler bottoms out in) walk the same counter stream: the
keys ``split(k)`` returns are literally the first blocks ``uniform(k, ...)``
would also draw.  A key consumed by two primitives therefore correlates
streams that the algorithm treats as independent -- the bug class that
silently breaks the sim == distributed identity and any seed-replay
protocol built on fold_in discipline.

This module walks a (closed) jaxpr as an abstract interpreter over key
identities:

* producers -- ``random_seed`` (``PRNGKey``), ``random_wrap``,
  ``random_split``, ``random_fold_in`` -- create identity nodes; two
  derivations with the SAME parent and the SAME static parameters (e.g.
  ``fold_in(k, 1)`` twice) collapse to one node, so their consumers are
  correctly seen as consuming one key;
* views -- ``random_unwrap`` / re-``wrap``, ``reshape``, ``squeeze``,
  ``transpose``, ``broadcast_in_dim`` -- alias the node; static ``slice``
  selects a per-parameter child (``ks[:, 0]`` vs ``ks[:, 1]`` are distinct
  keys; the same slice twice is the same key);
* consumers -- ``random_*`` samplers record a *sample* use,
  ``split``/``fold_in`` record a *derive* use;
* control flow -- the walker recurses through ``pjit``/custom-call
  sub-jaxprs with argument binding, through ``cond`` branches and
  ``while`` bodies, and gives ``scan`` special treatment: a carried key
  that is sampled in the body and returned to the carry UNCHANGED is the
  ``key-carry-unsplit`` rule (every iteration re-draws the same stream).

Findings:

* ``key-reuse``         -- a key with >= 2 sample uses, or a sample use
                           plus a later derivation (or vice versa);
* ``key-carry-unsplit`` -- a scan carry key sampled in the body and
                           threaded through unchanged;
* ``key-constant``      -- a sampler whose key has no dataflow from the
                           entry point's inputs (a hard-coded seed baked
                           into the traced program).

Suppression: a finding whose reported source line (or the line above it,
for wrapped statements) carries a ``# key-flow: ok (reason)`` comment is
moved to the report's ``suppressed`` list -- the mechanism the repo uses
to document the audited, intentional exceptions in ``core/algorithms.py``.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import re
from typing import Any, Optional

from jax import core as jcore

from repro.analysis.jaxpr_lint import Violation, source_of

#: Primitives that create or transform key identities.
_SEED = "random_seed"
_WRAP = "random_wrap"
_UNWRAP = "random_unwrap"
_CLONE = "random_clone"
_SPLIT = "random_split"
_FOLD = "random_fold_in"
_SAMPLER_EXEMPT = frozenset({_SEED, _WRAP, _UNWRAP, _CLONE, _SPLIT, _FOLD})

#: Pure element-preserving views: the out value IS the in key (set).
_ALIAS_VIEWS = frozenset({_UNWRAP, _CLONE, "reshape", "squeeze", "transpose",
                          "broadcast_in_dim", "copy", "rev"})

#: Call-like primitives whose single sub-jaxpr binds 1:1 to the eqn invars.
_CALL_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")

_SUPPRESS_RE = re.compile(r"#\s*key-flow:\s*ok\b")
_SRC_RE = re.compile(r"(/?[\w./-]+\.py):(\d+)")


@dataclasses.dataclass
class _Use:
    kind: str  # "sample" | "derive"
    source: str
    path: tuple[str, ...]
    order: int


@dataclasses.dataclass
class _KeyNode:
    nid: int
    origin: str  # source location of the creating equation
    tainted: bool  # has dataflow from the entry point's inputs
    uses: list[_Use] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class KeyFlowReport:
    """Full key-flow analysis result for one entry point."""

    violations: list[Violation]
    suppressed: list[Violation]
    n_keys: int
    n_samples: int


class _Analysis:
    def __init__(self):
        self.nodes: dict[int, _KeyNode] = {}
        self.children: dict[tuple[int, Any], int] = {}
        self.order = itertools.count()
        self.carry_unsplit: list[Violation] = []
        # (call-site source, is_jax_internal) per entered call-like eqn.
        # jax.random samplers trace their `_uniform`-style inner fn ONCE and
        # cache it, so eqn source info inside the sub-jaxpr points at the
        # FIRST trace site ever -- attribute uses inside an internal pjit to
        # the pjit's own call site instead.
        self.call_stack: list[tuple[str, bool]] = []

    def new_node(self, origin: str, tainted: bool) -> int:
        nid = len(self.nodes)
        self.nodes[nid] = _KeyNode(nid, origin, tainted)
        return nid

    def child(self, parent: int, sig: Any, origin: str) -> int:
        key = (parent, sig)
        nid = self.children.get(key)
        if nid is None:
            nid = self.new_node(origin, self.nodes[parent].tainted)
            self.children[key] = nid
        return nid

    def src(self, eqn) -> str:
        """Attribution source: the user-visible call site.  If the walker is
        inside a chain of jax-internal pjits, the site where user code
        entered that chain; otherwise the equation's own source."""
        site = None
        for s, internal in reversed(self.call_stack):
            if not internal:
                break
            site = s
        return site if site is not None else source_of(eqn)

    def use(self, nid: int, kind: str, eqn, path) -> None:
        self.nodes[nid].uses.append(
            _Use(kind, self.src(eqn), path, next(self.order)))

    # -- the walker --------------------------------------------------------

    def walk(self, jaxpr: jcore.Jaxpr, env: dict, taint: dict,
             path: tuple[str, ...]) -> None:
        """``env``: Var -> node id for key-typed values; ``taint``: Var ->
        bool dataflow-from-inputs.  Both are per-jaxpr scopes seeded by the
        caller."""

        def node_of(v) -> Optional[int]:
            return env.get(v) if isinstance(v, jcore.Var) else None

        def taint_of(v) -> bool:
            return bool(taint.get(v)) if isinstance(v, jcore.Var) else False

        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            in_taint = any(taint_of(v) for v in eqn.invars)
            for ov in eqn.outvars:
                taint[ov] = in_taint
            src = self.src(eqn)

            if prim == _SEED:
                env[eqn.outvars[0]] = self.new_node(src, in_taint)
            elif prim == _WRAP:
                raw = eqn.invars[0]
                nid = node_of(raw)
                if nid is None:
                    nid = self.new_node(src, in_taint)
                    if isinstance(raw, jcore.Var):
                        env[raw] = nid  # pass-through detection (scan carry)
                env[eqn.outvars[0]] = nid
            elif prim == _SPLIT:
                nid = node_of(eqn.invars[0])
                if nid is not None:
                    self.use(nid, "derive", eqn, path)
                    sig = ("split", repr(sorted(eqn.params.items())))
                    env[eqn.outvars[0]] = self.child(nid, sig, src)
            elif prim == _FOLD:
                nid = node_of(eqn.invars[0])
                if nid is not None:
                    self.use(nid, "derive", eqn, path)
                    data = eqn.invars[1]
                    if isinstance(data, jcore.Literal):
                        sig = ("fold_in", repr(data.val))
                    else:
                        sig = ("fold_in_dyn", id(eqn))  # traced data: unique
                    child = self.child(nid, sig, src)
                    if taint_of(data):
                        self.nodes[child].tainted = True
                    env[eqn.outvars[0]] = child
            elif prim in _ALIAS_VIEWS:
                nid = node_of(eqn.invars[0])
                if nid is not None:
                    env[eqn.outvars[0]] = nid
            elif prim == "slice":
                nid = node_of(eqn.invars[0])
                if nid is not None:
                    sig = ("slice", repr(sorted(eqn.params.items())))
                    env[eqn.outvars[0]] = self.child(nid, sig, src)
            elif prim in ("dynamic_slice", "gather"):
                nid = node_of(eqn.invars[0])
                if nid is not None:
                    # data-dependent selection: a fresh key per equation
                    env[eqn.outvars[0]] = self.child(nid, (prim, id(eqn)), src)
            elif prim.startswith("random_") and prim not in _SAMPLER_EXEMPT:
                nid = node_of(eqn.invars[0])
                if nid is not None:
                    self.use(nid, "sample", eqn, path)
            elif prim == "scan":
                self._walk_scan(eqn, env, taint, path)
            elif prim == "while":
                self._walk_while(eqn, env, taint, path)
            elif prim == "cond":
                for br in eqn.params["branches"]:
                    self._walk_sub(br.jaxpr, eqn.invars[1:], eqn, env, taint,
                                   path + ("cond",), bind_out=False)
            else:
                sub = next(
                    (eqn.params[k] for k in _CALL_JAXPR_PARAMS
                     if k in eqn.params
                     and isinstance(eqn.params[k],
                                    (jcore.Jaxpr, jcore.ClosedJaxpr))),
                    None,
                )
                if sub is not None:
                    j = sub.jaxpr if isinstance(sub, jcore.ClosedJaxpr) else sub
                    if len(j.invars) == len(eqn.invars):
                        self._walk_sub(j, eqn.invars, eqn, env, taint,
                                       path + (prim,), bind_out=True)

    def _walk_sub(self, body: jcore.Jaxpr, args, eqn, env, taint,
                  path, *, bind_out: bool) -> dict:
        sub_env = {bv: env[av] for bv, av in zip(body.invars, args)
                   if isinstance(av, jcore.Var) and av in env}
        sub_taint = {bv: taint.get(av, False)
                     for bv, av in zip(body.invars, args)
                     if isinstance(av, jcore.Var)}
        internal = (eqn.primitive.name == "pjit"
                    and str(eqn.params.get("name", "")).startswith("_"))
        self.call_stack.append((source_of(eqn), internal))
        try:
            self.walk(body, sub_env, sub_taint, path)
        finally:
            self.call_stack.pop()
        if bind_out:
            for ov, bv in zip(eqn.outvars, body.outvars):
                if isinstance(bv, jcore.Var) and bv in sub_env:
                    env[ov] = sub_env[bv]
                taint[ov] = taint.get(ov, False) or (
                    isinstance(bv, jcore.Var) and sub_taint.get(bv, False))
        return sub_env

    def _walk_while(self, eqn, env, taint, path) -> None:
        ncc = eqn.params["cond_nconsts"]
        nbc = eqn.params["body_nconsts"]
        cond = eqn.params["cond_jaxpr"].jaxpr
        body = eqn.params["body_jaxpr"].jaxpr
        carry = eqn.invars[ncc + nbc:]
        self._walk_sub(cond, eqn.invars[:ncc] + carry, eqn, env, taint,
                       path + ("while.cond",), bind_out=False)
        self._walk_sub(body, eqn.invars[ncc:ncc + nbc] + carry, eqn, env,
                       taint, path + ("while.body",), bind_out=False)

    def _walk_scan(self, eqn, env, taint, path) -> None:
        body = eqn.params["jaxpr"].jaxpr
        nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
        sub_env: dict = {}
        sub_taint: dict = {}
        for bv, av in zip(body.invars[:nc], eqn.invars[:nc]):
            if isinstance(av, jcore.Var) and av in env:
                sub_env[bv] = env[av]
            sub_taint[bv] = taint.get(av, False) \
                if isinstance(av, jcore.Var) else False
        # carry and per-iteration xs slots get fresh identities: each
        # iteration sees a different concrete value under one abstract var
        for bv, av in zip(body.invars[nc:], eqn.invars[nc:]):
            sub_taint[bv] = taint.get(av, False) \
                if isinstance(av, jcore.Var) else False
        self.call_stack.append((source_of(eqn), False))
        try:
            self.walk(body, sub_env, sub_taint, path + ("scan",))
        finally:
            self.call_stack.pop()
        # key-carry-unsplit: the body wrapped a carried raw key (binding the
        # carry invar to its node), sampled it, and returned the SAME node
        # as the carry output
        for i in range(ncar):
            inv = body.invars[nc + i]
            outv = body.outvars[i]
            nid = sub_env.get(inv)
            if nid is None or not isinstance(outv, jcore.Var):
                continue
            if sub_env.get(outv) != nid:
                continue
            samples = [u for u in self.nodes[nid].uses if u.kind == "sample"]
            if samples:
                u = samples[0]
                self.carry_unsplit.append(Violation(
                    rule="key-carry-unsplit",
                    message=(
                        "PRNG key threaded UNSPLIT through a scan carry: "
                        f"sampled in the body (at {u.source}) and returned "
                        "to the carry unchanged, so every iteration "
                        "re-draws the same stream"
                    ),
                    source=u.source,
                    path=u.path,
                ))


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def _repo_roots() -> list[str]:
    here = os.path.dirname(os.path.abspath(__file__))  # .../src/repro/analysis
    src = os.path.dirname(os.path.dirname(here))
    return [os.getcwd(), src, os.path.dirname(src)]


def _suppressed_at(source: str) -> bool:
    """True if the reported source line (or the line above, for wrapped
    statements) carries a ``# key-flow: ok`` comment."""
    m = _SRC_RE.search(source)
    if not m:
        return False
    rel, lineno = m.group(1), int(m.group(2))
    candidates = [rel] if os.path.isabs(rel) else [
        os.path.join(root, rel) for root in _repo_roots()
    ]
    for cand in candidates:
        if not os.path.isfile(cand):
            continue
        try:
            with open(cand, encoding="utf-8") as f:
                lines = f.readlines()
        except OSError:  # pragma: no cover
            continue
        if 1 <= lineno <= len(lines) and _SUPPRESS_RE.search(lines[lineno - 1]):
            return True
        # walk upward through the contiguous comment block above the line
        ln = lineno - 1
        while 1 <= ln <= len(lines) and lines[ln - 1].lstrip().startswith("#"):
            if _SUPPRESS_RE.search(lines[ln - 1]):
                return True
            ln -= 1
    return False


def analyze_key_flow(closed: jcore.ClosedJaxpr) -> KeyFlowReport:
    """Run the key-flow lint over one closed jaxpr (an entry point traced
    with ``jax.make_jaxpr``).  Entry-point invars are the taint sources for
    the constant-key rule."""
    ana = _Analysis()
    jaxpr = closed.jaxpr
    taint = {v: True for v in jaxpr.invars}
    for v in jaxpr.constvars:
        taint[v] = False
    ana.walk(jaxpr, {}, taint, ())

    findings: list[Violation] = []
    n_samples = 0
    constant_origins: set[str] = set()
    for node in ana.nodes.values():
        uses = sorted(node.uses, key=lambda u: u.order)
        samples = [u for u in uses if u.kind == "sample"]
        n_samples += len(samples)
        if len(uses) >= 2 and samples:
            # multiple samples, or sample + derivation, of ONE key.  Two
            # derivations with distinct parameters are fine (distinct
            # streams); any pair involving a sample is a conflict.  Flag at
            # the LATER consumer of each conflicting pair.
            for i, u in enumerate(uses[1:], start=1):
                earlier = uses[:i]
                if not (u.kind == "sample"
                        or any(e.kind == "sample" for e in earlier)):
                    continue
                first = next(e for e in earlier
                             if u.kind == "sample" or e.kind == "sample")
                findings.append(Violation(
                    rule="key-reuse",
                    message=(
                        f"PRNG key consumed more than once: first use is a "
                        f"{first.kind} at {first.source}; this {u.kind} "
                        "re-consumes the same key (derivations and samples "
                        "of one key walk the same counter stream)"
                    ),
                    source=u.source,
                    path=u.path,
                ))
        if samples and not node.tainted and node.origin not in constant_origins:
            constant_origins.add(node.origin)
            findings.append(Violation(
                rule="key-constant",
                message=(
                    "sampler consumes a key with NO dataflow from the entry "
                    f"point's inputs (hard-coded seed created at "
                    f"{node.origin}; sampled at {samples[0].source}) -- the "
                    "drawn values are identical for every caller seed"
                ),
                source=node.origin,
                path=samples[0].path,
            ))
    findings.extend(ana.carry_unsplit)

    violations, suppressed = [], []
    for v in findings:
        (suppressed if _suppressed_at(v.source) else violations).append(v)
    return KeyFlowReport(violations=violations, suppressed=suppressed,
                         n_keys=len(ana.nodes), n_samples=n_samples)


def check_key_flow(closed: jcore.ClosedJaxpr) -> list[Violation]:
    """Contract-style entry: unsuppressed key-flow violations only."""
    return analyze_key_flow(closed).violations
