"""Contract runner: ``python -m repro.analysis`` (DESIGN.md Sec. 7).

Lowers every registered (algorithm, engine-flag) combination and lints it
against its declared contract.  Exit code 0 = every contract clean;
nonzero = at least one violation, each reported with its rule, context
path, and jaxpr source location.  Wired into ``benchmarks/verify.sh
--static`` and CI; the same checks back the tier-1 tests through
``tests/test_analysis.py``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Iterable, Optional

from repro.analysis.contracts import CONTRACTS
from repro.analysis.jaxpr_lint import Violation


def check_all(
    names: Optional[Iterable[str]] = None,
    *,
    verbose: bool = False,
    out=None,
) -> dict[str, list[Violation]]:
    """Run the selected (default: all) contracts; return name -> violations.

    ``out`` defaults to the CURRENT ``sys.stdout`` (resolved per call, so
    stream redirection -- pytest capture, tee'd CI logs -- is honored).
    """
    out = out if out is not None else sys.stdout
    selected = list(names) if names else sorted(CONTRACTS)
    unknown = [n for n in selected if n not in CONTRACTS]
    if unknown:
        raise KeyError(
            f"unknown contract(s) {unknown}; registered: {sorted(CONTRACTS)}"
        )
    results: dict[str, list[Violation]] = {}
    for name in selected:
        t0 = time.time()
        try:
            violations = CONTRACTS[name].check()
        except Exception as e:  # lowering itself broke: that IS a violation
            violations = [Violation(
                rule="lowering-error",
                message=f"contract could not lower/lint: {type(e).__name__}: {e}",
            )]
        results[name] = violations
        dt = time.time() - t0
        if violations:
            print(f"FAIL {name} ({len(violations)} violation(s), {dt:.1f}s)",
                  file=out)
            for v in violations:
                print(f"     {v}", file=out)
        else:
            tag = f"ok   {name}"
            if verbose:
                tag += f"  -- {CONTRACTS[name].description} ({dt:.1f}s)"
            print(tag, file=out)
    return results


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="lint the compiled round-engine programs against their "
                    "declared contracts (no execution)",
    )
    ap.add_argument("--only", default="",
                    help="comma-separated contract names (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="list registered contracts and exit")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write a machine-readable report (per-contract "
                         "violations + totals) to PATH, '-' for stdout")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(CONTRACTS):
            print(f"{name}: {CONTRACTS[name].description}")
        return 0

    names = [n.strip() for n in args.only.split(",") if n.strip()] or None
    results = check_all(names, verbose=args.verbose)
    n_bad = sum(1 for v in results.values() if v)
    n_violations = sum(len(v) for v in results.values())
    if args.json:
        report = {
            "contracts": {
                name: {
                    "description": CONTRACTS[name].description,
                    "violations": [dataclasses.asdict(v) for v in vs],
                }
                for name, vs in results.items()
            },
            "n_contracts": len(results),
            "n_violated": n_bad,
            "n_violations": n_violations,
            "clean": n_bad == 0,
        }
        text = json.dumps(report, indent=2, default=str)
        if args.json == "-":
            print(text)
        else:
            with open(args.json, "w", encoding="utf-8") as f:
                f.write(text + "\n")
    if n_bad:
        print(f"repro.analysis: {n_bad}/{len(results)} contract(s) violated "
              f"({n_violations} violation(s))")
        return 1
    print(f"repro.analysis: {len(results)} contract(s) clean")
    return 0
