"""Static Pallas kernel-launch verifier (DESIGN.md Sec. 7).

Audits a declarative ``repro.kernels.spec.KernelSpec`` -- the same object
that constructs the real ``pl.pallas_call`` -- WITHOUT executing or even
lowering anything.  The auditor enumerates the grid through the declared
index maps and proves:

* **write-race freedom** (``kernel-write-race``): every output block is
  written by exactly one grid cell, except revisits along the DECLARED
  reduction axes (``revisit_axes``) -- two cells that map to the same
  output block while differing in a non-revisit axis would race (or, on
  the sequentially-executed TPU grid, silently clobber partial sums);
* **output coverage** (``kernel-unwritten-block``): every block of every
  output array is written by at least one grid cell -- an index-map typo
  that strands a block leaves uninitialized memory in the result;
* **revisit ordering** (``kernel-revisit-order``): revisit axes must be
  the TRAILING grid axes, so all revisits of one output block are
  consecutive under the TPU's sequential row-major grid execution (a
  leading revisit axis interleaves partial sums of different blocks
  through one scratch accumulator);
* **accumulator discipline** (``kernel-accum-missing`` /
  ``kernel-accum-init`` / ``kernel-accum-dtype``): a kernel whose output
  blocks are revisited must declare where the partial state lives
  (scratch or the output ref itself), must initialize it exactly when the
  revisit sweep restarts (``init_axes == revisit_axes``: a strict subset
  is a stale or mid-sweep-clobbered accumulator), and must keep it in
  >= 32-bit float when any input is sub-f32 (bf16 partial sums lose the
  low bits of every accumulation step);
* **in-bounds addressing** (``kernel-oob-index``): no grid cell's block
  index addresses past the padded array bounds on any axis;
* **block alignment** (``kernel-block-misaligned``): every array axis is
  a whole multiple of its block axis (ops.py pads to guarantee this; a
  spec that violates it silently truncates the trailing partial block);
* **VMEM budget** (``kernel-vmem-budget``): the per-grid-cell footprint
  (double-buffered blocks + scratch, minor axes tile-padded) fits the
  ``BACKEND_ROOFLINE`` budget -- checked for every block candidate the
  autotuner can emit and for user-pinned ``AlgoConfig`` blocks.

Every violation carries the kernel name and the offending grid cell.
"""

from __future__ import annotations

import itertools
from typing import Optional

import jax.numpy as jnp

from repro.analysis.jaxpr_lint import Violation
from repro.kernels.spec import KernelSpec
from repro.launch.mesh import BACKEND_ROOFLINE


def _cell(c) -> str:
    return "(" + ", ".join(map(str, c)) + ")"


def check_geometry(spec: KernelSpec) -> list[Violation]:
    """Grid-enumeration rules: races, coverage, bounds, accumulators."""
    out: list[Violation] = []
    name = spec.name

    # revisit axes must be a trailing suffix of the grid
    k = len(spec.revisit_axes)
    trailing = tuple(range(len(spec.grid) - k, len(spec.grid)))
    if tuple(sorted(spec.revisit_axes)) != trailing:
        out.append(Violation(
            rule="kernel-revisit-order",
            message=(
                f"{name}: revisit_axes {spec.revisit_axes} are not the "
                f"trailing grid axes {trailing}; revisits of one output "
                "block would not be consecutive under sequential grid "
                "execution, interleaving partial sums through the "
                "accumulator"
            ),
            source=name,
        ))

    cells = list(spec.grid_cells())
    for role, idx, arr, blk in spec.operands():
        opname = f"{role}[{idx}]"
        if len(blk.block_shape) != len(arr.shape):
            out.append(Violation(
                rule="kernel-block-misaligned",
                message=(f"{name}: {opname} block rank "
                         f"{len(blk.block_shape)} != array rank "
                         f"{len(arr.shape)}"),
                source=name,
            ))
            continue
        misaligned = [ax for ax, (s, b) in
                      enumerate(zip(arr.shape, blk.block_shape)) if s % b]
        if misaligned:
            out.append(Violation(
                rule="kernel-block-misaligned",
                message=(
                    f"{name}: {opname} axes {misaligned} are not whole "
                    f"multiples of the block {blk.block_shape} (array "
                    f"{arr.shape}); the trailing partial block would be "
                    "silently truncated"
                ),
                source=name,
            ))
            continue

        writers: dict[tuple[int, ...], tuple[int, ...]] = {}
        raced: set[tuple[int, ...]] = set()
        oob_reported = 0
        for cell in cells:
            bi = tuple(blk.index_map(*cell))
            if len(bi) != len(arr.shape):
                out.append(Violation(
                    rule="kernel-oob-index",
                    message=(f"{name}: {opname} index map returned rank "
                             f"{len(bi)} for rank-{len(arr.shape)} array "
                             f"at grid cell {_cell(cell)}"),
                    source=name,
                ))
                break
            bad_axis = next(
                (ax for ax in range(len(bi))
                 if bi[ax] < 0
                 or (bi[ax] + 1) * blk.block_shape[ax] > arr.shape[ax]),
                None,
            )
            if bad_axis is not None:
                if oob_reported < 3:  # first few cells, not the whole grid
                    lo = bi[bad_axis] * blk.block_shape[bad_axis]
                    out.append(Violation(
                        rule="kernel-oob-index",
                        message=(
                            f"{name}: {opname} grid cell {_cell(cell)} "
                            f"addresses block {_cell(bi)} -> elements "
                            f"[{lo}, {lo + blk.block_shape[bad_axis]}) "
                            f"beyond padded bound {arr.shape[bad_axis]} "
                            f"on axis {bad_axis}"
                        ),
                        source=name,
                    ))
                oob_reported += 1
                continue
            if role != "out":
                continue
            prev = writers.setdefault(bi, cell)
            if prev is not cell and bi not in raced:
                diff = [ax for ax in range(len(cell)) if cell[ax] != prev[ax]]
                if any(ax not in spec.revisit_axes for ax in diff):
                    raced.add(bi)
                    out.append(Violation(
                        rule="kernel-write-race",
                        message=(
                            f"{name}: output block {_cell(bi)} of {opname} "
                            f"is written by grid cells {_cell(prev)} and "
                            f"{_cell(cell)}, which differ outside the "
                            f"declared revisit axes {spec.revisit_axes}"
                        ),
                        source=name,
                    ))
        if role == "out" and not oob_reported:
            nblocks = tuple(s // b for s, b in
                            zip(arr.shape, blk.block_shape))
            missing = [b for b in itertools.product(*(range(x) for x in nblocks))
                       if b not in writers]
            for b in missing[:3]:
                out.append(Violation(
                    rule="kernel-unwritten-block",
                    message=(f"{name}: output block {_cell(b)} of {opname} "
                             "is written by NO grid cell (uninitialized "
                             "result memory)"),
                    source=name,
                ))

    # accumulator protocol of revisiting kernels
    if spec.revisit_axes:
        accs = spec.accumulators()
        if not accs:
            out.append(Violation(
                rule="kernel-accum-missing",
                message=(
                    f"{name}: output blocks are revisited over grid axes "
                    f"{spec.revisit_axes} but the spec declares neither "
                    "scratch accumulators nor out_accumulates; partial "
                    "state has nowhere to live across revisits"
                ),
                source=name,
            ))
        if tuple(sorted(spec.init_axes)) != tuple(sorted(spec.revisit_axes)):
            first_revisit = tuple(
                1 if ax == spec.revisit_axes[-1] else 0
                for ax in range(len(spec.grid))
            )
            out.append(Violation(
                rule="kernel-accum-init",
                message=(
                    f"{name}: accumulator init is guarded on grid axes "
                    f"{spec.init_axes} but output blocks are revisited "
                    f"over {spec.revisit_axes}; the accumulator is stale "
                    "or clobbered by the first revisiting grid step "
                    f"(e.g. cell {_cell(first_revisit)})"
                ),
                source=name,
            ))
        sub_f32 = [
            (f"in[{i}]", a.dtype) for i, a in enumerate(spec.in_shapes)
            if jnp.issubdtype(jnp.dtype(a.dtype), jnp.floating)
            and jnp.dtype(a.dtype).itemsize < 4
        ]
        if sub_f32:
            for kind, i, dt in accs:
                dt = jnp.dtype(dt)
                if not (jnp.issubdtype(dt, jnp.floating) and dt.itemsize >= 4):
                    out.append(Violation(
                        rule="kernel-accum-dtype",
                        message=(
                            f"{name}: {kind}[{i}] accumulator is {dt.name} "
                            f"while inputs {[n for n, _ in sub_f32]} are "
                            "sub-f32; partial sums must accumulate in f32 "
                            "(bf16 accumulation loses the low bits of "
                            "every revisiting grid step)"
                        ),
                        source=name,
                    ))
    return out


def check_vmem(spec: KernelSpec, *, backend: str = "tpu",
               budget: Optional[int] = None) -> list[Violation]:
    """Per-grid-cell VMEM footprint vs the backend roofline budget."""
    if budget is None:
        hw = BACKEND_ROOFLINE.get(backend, BACKEND_ROOFLINE["_default"])
        budget = hw["vmem_bytes"]
    need = spec.vmem_cell_bytes()
    if need <= budget:
        return []
    blocks = {f"{role}[{i}]": tuple(b.block_shape)
              for role, i, _, b in spec.operands()}
    return [Violation(
        rule="kernel-vmem-budget",
        message=(
            f"{spec.name}: per-grid-cell VMEM footprint {need} B (blocks "
            f"{blocks}, x2 double-buffered, + scratch) exceeds the "
            f"{backend} budget {budget} B at every grid cell (e.g. "
            f"{_cell(tuple(0 for _ in spec.grid))})"
        ),
        source=spec.name,
    )]


def audit_spec(spec: KernelSpec, *, backend: str = "tpu",
               budget: Optional[int] = None) -> list[Violation]:
    """Full static audit: geometry rules + VMEM budget."""
    return check_geometry(spec) + check_vmem(spec, backend=backend,
                                             budget=budget)
