"""Per-engine compiled-program contracts (DESIGN.md Sec. 7).

Each engine configuration DECLARES its invariants here; ``runner.py`` (and
``python -m repro.analysis``) lowers every registered (algorithm,
engine-flag) combination from ``AlgoConfig`` -- via the same
``launch.common.make_config`` surface the launchers use -- and lints the
jaxpr + lowered HLO against the declaration, without executing anything.

Registered contracts (one line each; detection mechanism in parens):

  * fzoos deferred body, sim + dist: NO eigh (jaxpr primitive + HLO
    fingerprint), no host callbacks/transfers, no carry-dtype promotion;
    dist adds the collective census;
  * fzoos inline oracle body: eigh MUST be present (the oracle exists to
    demonstrate the contrast) but everything else holds;
  * fedzo / fedprox (FD family) bodies: eigh-free by construction, census
    pins 1 array psum (the iterate payload) on the dist path;
  * fzoos/fedzo FAULT-MASKED bodies, sim + dist: same rules AND the same
    collective census as the unmasked engines -- the live/quarantine counts
    ride inside the existing payload psums, so fault masking adds zero
    collectives and zero host ops to the round;
  * chunk step: every donated {ClientState, history} leaf is actually
    aliased input->output in the lowering (``tf.aliasing_output``), with
    and without the fault mask;
  * boundary repair: the repair eigh exists but ONLY behind a cond, and
    the donated factor buffers alias;
  * quarantine reset: the device-decided re-admission gate traces NO
    init-time linear algebra (the fresh-client template is eager) and
    donates the stacked state;
  * optimizers: sgd/adam/adamw updates preserve bf16 param dtype (the
    PR 4 drift class, checked on invar/outvar avals).

The census numbers are DECLARED from the communication claim, not
re-measured: 2 array-payload psums for fzoos (iterate x + RFF weights w =
the paper's ``d + M`` floats/round), 1 for the FD family (x only), plus 6
scalar psums (5 RoundStats reductions + the eval pmean, which lowers to a
psum).  Adding a collective to the round body is a PROTOCOL change and
must show up here as a deliberate diff.

``steady_state_guard`` / ``no_recompiles`` are the runtime complement: a
context manager that fails on unexpected executable compiles (cache
misses) and host ``device_get`` syncs inside a steady-state window --
subsuming the PR 4 zero-device_get assertion.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from functools import lru_cache
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.analysis import hlo_audit, jaxpr_lint
from repro.analysis.jaxpr_lint import Violation

# ---------------------------------------------------------------------------
# Steady-state guard (recompiles + host syncs)
# ---------------------------------------------------------------------------

#: Monitoring event jax records once per backend executable compile.
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_guard_lock = threading.Lock()
_active_guards: list["GuardState"] = []
_listener_installed = False


class SteadyStateViolation(AssertionError):
    """A steady-state window compiled or synced more than its contract allows."""


@dataclasses.dataclass
class GuardState:
    """Counters exposed to the ``with steady_state_guard() as g`` body."""

    compiles: int = 0
    device_gets: int = 0


def _on_event_duration(event: str, duration: float, **kw) -> None:
    del duration, kw
    if event == _COMPILE_EVENT:
        with _guard_lock:
            for g in _active_guards:
                g.compiles += 1


def _ensure_listener() -> None:
    global _listener_installed
    if not _listener_installed:
        jax.monitoring.register_event_duration_secs_listener(_on_event_duration)
        _listener_installed = True


@contextlib.contextmanager
def steady_state_guard(
    *,
    allow_compiles: Optional[int] = None,
    allow_device_gets: Optional[int] = 0,
):
    """Fail if the enclosed code compiles / host-syncs beyond its budget.

    ``allow_compiles``: max executable compiles (compilation-cache misses)
    tolerated; ``None`` counts but does not enforce.  ``allow_device_gets``
    likewise for ``jax.device_get`` calls (the chunk-boundary host-sync
    class PR 4 eliminated).  Yields a ``GuardState`` whose counters are
    live, so callers can also assert richer conditions themselves.
    """
    _ensure_listener()
    st = GuardState()
    real_get = jax.device_get

    def spy(x):
        st.device_gets += 1
        return real_get(x)

    with _guard_lock:
        _active_guards.append(st)
    jax.device_get = spy
    try:
        yield st
    finally:
        jax.device_get = real_get
        with _guard_lock:
            _active_guards.remove(st)
    if allow_compiles is not None and st.compiles > allow_compiles:
        raise SteadyStateViolation(
            f"steady-state window compiled {st.compiles} executable(s) "
            f"(allowed {allow_compiles}): an executable cache miss is "
            "re-tracing inside the steady state"
        )
    if allow_device_gets is not None and st.device_gets > allow_device_gets:
        raise SteadyStateViolation(
            f"steady-state window issued {st.device_gets} jax.device_get "
            f"sync(s) (allowed {allow_device_gets}): the zero-sync boundary "
            "contract is broken"
        )


def no_recompiles(allow: int = 0):
    """Recompile guard only: fail on executable cache misses, ignore syncs."""
    return steady_state_guard(allow_compiles=allow, allow_device_gets=None)


# ---------------------------------------------------------------------------
# Contract registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Contract:
    """One declared invariant set over one lowered entry point."""

    name: str
    description: str
    check: Callable[[], list[Violation]]


CONTRACTS: dict[str, Contract] = {}


def register(name: str, description: str):
    def deco(fn: Callable[[], list[Violation]]):
        CONTRACTS[name] = Contract(name=name, description=description, check=fn)
        return fn

    return deco


def check_contract(name: str) -> list[Violation]:
    return CONTRACTS[name].check()


# -- shared fixtures (small shapes: lint cost, not run cost) ----------------


def _make_cfg(algo: str, **overrides):
    from repro.launch.common import make_config

    base = dict(dim=8, n_clients=4, local_steps=2, lengthscale=0.5)
    if algo == "fzoos":
        base.update(n_features=32, traj_capacity=32, active_per_iter=1,
                    active_candidates=8, active_round_end=1)
    else:
        base.update(q=4)
    base.update(overrides)
    return make_config(algo, **base)


@lru_cache(maxsize=None)
def _fixture(algo: str, defer_repair: bool):
    from repro.core import algorithms as alg
    from repro.core import objectives as obj
    from repro.core import rff as rfflib

    cfg = _make_cfg(algo, defer_repair=defer_repair)
    quad = obj.make_quadratic(jax.random.PRNGKey(0), cfg.n_clients, cfg.dim,
                              2.0, 0.001)
    x0 = jnp.full((cfg.dim,), 0.5, jnp.float32)
    rff = None
    if cfg.is_fzoos:
        rff = rfflib.make_rff(jax.random.PRNGKey(1), cfg.n_features, cfg.dim,
                              cfg.lengthscale)
    states = alg.init_states(cfg, jax.random.PRNGKey(2), x0)
    return cfg, rff, quad, states, x0


@lru_cache(maxsize=None)
def _mesh():
    return jax.make_mesh((1,), ("data",))


def _fault_fixture():
    """The tolerant fault schedule every faulted contract lowers with:
    nonzero drop + poison rates so the mask, the packed-count payload and
    the quarantine logic are all live in the traced program."""
    from repro.faults import FaultConfig

    return FaultConfig(seed=0, drop_rate=0.25, nan_rate=0.25, tolerate=True)


def _chunk_fn(algo: str, defer_repair: bool, distributed: bool, length: int = 2,
              faulted: bool = False):
    from repro.core import objectives as obj
    from repro.core import rounds as rounds_mod

    cfg, rff, quad, states, x0 = _fixture(algo, defer_repair)
    faults = _fault_fixture() if faulted else None
    if distributed:
        cf = rounds_mod.dist_chunk_fn(cfg, _mesh(), rff, obj.quadratic_query,
                                      obj.quadratic_global_value, length, 1, 4,
                                      faults=faults)
    else:
        cf = rounds_mod.sim_chunk_fn(cfg, rff, obj.quadratic_query,
                                     obj.quadratic_global_value, None, length,
                                     1, 4, faults=faults)
    args = (states, quad, x0, jnp.int32(0))
    return cf, args


@lru_cache(maxsize=None)
def _body_artifacts(algo: str, defer_repair: bool, distributed: bool,
                    faulted: bool = False):
    """(closed jaxpr, lowered stablehlo text) of one scanned chunk body."""
    cf, args = _chunk_fn(algo, defer_repair, distributed, faulted=faulted)
    closed = jax.make_jaxpr(cf)(*args)
    text = jax.jit(cf).lower(*args).as_text()
    return closed, text


#: Scalar psums every distributed round body carries: the five RoundStats
#: reductions (cos, disparity, queries, refactor, repair) + the eval pmean.
_SCALAR_PSUMS = 6


def _body_rules(
    closed,
    text,
    *,
    expect_eigh: bool,
    census: Optional[dict[str, int]],
) -> list[Violation]:
    out: list[Violation] = []
    if expect_eigh:
        # the oracle body must DEMONSTRABLY carry the inline eigh, or the
        # no-eigh assertions elsewhere are vacuous
        if not jaxpr_lint.count_primitives(closed, jaxpr_lint.EIGH_PRIMITIVES):
            out.append(Violation(
                rule="oracle-eigh-missing",
                message="inline-cond oracle body lowered WITHOUT eigh; the "
                        "deferred/inline contrast is no longer being tested",
            ))
        if not hlo_audit.contains_eigh(text):
            out.append(Violation(
                rule="oracle-eigh-missing",
                message="inline-cond oracle HLO carries no eigh custom call",
            ))
    else:
        out += jaxpr_lint.find_forbidden(closed, jaxpr_lint.EIGH_PRIMITIVES,
                                         rule="no-eigh")
        out += hlo_audit.check_no_eigh(text, where="scanned round body")
    out += jaxpr_lint.find_host_ops(closed)
    out += jaxpr_lint.find_carry_promotions(closed)
    if census is not None:
        out += jaxpr_lint.check_psum_census(closed, census)
    else:
        # the vmapped sim body must stay collective-free outright
        out += jaxpr_lint.check_psum_census(closed, {})
    return out


def _register_engine(key: str, algo: str, defer_repair: bool,
                     expect_eigh: bool, n_array_psums: int,
                     faulted: bool = False) -> None:
    for dist in (False, True):
        mode = "distributed" if dist else "simulate"
        census = (
            {"psum_array": n_array_psums, "psum_scalar": _SCALAR_PSUMS}
            if dist else None
        )

        def chk(d=dist, c=census):
            closed, text = _body_artifacts(algo, defer_repair, d, faulted)
            return _body_rules(closed, text, expect_eigh=expect_eigh, census=c)

        register(
            f"{key}/{mode}",
            f"{key} scanned round body ({mode}): "
            + ("eigh present (oracle)" if expect_eigh else "eigh-free")
            + ", no host ops, no carry promotion"
            + (f", census {census}" if census else ", collective-free"),
        )(chk)


# FZooS deferred engine (the default): the tentpole no-eigh contract.
_register_engine("fzoos-deferred", "fzoos", defer_repair=True,
                 expect_eigh=False, n_array_psums=2)
# FZooS inline-cond oracle: eigh must remain visible (contrast witness).
_register_engine("fzoos-inline", "fzoos", defer_repair=False,
                 expect_eigh=True, n_array_psums=2)
# FD family: eigh-free by construction, iterate-only array payload.
_register_engine("fedzo", "fedzo", defer_repair=True,
                 expect_eigh=False, n_array_psums=1)
_register_engine("fd-fedprox", "fedprox", defer_repair=True,
                 expect_eigh=False, n_array_psums=1)
# Fault-masked engines: the census is UNCHANGED vs the unmasked bodies --
# the live/quarantine counts ride inside the existing payload psums, so
# masking adds zero collectives (and zero host ops) to the round.
_register_engine("fzoos-faults", "fzoos", defer_repair=True,
                 expect_eigh=False, n_array_psums=2, faulted=True)
_register_engine("fedzo-faults", "fedzo", defer_repair=True,
                 expect_eigh=False, n_array_psums=1, faulted=True)


# -- partial-participation cohort engine (core/pool.py) ---------------------


def _pool_chunk_fn(algo: str, distributed: bool, length: int = 2):
    """The cohort chunk body EXACTLY as run_pooled_rounds builds it: the
    round body compiles against the K-client cohort config and the masked
    zero-rate sum_fn path (participation-weighted aggregation)."""
    import dataclasses as _dc

    from repro.core import objectives as obj
    from repro.core import pool as pool_mod
    from repro.core import rounds as rounds_mod
    from repro.faults import FaultConfig

    cfg, rff, quad, states, x0 = _fixture(algo, True)
    cohort = cfg.n_clients // 2
    ccfg = _dc.replace(cfg, n_clients=cohort)
    bcfg = FaultConfig()  # zero rates: the pooled faults=None body
    pool = pool_mod.ClientPool.from_states(states)
    idx = pool_mod.sample_cohort(0, 0, cfg.n_clients, cohort)
    mesh = _mesh() if distributed else None
    cstates = pool.gather(idx, mesh=mesh)
    c_quad = jax.tree_util.tree_map(lambda a: jnp.asarray(a)[jnp.asarray(idx)], quad)
    if distributed:
        cf = rounds_mod.dist_chunk_fn(ccfg, mesh, rff, obj.quadratic_query,
                                      obj.quadratic_global_value, length, 1, 4,
                                      faults=bcfg)
    else:
        cf = rounds_mod.sim_chunk_fn(ccfg, rff, obj.quadratic_query,
                                     obj.quadratic_global_value, None, length,
                                     1, 4, faults=bcfg)
    return cf, (cstates, c_quad, x0, jnp.int32(0))


def _register_pool_engine(key: str, algo: str, n_array_psums: int) -> None:
    """Cohort-engine census contract: the K-client cohort body must carry
    EXACTLY the dense engine's collective count -- the participation
    weighting rides inside the existing payload psums, so partial
    participation changes the denominator, never the protocol."""
    for dist in (False, True):
        mode = "distributed" if dist else "simulate"
        census = (
            {"psum_array": n_array_psums, "psum_scalar": _SCALAR_PSUMS}
            if dist else None
        )

        def chk(d=dist, c=census):
            cf, args = _pool_chunk_fn(algo, d)
            closed = jax.make_jaxpr(cf)(*args)
            text = jax.jit(cf).lower(*args).as_text()
            return _body_rules(closed, text, expect_eigh=False, census=c)

        register(
            f"{key}/{mode}",
            f"{key} cohort round body ({mode}): eigh-free, no host ops, "
            + (f"census {census} == dense engine" if census
               else "collective-free"),
        )(chk)


_register_pool_engine("fzoos-pool", "fzoos", n_array_psums=2)
_register_pool_engine("fedzo-pool", "fedzo", n_array_psums=1)


def _chunk_step_donation(distributed: bool, faulted: bool = False) -> list[Violation]:
    from repro.core import rounds as rounds_mod

    cf, (states, quad, x0, off) = _chunk_fn("fzoos", True, distributed,
                                            faulted=faulted)
    hist = rounds_mod.history_init(4, x0, jnp.zeros((), jnp.float32))
    step = rounds_mod.make_chunk_step(cf)
    text = step.lower(states, hist, quad, x0, off).as_text()
    n_leaves = len(jax.tree_util.tree_leaves((states, hist)))
    where = "distributed" if distributed else "simulate"
    if faulted:
        where += ", faulted"
    return hlo_audit.check_donation(text, n_leaves, where=f"chunk step ({where})")


register(
    "chunk-step-donation/simulate",
    "every donated {ClientState, history} leaf aliases input->output",
)(lambda: _chunk_step_donation(False))
register(
    "chunk-step-donation/distributed",
    "donation survives the shard_map lowering of the chunk step",
)(lambda: _chunk_step_donation(True))
register(
    "chunk-step-donation/faulted",
    "the fault-masked chunk step (incl. mask/quarantine leaves) still "
    "donates every {ClientState, history} leaf",
)(lambda: _chunk_step_donation(False, faulted=True))
register(
    "chunk-step-donation/faulted-distributed",
    "faulted chunk-step donation survives the shard_map lowering",
)(lambda: _chunk_step_donation(True, faulted=True))


@register(
    "quarantine-reset",
    "device-decided quarantine reset: NO init-time linear algebra traced "
    "(eager template), no host ops; donated state leaves alias",
)
def _quarantine_reset_contract() -> list[Violation]:
    from repro.core import rounds as rounds_mod

    cfg, _, _, states, x0 = _fixture("fzoos", True)
    fn = rounds_mod._quarantine_reset_exec(cfg, None, states.x.shape)
    closed = jax.make_jaxpr(fn)(states, x0)
    out = jaxpr_lint.find_forbidden(closed, jaxpr_lint.EIGH_PRIMITIVES,
                                    rule="no-eigh")
    out += jaxpr_lint.find_host_ops(closed)
    text = fn.lower(states, x0).as_text()
    out += hlo_audit.check_no_eigh(text, where="quarantine reset")
    n_leaves = len(jax.tree_util.tree_leaves(states))
    out += hlo_audit.check_donation(text, n_leaves, where="quarantine reset")
    return out


@register(
    "boundary-repair",
    "repair eigh exists ONLY behind cond; donated factor buffers alias",
)
def _boundary_repair_contract() -> list[Violation]:
    from repro.core import gp_surrogate as gp

    _, _, _, states, _ = _fixture("fzoos", True)
    closed = jax.make_jaxpr(gp.factor_repair_gated)(states.factor,
                                                    jnp.float32(1e-4))
    out = jaxpr_lint.eigh_only_behind_cond(closed)
    if not jaxpr_lint.count_primitives(closed, jaxpr_lint.EIGH_PRIMITIVES):
        out.append(Violation(
            rule="oracle-eigh-missing",
            message="boundary repair lost its eigh: flagged Grams would "
                    "never be refactorized",
        ))
    jitted = jax.jit(gp.factor_repair_gated, donate_argnums=0)
    text = jitted.lower(states.factor, jnp.float32(1e-4)).as_text()
    n_leaves = len(jax.tree_util.tree_leaves(states.factor))
    out += hlo_audit.check_donation(text, n_leaves, where="boundary repair")
    return out


@register(
    "optimizer-dtype",
    "sgd/adam/adamw updates preserve bf16 param dtype (PR 4 drift class)",
)
def _optimizer_dtype_contract() -> list[Violation]:
    from repro.optim import make_optimizer

    out: list[Violation] = []
    for name in ("sgd", "adam", "adamw"):
        opt_init, opt_update = make_optimizer(name)
        p = jnp.zeros((4,), jnp.bfloat16)
        state = opt_init(p)
        g = jnp.zeros((4,), jnp.float32)
        closed = jax.make_jaxpr(
            lambda s, gg, pp: opt_update(s, gg, pp, 0.01)
        )(state, g, p)
        # flat leaf indices: params are the LAST input leaf; the updated
        # params are the FIRST output leaf ((new_params, new_state) order)
        n_in = len(jax.tree_util.tree_leaves((state, g, p)))
        for v in jaxpr_lint.check_io_dtypes(closed, [(n_in - 1, 0)]):
            out.append(dataclasses.replace(
                v, message=f"{name}: {v.message}"))
    return out


# ---------------------------------------------------------------------------
# Static kernel-launch contracts (analysis/kernel_audit.py over KernelSpec)
# ---------------------------------------------------------------------------

#: Audit shapes: small enough to enumerate the grid instantly, large enough
#: that every kernel is genuinely tiled (several blocks per axis).
_KAUDIT_N, _KAUDIT_CAP, _KAUDIT_D = 64, 512, 32
_KAUDIT_BN, _KAUDIT_BC = 16, 128
_KAUDIT_NB = 4  # client batch of the *_clients variants


def _register_kernel_contract(key: str, make_specs, description: str) -> None:
    def chk():
        from repro.analysis import kernel_audit

        out: list[Violation] = []
        for spec in make_specs():
            out += kernel_audit.audit_spec(spec)
        return out

    register(f"kernel/{key}", description)(chk)


def _gp_specs(builder, *, tiled: bool, clients: bool):
    """The f32 spec plus -- for the tiled accumulator kernels -- the bf16
    variant, which must keep its scratch accumulators in f32."""

    def make():
        shape = (_KAUDIT_N, _KAUDIT_CAP if tiled else _KAUDIT_BC, _KAUDIT_D)
        blocks = {"block_n": _KAUDIT_BN}
        if tiled:
            blocks["block_cap"] = _KAUDIT_BC
        dtypes = (jnp.float32, jnp.bfloat16) if tiled else (jnp.float32,)
        for dt in dtypes:
            if clients:
                yield builder(_KAUDIT_NB, *shape, dt, **blocks)
            else:
                yield builder(*shape, dt, **blocks)

    return make


def _register_gp_kernel_contracts() -> None:
    from repro.kernels import gp_grad, gp_score

    for mod, stem in ((gp_score, "gp-score"), (gp_grad, "gp-grad")):
        pre = "score" if stem == "gp-score" else "grad"
        for variant, tiled, clients in (
            ("resident", False, False),
            ("clients", False, True),
            ("tiled", True, False),
            ("tiled-clients", True, True),
        ):
            builder = getattr(mod, f"{pre}_{variant.replace('-', '_')}_spec")
            _register_kernel_contract(
                f"{stem}-{variant}",
                _gp_specs(builder, tiled=tiled, clients=clients),
                f"{stem}.{variant} launch geometry: race-free, covered, "
                "in-bounds, accumulator-disciplined, in VMEM budget"
                + (" (f32 + bf16-in/f32-scratch)" if tiled else ""),
            )


_register_gp_kernel_contracts()

def _rff_features_specs():
    from repro.kernels.rff_features import features_spec

    return [features_spec(128, 256, _KAUDIT_D, jnp.float32,
                          block_n=64, block_m=128)]


def _rff_grad_specs():
    from repro.kernels.rff_grad import grad_spec

    return [grad_spec(128, 256, _KAUDIT_D, jnp.float32,
                      block_n=64, block_m=128)]


def _sqexp_specs():
    from repro.kernels.sqexp import sqexp_spec

    return [sqexp_spec(128, 256, _KAUDIT_D, jnp.float32,
                       block_n=64, block_m=128)]


_register_kernel_contract(
    "rff-features", _rff_features_specs,
    "rff_features launch geometry: one writer per output tile, in budget",
)
_register_kernel_contract(
    "rff-grad", _rff_grad_specs,
    "rff_grad launch geometry: M-axis reduction accumulates in the output "
    "ref (f32 only: the output IS the accumulator, so bf16 would trip "
    "kernel-accum-dtype -- see tests)",
)
_register_kernel_contract(
    "sqexp", _sqexp_specs,
    "sqexp launch geometry: one writer per output tile, in budget",
)


@register(
    "kernel/autotune-candidates",
    "every block pair the tuner's feasibility filter can emit (score + "
    "grad, f32 + bf16, cap=1024) fits the TPU VMEM budget as a real "
    "KernelSpec launch",
)
def _autotune_candidates_contract() -> list[Violation]:
    import numpy as np

    from repro.analysis import kernel_audit
    from repro.kernels import autotune
    from repro.kernels.gp_grad import grad_tiled_spec
    from repro.kernels.gp_score import score_tiled_spec
    from repro.launch.mesh import BACKEND_ROOFLINE

    hw = BACKEND_ROOFLINE["tpu"]
    n, cap, d = 256, 1024, 64
    out: list[Violation] = []
    for kind, builder in (("score", score_tiled_spec),
                          ("grad", grad_tiled_spec)):
        for dt in (jnp.float32, jnp.bfloat16):
            itemsize = np.dtype(dt).itemsize
            for bn, bc in autotune._feasible(kind, n, cap, d, hw, itemsize):
                if bc > cap:
                    continue  # routes to the resident kernel, not this spec
                spec = builder(n, cap, d, dt, block_n=bn, block_cap=bc)
                out += kernel_audit.check_vmem(spec, backend="tpu")
    return out


# ---------------------------------------------------------------------------
# PRNG key-flow contracts (analysis/key_flow.py over engine entry points)
# ---------------------------------------------------------------------------


def _register_key_flow(key: str, algo: str, defer_repair: bool) -> None:
    @register(
        f"key-flow/{key}",
        f"{key} round body: no PRNG key consumed twice, no key threaded "
        "unsplit through a scan carry, no unsuppressed hard-coded seed",
    )
    def _chk() -> list[Violation]:
        from repro.analysis import key_flow

        closed, _ = _body_artifacts(algo, defer_repair, False)
        return key_flow.check_key_flow(closed)


_register_key_flow("fzoos-deferred", "fzoos", True)
_register_key_flow("fzoos-inline", "fzoos", False)
_register_key_flow("fedzo", "fedzo", True)
_register_key_flow("fd-fedprox", "fedprox", True)


@register(
    "key-flow/init-states",
    "init_states: the constant direction-bank key (Prop. D.4) is the ONLY "
    "hard-coded seed, and it is explicitly suppressed in source",
)
def _init_states_key_flow() -> list[Violation]:
    from repro.analysis import key_flow
    from repro.core import algorithms as alg

    cfg, _, _, _, x0 = _fixture("fzoos", True)
    closed = jax.make_jaxpr(
        lambda key, x: alg.init_states(cfg, key, x)
    )(jax.random.PRNGKey(2), x0)
    report = key_flow.analyze_key_flow(closed)
    out = list(report.violations)
    if not report.suppressed:
        out.append(Violation(
            rule="key-flow-suppression-missing",
            message="init_states no longer carries the suppressed "
                    "constant-bank finding; if the bank key became "
                    "caller-derived, Prop. D.4 (identical banks across "
                    "clients) needs a new witness",
        ))
    return out
