"""Lowered-HLO auditor (DESIGN.md Sec. 7).

Checks that only the LOWERED program can answer:

  * **custom-call fingerprints** -- which backend routine a linalg
    primitive lowers to is backend-specific (``lapack_ssyevd`` on CPU,
    ``Eigh``/``cusolver_syevd`` elsewhere).  ``eigh_fingerprints()`` /
    ``cholesky_fingerprints()`` derive the current backend's names ONCE by
    lowering a probe, so no test or contract hardcodes a fingerprint
    (previously duplicated inline in test_deferred_repair.py);
  * **collective census** -- ``stablehlo.all_reduce`` etc. counts in the
    lowered text, cross-checking the jaxpr-level psum census;
  * **donation audit** -- every buffer a jit claims to donate must show up
    as an actual input-output alias (``tf.aliasing_output``) on the
    lowered main function; XLA silently DROPS donation when shapes/dtypes
    prevent aliasing (a UserWarning at best), which re-introduces the
    per-chunk state copy the scan engine exists to avoid.
"""

from __future__ import annotations

import functools
import re
from collections import Counter
from typing import Iterable

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_lint import Violation

_CUSTOM_CALL_RE = re.compile(r'custom_call_target\s*=\s*"([^"]+)"')
_ALIAS_RE = re.compile(r"tf\.aliasing_output\s*=\s*(\d+)")
_COLLECTIVE_OPS = (
    "all_reduce", "all_gather", "reduce_scatter", "collective_permute",
    "all_to_all",
)


def custom_call_targets(hlo_text: str) -> Counter:
    """Multiset of custom-call target names in a lowered module."""
    return Counter(_CUSTOM_CALL_RE.findall(hlo_text))


def _probe_fingerprints(probe_fn, static_markers: frozenset[str],
                        substrings: tuple[str, ...]) -> frozenset[str]:
    probe = jax.jit(probe_fn).lower(jnp.eye(4, dtype=jnp.float32)).as_text()
    markers = set(custom_call_targets(probe)) | set(static_markers)
    markers = {m for m in markers if any(s in m.lower() for s in substrings)}
    if not markers:
        raise RuntimeError(
            f"could not fingerprint {substrings} lowering on backend "
            f"{jax.default_backend()!r}"
        )
    return frozenset(markers)


@functools.lru_cache(maxsize=None)
def eigh_fingerprints() -> frozenset[str]:
    """Backend custom-call names ``jnp.linalg.eigh`` lowers to (plus the
    cross-backend fallbacks), derived once per process."""
    return _probe_fingerprints(
        lambda a: jnp.linalg.eigh(a)[0],
        frozenset({"Eigh", "syevd"}),
        ("syev", "eigh"),
    )


@functools.lru_cache(maxsize=None)
def cholesky_fingerprints() -> frozenset[str]:
    """Backend custom-call names ``jnp.linalg.cholesky`` lowers to."""
    return _probe_fingerprints(
        jnp.linalg.cholesky,
        frozenset({"Cholesky", "potrf"}),
        ("potrf", "cholesky"),
    )


def found_markers(hlo_text: str, markers: Iterable[str]) -> list[str]:
    """Which of ``markers`` occur in the lowered text (sorted)."""
    return sorted(m for m in set(markers) if m in hlo_text)


def contains_eigh(hlo_text: str) -> bool:
    return bool(found_markers(hlo_text, eigh_fingerprints()))


def contains_cholesky(hlo_text: str) -> bool:
    return bool(found_markers(hlo_text, cholesky_fingerprints()))


def check_no_eigh(hlo_text: str, where: str = "body") -> list[Violation]:
    hits = found_markers(hlo_text, eigh_fingerprints())
    if not hits:
        return []
    return [Violation(
        rule="no-eigh-hlo",
        message=f"{where} lowers eigh custom calls {hits}: the scanned body "
                "must stay factorization-free (deferred-repair contract)",
    )]


def collective_census(hlo_text: str) -> dict[str, int]:
    """Counts of stablehlo collective ops in the lowered text."""
    return {
        op: len(re.findall(rf"stablehlo\.{op}\b", hlo_text))
        for op in _COLLECTIVE_OPS
    }


# ---------------------------------------------------------------------------
# Donation / aliasing
# ---------------------------------------------------------------------------


def _main_signature(hlo_text: str) -> str:
    """The argument list of the public @main function (balanced parens)."""
    m = re.search(r"func\.func\s+public\s+@main\(", hlo_text)
    if m is None:
        raise ValueError("lowered module has no public @main function")
    start = m.end()  # just past the opening paren
    depth = 1
    for i in range(start, len(hlo_text)):
        ch = hlo_text[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return hlo_text[start:i]
    raise ValueError("unbalanced parens in @main signature")


def aliased_inputs(hlo_text: str) -> dict[int, int]:
    """``{input arg index: output index}`` for every donated-and-aliased
    input of the lowered main function."""
    sig = _main_signature(hlo_text)
    out: dict[int, int] = {}
    # args are "%argN: tensor<...> {attrs}"; attrs never nest braces.
    for am in re.finditer(r"%arg(\d+):[^%]*", sig):
        alias = _ALIAS_RE.search(am.group(0))
        if alias:
            out[int(am.group(1))] = int(alias.group(1))
    return out


def check_donation(hlo_text: str, expected_aliased: int, where: str = "executable") -> list[Violation]:
    """The lowered program must alias exactly ``expected_aliased`` inputs.

    ``expected_aliased`` is the leaf count of the donated arguments (every
    donated leaf has a shape/dtype-matched output in the engine's
    state-in/state-out signature, so ALL of them must alias; fewer means
    XLA dropped a donation and the engine silently double-buffers).
    """
    got = aliased_inputs(hlo_text)
    if len(got) == expected_aliased:
        return []
    return [Violation(
        rule="donation-dropped",
        message=f"{where}: expected {expected_aliased} input-output aliases "
                f"but the lowering carries {len(got)} -- a donated buffer "
                "is being copied instead of reused in place",
    )]
