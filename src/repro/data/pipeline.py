"""Synthetic token pipeline for the LM-training substrate.

No datasets ship in this container, so the pipeline generates structured
synthetic streams (Zipf-distributed unigrams mixed with an order-2 Markov
backbone) -- enough signal that a ~100M model's loss visibly drops within a
few hundred steps in examples/train_lm.py, while staying fully deterministic
per seed.  The iterator yields exactly the batch dict that
``models.input_specs(cfg, 'train_4k')`` promises.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTextConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    zipf_a: float = 1.2
    markov_weight: float = 0.7  # fraction of tokens drawn from the Markov chain
    n_states: int = 97
    seed: int = 0


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks**-a
    return (p / p.sum()).astype(np.float64)


def synthetic_batch(cfg: SyntheticTextConfig, step: int, model_cfg=None) -> dict:
    """Deterministic batch for `step`.  Adds modality stubs when model_cfg
    is a vlm/encdec config."""
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    b, l, v = cfg.batch_size, cfg.seq_len, cfg.vocab_size

    zipf = _zipf_probs(v, cfg.zipf_a)
    uni = rng.choice(v, size=(b, l + 1), p=zipf)

    # order-2 Markov backbone: token ~ f(prev two) via hashing, injects
    # learnable structure
    state = rng.integers(0, cfg.n_states, size=(b,))
    markov = np.empty((b, l + 1), dtype=np.int64)
    prev = rng.integers(0, v, size=(b,))
    for t in range(l + 1):
        nxt = (prev * 2654435761 + state * 97 + t) % v
        markov[:, t] = nxt
        state = (state + nxt) % cfg.n_states
        prev = nxt
    use_markov = rng.random((b, l + 1)) < cfg.markov_weight
    stream = np.where(use_markov, markov, uni)

    batch = {
        "tokens": jnp.asarray(stream[:, :-1], jnp.int32),
        "labels": jnp.asarray(stream[:, 1:], jnp.int32),
    }
    if model_cfg is not None:
        if model_cfg.arch_type == "vlm":
            key = jax.random.PRNGKey(step)
            batch["patches"] = 0.02 * jax.random.normal(
                key, (b, model_cfg.n_patches, model_cfg.d_model), jnp.float32
            )
            pos = jnp.broadcast_to(jnp.arange(l)[None, :, None], (b, l, 3))
            batch["positions"] = pos.astype(jnp.int32)
        if model_cfg.arch_type == "encdec":
            key = jax.random.PRNGKey(step)
            batch["frames"] = 0.02 * jax.random.normal(
                key, (b, model_cfg.enc_seq, model_cfg.d_model), jnp.float32
            )
    return batch


def make_batch_iterator(cfg: SyntheticTextConfig, model_cfg=None, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, step, model_cfg)
        step += 1
