"""Federated heterogeneity partitioners (paper Appx. E.2/E.3).

The paper controls client heterogeneity two ways:

* synthetic: Dirichlet(1/N) weights per dimension (Appx. E.1) -- that lives
  in core/objectives.py;
* real data: each client sees only ``P * n_classes`` label classes
  (Appx. E.2: CIFAR/MNIST attack models; E.3: Covertype metric fine-tuning).
  A larger P means MORE shared classes and hence LESS heterogeneity.

These partitioners operate on label arrays and return per-client index sets
with conservation guarantees (property-tested: no sample duplicated within a
client, every client non-empty).
"""

from __future__ import annotations

import numpy as np


def label_subset_partition(
    labels: np.ndarray,
    n_clients: int,
    p_shared: float,
    seed: int = 0,
    min_per_client: int = 8,
) -> list[np.ndarray]:
    """Paper E.2/E.3: client i samples floor(P * C) classes and takes all
    points of those classes.  P = 1 -> every client sees everything."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    n_take = max(int(round(p_shared * len(classes))), 1)
    out = []
    for _ in range(n_clients):
        chosen = rng.choice(classes, size=n_take, replace=False)
        idx = np.where(np.isin(labels, chosen))[0]
        if len(idx) < min_per_client:
            # Degenerate draw; pad from the COMPLEMENT of the chosen points
            # -- sampling from all points could duplicate an index already
            # in `idx`, violating the no-duplicates-within-a-client
            # guarantee above.
            pool = np.setdiff1d(np.arange(len(labels)), idx)
            take = min(min_per_client - len(idx), len(pool))
            extra = rng.choice(pool, size=take, replace=False)
            idx = np.concatenate([idx, extra])
        out.append(np.sort(idx))
    return out


def dirichlet_partition(
    labels: np.ndarray, n_clients: int, alpha: float, seed: int = 0
) -> list[np.ndarray]:
    """Standard non-IID Dirichlet split: class-c points divided across
    clients with proportions ~ Dir(alpha).  Disjoint and exhaustive."""
    rng = np.random.default_rng(seed)
    out: list[list[int]] = [[] for _ in range(n_clients)]
    for c in np.unique(labels):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for i, part in enumerate(np.split(idx, cuts)):
            out[i].extend(part.tolist())
    return [np.sort(np.array(ix, dtype=np.int64)) for ix in out]
