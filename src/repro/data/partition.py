"""Federated heterogeneity partitioners (paper Appx. E.2/E.3).

The paper controls client heterogeneity two ways:

* synthetic: Dirichlet(1/N) weights per dimension (Appx. E.1) -- that lives
  in core/objectives.py;
* real data: each client sees only ``P * n_classes`` label classes
  (Appx. E.2: CIFAR/MNIST attack models; E.3: Covertype metric fine-tuning).
  A larger P means MORE shared classes and hence LESS heterogeneity.

These partitioners operate on label arrays and return per-client index sets
with conservation guarantees (property-tested: no sample duplicated within a
client, every client non-empty).
"""

from __future__ import annotations

import numpy as np


def _check_n_clients(n_clients: int) -> None:
    if not isinstance(n_clients, (int, np.integer)) or n_clients < 1:
        raise ValueError(f"n_clients={n_clients!r} must be an int >= 1")


def label_subset_partition(
    labels: np.ndarray,
    n_clients: int,
    p_shared: float,
    seed: int = 0,
    min_per_client: int = 8,
) -> list[np.ndarray]:
    """Paper E.2/E.3: client i samples floor(P * C) classes and takes all
    points of those classes.  P = 1 -> every client sees everything."""
    # Validate up front: p_shared > 1 would crash deep inside rng.choice
    # with an opaque "cannot take a larger sample" error, and p_shared <= 0
    # would silently degenerate to 1 class per client.
    _check_n_clients(n_clients)
    if not (np.isfinite(p_shared) and 0.0 < p_shared <= 1.0):
        raise ValueError(
            f"p_shared={p_shared!r} must be a fraction in (0, 1] of the label "
            "classes each client sees (paper Appx. E.2: larger P = less "
            "heterogeneity)"
        )
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    n_take = max(int(round(p_shared * len(classes))), 1)
    out = []
    for _ in range(n_clients):
        chosen = rng.choice(classes, size=n_take, replace=False)
        idx = np.where(np.isin(labels, chosen))[0]
        if len(idx) < min_per_client:
            # Degenerate draw; pad from the COMPLEMENT of the chosen points
            # -- sampling from all points could duplicate an index already
            # in `idx`, violating the no-duplicates-within-a-client
            # guarantee above.
            pool = np.setdiff1d(np.arange(len(labels)), idx)
            take = min(min_per_client - len(idx), len(pool))
            extra = rng.choice(pool, size=take, replace=False)
            idx = np.concatenate([idx, extra])
        out.append(np.sort(idx))
    return out


def dirichlet_partition(
    labels: np.ndarray, n_clients: int, alpha: float, seed: int = 0
) -> list[np.ndarray]:
    """Standard non-IID Dirichlet split: class-c points divided across
    clients with proportions ~ Dir(alpha).  Disjoint and exhaustive."""
    # alpha <= 0 is outside the Dirichlet domain; numpy "accepts" it and
    # returns NaN proportions, silently emptying every client.
    _check_n_clients(n_clients)
    if not (np.isfinite(alpha) and alpha > 0.0):
        raise ValueError(
            f"alpha={alpha!r} must be a positive finite Dirichlet "
            "concentration (smaller alpha = more heterogeneity)"
        )
    rng = np.random.default_rng(seed)
    out: list[list[int]] = [[] for _ in range(n_clients)]
    for c in np.unique(labels):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for i, part in enumerate(np.split(idx, cuts)):
            out[i].extend(part.tolist())
    return [np.sort(np.array(ix, dtype=np.int64)) for ix in out]
