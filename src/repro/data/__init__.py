from repro.data.pipeline import (  # noqa: F401
    SyntheticTextConfig,
    make_batch_iterator,
    synthetic_batch,
)
from repro.data.partition import (  # noqa: F401
    dirichlet_partition,
    label_subset_partition,
)
