"""Paper Fig. 1: communication + query efficiency of FZooS vs FedZO /
FedProx / SCAFFOLD (I/II) on heterogeneous synthetic quadratics with
varying C.

CPU-scale reduction of Appx. E.1: d (300 -> 40/100), R (50 -> 20/35),
N = 5 as in the paper.  Reported per (algo, C): best F, rounds/queries to
reach the epsilon target, and mean wall time per round.
"""

from __future__ import annotations

import jax

from benchmarks.common import Row, algo_config, best_f, queries_at_round, rounds_to_target, run_algo
from repro.core import objectives as obj

ALGOS = ("fzoos", "fedzo", "fedprox", "scaffold1", "scaffold2")


def run(quick: bool = True) -> list[Row]:
    d = 40 if quick else 100
    rounds = 20 if quick else 35
    n = 5
    eps_gap = 0.35  # target: close 65% of the F(x0)->F* gap
    rows = []
    for c_het in (0.5, 5.0) if quick else (0.5, 5.0, 50.0):
        key = jax.random.PRNGKey(0)
        cobjs = obj.make_quadratic(key, n, d, c_het, 0.001)
        f0 = float(obj.quadratic_global_value(cobjs, jax.numpy.full((d,), 0.5)))
        fstar = obj.quadratic_fstar(d)
        target = fstar + eps_gap * (f0 - fstar)
        for name in ALGOS:
            cfg = algo_config(name, d, n,
                              n_features=256 if quick else 512,
                              traj_capacity=128 if quick else 192)
            res, dt = run_algo(cfg, jax.random.PRNGKey(1), cobjs,
                               obj.quadratic_query, obj.quadratic_global_value, rounds)
            r_hit = rounds_to_target(res.f_values, target)
            rows.append(Row(
                name=f"fig1/{name}/C={c_het}",
                us_per_call=dt / rounds * 1e6,
                derived=(f"bestF={best_f(res):+.4f};F*={fstar:+.4f};"
                         f"rounds_to_eps={r_hit};"
                         f"queries_to_eps={queries_at_round(res, r_hit) if r_hit >= 0 else -1};"
                         f"queries_total={int(res.queries[-1])}"),
            ))
    return rows
