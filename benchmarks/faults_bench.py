"""Fault-tolerant engine benchmark (ISSUE 8 tentpole): the cost of the
masked participation-weighted aggregation, and the recovery machinery.

Three questions, one config (N=64 clients, the engine-comparison scale):

  * **mask overhead** -- the faults-off engine (``faults=None``, the
    structurally unchanged pre-fault path) vs the fault-tolerant engine
    with ALL rates zero (``--fault-tolerance``): the masking, count
    packing and renormalized psum mean with nothing ever faulted.  The
    zero-rate draws lower to static constants, so this measures the pure
    arithmetic of the mask/renorm path -- the ISSUE's "~0 at N=64" claim.
    Both ms/round numbers are gated in CI; the ratio is informational.
  * **faulted throughput** -- the same engine under the ISSUE acceptance
    fault mix (20% dropout + 5% NaN payloads): masked aggregation with
    live fault draws, quarantine set/reset traffic, and the per-chunk
    gated reset dispatch.  Deterministic schedule, so the measured
    drop/quarantine rates are stable across runs (informational).
  * **recovery latency** -- the dominant cost of a chunk rollback: the
    checkpoint restore (npz read + checksum verify + device_put of the
    full ClientState + history).  Wall-clock file I/O, machine-dependent:
    informational ``_msec``, not gated.

Like ``rounds_bench``, every timed loop runs around ONE pre-warmed donated
chunk step so compile time stays out of the measurement; best-of-REPEATS.
"""

from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.checkpoint import io as ckpt_io
from repro.core import algorithms as alg
from repro.core import objectives as obj
from repro.core import rff as rfflib
from repro.core import rounds as rounds_mod
from repro.faults import FaultConfig
from repro.launch import common as launch_common

_JSON_PAYLOAD: dict = {}

CHUNK = 8
DIM = 4
N_CLIENTS = 64
REPEATS = 3

#: moderate per-round fzoos compute (the boundary-bench config): enough
#: surrogate work that the round time is real, small enough that the
#: masked-aggregation delta is not drowned by eigh noise.
FAULT_CFG = dict(local_steps=1, n_features=32, traj_capacity=64,
                 active_per_iter=2, active_candidates=32,
                 active_round_end=2, lengthscale=0.5, noise=1e-5)

#: the ISSUE acceptance fault mix: 20% dropout + 5% NaN payloads.
FAULT_MIX = dict(seed=0, drop_rate=0.2, nan_rate=0.05, tolerate=True)


def json_payload() -> dict:
    return _JSON_PAYLOAD


def _setup():
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, N_CLIENTS, DIM, 5.0, 0.001)
    cfg = launch_common.make_config("fzoos", dim=DIM, n_clients=N_CLIENTS,
                                    **FAULT_CFG)
    x0 = jnp.full((DIM,), 0.5, jnp.float32)
    rff = rfflib.make_rff(jax.random.PRNGKey(1), cfg.n_features, DIM,
                          cfg.lengthscale)
    return cfg, cobjs, rff, x0


def _bench_engine(faults: FaultConfig | None, rounds: int) -> dict:
    """Steady-state ms/round of the simulated vmapped fzoos engine, with the
    per-chunk boundary quarantine-reset dispatch included when tolerant
    (that gated cond IS part of the fault-tolerant driver loop)."""
    cfg, cobjs, rff, x0 = _setup()
    query, gval = obj.quadratic_query, obj.quadratic_global_value
    tolerant = faults is not None and faults.tolerate

    step = rounds_mod.make_chunk_step(
        rounds_mod.sim_chunk_fn(cfg, rff, query, gval, None, CHUNK,
                                faults=faults)
    )

    def fresh():
        states = alg.init_states(cfg, jax.random.PRNGKey(2), x0)
        hist = rounds_mod.history_init(rounds, x0, gval(cobjs, x0))
        return states, hist

    s_w, h_w = fresh()
    s_w, h_w, sx_w = step(s_w, h_w, cobjs, x0, jnp.int32(0))  # compile chunk
    if tolerant:
        s_w = rounds_mod.boundary_quarantine_reset(s_w, cfg, sx_w)  # compile
    jax.block_until_ready(s_w.x)

    def time_once() -> tuple[float, alg.SimResult]:
        states, hist = fresh()
        jax.block_until_ready((states.x, hist.xs))
        sx = x0
        t0 = time.time()
        for off in range(0, rounds, CHUNK):
            states, hist, sx = step(states, hist, cobjs, sx, jnp.int32(off))
            if tolerant:
                states = rounds_mod.boundary_quarantine_reset(states, cfg, sx)
        jax.block_until_ready(hist.xs)
        return time.time() - t0, hist

    best, hist = float("inf"), None
    for _ in range(REPEATS):
        dt, hist = time_once()
        best = min(best, dt)
    pr = best / rounds
    return {
        "n_clients": N_CLIENTS,
        "ms_per_round": pr * 1e3,
        "rounds_per_sec": 1.0 / pr,
        "drop_rate": float(jnp.mean(hist.drop_rate[:rounds])),
        "quarantine_rate": float(jnp.mean(hist.quarantine_rate[:rounds])),
        "rounds_measured": rounds,
    }


def _bench_recovery(rounds: int) -> dict:
    """Rollback recovery cost: restore a boundary checkpoint of the full
    N=64 ClientState + history from disk back onto devices.  This is what a
    poisoned chunk pays on top of re-running it with tolerance forced on."""
    cfg, cobjs, rff, x0 = _setup()
    states = alg.init_states(cfg, jax.random.PRNGKey(2), x0)
    hist = rounds_mod.history_init(rounds, x0,
                                   obj.quadratic_global_value(cobjs, x0))
    jax.block_until_ready(states.x)
    with tempfile.TemporaryDirectory() as td:
        ckpt_io.save_round_state(td, CHUNK, states, hist)
        # warm-up read (page cache, jit of device_put paths)
        ckpt_io.restore_round_state(td, states, hist)
        best = float("inf")
        for _ in range(REPEATS):
            t0 = time.time()
            s, h, step = ckpt_io.restore_round_state(td, states, hist)
            jax.block_until_ready((s.x, h.xs))
            best = min(best, time.time() - t0)
    return {"recovery_restore_msec": best * 1e3, "restored_step": int(step)}


def run(quick: bool) -> list[Row]:
    rounds = 2 * CHUNK if quick else 4 * CHUNK
    rows: list[Row] = []
    _JSON_PAYLOAD.clear()
    _JSON_PAYLOAD.update({
        "chunk": CHUNK, "dim": DIM, "n_clients": N_CLIENTS,
        "engine_config": dict(FAULT_CFG), "fault_mix": dict(FAULT_MIX),
        "quick": bool(quick),
    })

    m_off = _bench_engine(None, rounds)
    m_mask = _bench_engine(FaultConfig(seed=0, tolerate=True), rounds)
    m_fault = _bench_engine(FaultConfig(**FAULT_MIX), rounds)
    rec = _bench_recovery(rounds)

    overhead = m_mask["ms_per_round"] / m_off["ms_per_round"]
    _JSON_PAYLOAD["mask_overhead_n64"] = {
        "faults_off_ms_per_round": m_off["ms_per_round"],
        "masked_ms_per_round": m_mask["ms_per_round"],
        "faults_off_rounds_per_sec": m_off["rounds_per_sec"],
        "masked_rounds_per_sec": m_mask["rounds_per_sec"],
        "mask_overhead_ratio": overhead,
        "n_clients": N_CLIENTS,
        "rounds_measured": rounds,
    }
    _JSON_PAYLOAD["faulted_n64"] = m_fault
    _JSON_PAYLOAD["recovery"] = rec

    rows.append(Row(
        name="faults_off_n64",
        us_per_call=m_off["ms_per_round"] * 1e3,
        derived=f"rounds_per_sec={m_off['rounds_per_sec']:.2f}",
    ))
    rows.append(Row(
        name="faults_masked_zero_rate_n64",
        us_per_call=m_mask["ms_per_round"] * 1e3,
        derived=(f"rounds_per_sec={m_mask['rounds_per_sec']:.2f};"
                 f"mask_overhead_ratio={overhead:.3f}x"),
    ))
    rows.append(Row(
        name="faults_drop20_nan5_n64",
        us_per_call=m_fault["ms_per_round"] * 1e3,
        derived=(f"rounds_per_sec={m_fault['rounds_per_sec']:.2f};"
                 f"drop_rate={m_fault['drop_rate']:.3f};"
                 f"quarantine_rate={m_fault['quarantine_rate']:.3f}"),
    ))
    rows.append(Row(
        name="faults_recovery_restore",
        us_per_call=rec["recovery_restore_msec"] * 1e3,
        derived=f"restored_step={rec['restored_step']}",
    ))
    return rows
