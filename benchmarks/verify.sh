#!/usr/bin/env bash
# CI smoke gate: tier-1 tests + benchmark regression check.
#
#   bash benchmarks/verify.sh            # full tier-1 + bench compare
#   bash benchmarks/verify.sh --static   # static gate only: contract
#                                        # analyzer + ruff (no execution)
#   bash benchmarks/verify.sh --faults   # fault-tolerance gate: the fault
#                                        # test suite + BENCH_faults compare
#   bash benchmarks/verify.sh --pool     # partial-participation gate: the
#                                        # pool equivalence suite + the
#                                        # BENCH_rounds pool-section compare
#   BENCH_TOL=0.5 bash benchmarks/verify.sh
#   BENCH_ONLY=rounds,kernels bash benchmarks/verify.sh
#
# The bench step runs `benchmarks/run.py --compare`, which diffs a fresh
# quick-mode run against the COMMITTED BENCH_*.json files and exits nonzero
# on any perf metric regressing by more than BENCH_TOL (relative) -- so a
# perf regression fails the PR instead of silently overwriting the JSONs.
# The default tolerance is deliberately loose (50%): CI boxes are noisy and
# the gate is for catching engine-level regressions, not 5% drift.
#
# --static runs the compiled-program contract analyzer (DESIGN.md Sec. 7:
# python -m repro.analysis lowers every registered engine entry point and
# lints jaxpr + HLO, no execution) plus `ruff check` at the version pinned
# in pyproject.toml.  ruff is not baked into every image, so its absence is
# a LOUD skip, not a failure -- CI installs it and gets the full gate.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BENCH_TOL="${BENCH_TOL:-0.5}"
BENCH_ONLY="${BENCH_ONLY:-rounds,kernels}"

if [[ "${1:-}" == "--static" ]]; then
    echo "== static gate: compiled-program contracts =="
    # Engine contracts + the Pallas kernel-launch audit + the PRNG key-flow
    # lint, with the machine-readable report CI uploads as an artifact.
    # CONTRACT_FLOOR guards against registrations silently vanishing (e.g.
    # an import-time exception swallowing half the registry).
    CONTRACT_FLOOR="${CONTRACT_FLOOR:-27}"
    REPORT="${ANALYSIS_REPORT:-analysis_report.json}"
    python -m repro.analysis --json "${REPORT}"
    N_CONTRACTS=$(python -c "import json; print(json.load(open('${REPORT}'))['n_contracts'])")
    echo "static gate: ${N_CONTRACTS} contract(s) ran (floor ${CONTRACT_FLOOR}), report: ${REPORT}"
    if [[ "${N_CONTRACTS}" -lt "${CONTRACT_FLOOR}" ]]; then
        echo "ERROR: only ${N_CONTRACTS} contracts ran, below the floor of ${CONTRACT_FLOOR}" >&2
        exit 1
    fi

    echo "== static gate: ruff =="
    if command -v ruff >/dev/null 2>&1; then
        ruff check .
    else
        echo "WARNING: ruff not installed -- SKIPPING the lint half of the" >&2
        echo "WARNING: static gate (pip install ruff to match CI)" >&2
    fi
    echo "verify --static: OK"
    exit 0
fi

if [[ "${1:-}" == "--faults" ]]; then
    # Robustness gate (ISSUE 8): the fault-injection suite end to end --
    # deterministic schedules, faults-off bitwise identity, quarantine
    # reset vs the fresh-init oracle, corrupt-checkpoint fallback and
    # chunk rollback -- then the masked-aggregation overhead compare
    # against the committed BENCH_faults.json.
    echo "== fault-tolerance gate: test suite =="
    python -m pytest -x -q tests/test_faults.py

    echo "== fault-tolerance gate: mask-overhead regression =="
    python -m benchmarks.run --only faults --compare --compare-tol "${BENCH_TOL}"

    echo "verify --faults: OK"
    exit 0
fi

if [[ "${1:-}" == "--pool" ]]; then
    # Partial-participation gate (ISSUE 9): the client-pool suite end to
    # end -- deterministic cohort sampling, K=N bitwise identity against
    # the dense engine (sim + distributed), pooled checkpoint resume, one
    # cohort executable across rounds -- then the pooled-vs-dense round
    # timing compare against the committed BENCH_rounds.json pool section.
    echo "== partial-participation gate: test suite =="
    python -m pytest -x -q tests/test_pool.py

    echo "== partial-participation gate: pooled-round regression =="
    python -m benchmarks.run --only rounds --compare --compare-tol "${BENCH_TOL}"

    echo "verify --pool: OK"
    exit 0
fi

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== detected backend =="
python -c "from benchmarks.run import backend_identity; b = backend_identity(); \
print(f\"backend={b['platform']} device_kind={b['device_kind']}\")"

echo "== benchmark regression gate (--only ${BENCH_ONLY}, tol ${BENCH_TOL}) =="
python -m benchmarks.run --only "${BENCH_ONLY}" --compare --compare-tol "${BENCH_TOL}"

echo "verify: OK"
