"""Round-driver benchmark: the seed one-dispatch-per-round Python loop vs
the chunked on-device scan engine (core/rounds.py, ISSUE 2 tentpole).

The kernels benchmark covers the surrogate math; this one isolates the
DRIVER overhead the scan engine removes -- per-round jit dispatch plus the
host-roundtrip eval of the un-jitted ``global_value_fn`` (an eager vmap
that re-traces every round).  Two regimes at N in {8, 64} clients:

  * ``fedzo`` -- the query-parsimonious many-cheap-rounds regime the round
    engine exists for (FedZeN-style): per-round compute is tiny, so the
    driver tax IS the round time and the scan engine's win is largest;
  * ``fzoos`` -- the surrogate method's fuller per-round compute, showing
    how the win shrinks as on-device work grows (the overhead pipelines
    under compute once rounds are a few ms).

Each driver is reduced to its steady-state inner loop around ONE pre-warmed
executable (the per-round jit for the seed loop, the donated chunk step for
the scan engine), so compile time and jit-cache misses stay out of the
measurement; wall time per round is best-of-``REPEATS`` over a fixed span.

Dispatches/round counts host->device program launches issued by Python:
the seed loop pays 1 jitted round call + 1 eager global-value eval per
round; the scan engine pays 1 chunk call per ``chunk`` rounds.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import algorithms as alg
from repro.core import objectives as obj
from repro.core import rff as rfflib
from repro.core import rounds as rounds_mod
from repro.launch import common as launch_common

#: filled by run(); run.py serializes it to BENCH_rounds.json.  The driver
#: configs are fixed regardless of quick/full mode so the file stays
#: comparable across PRs; only the measured round span changes.
_JSON_PAYLOAD: dict = {}

CHUNK = 8
DIM = 4
REPEATS = 3
_ALGOS = {
    # dispatch-bound: 1 local step, 3 queries/round -- the cheap-round regime
    "fedzo": dict(local_steps=1, q=2, fd_lambda=5e-3),
    # surrogate compute: Gram cap 8, M=16 RFF fit, 1 round-end active query
    "fzoos": dict(local_steps=1, n_features=16, traj_capacity=8,
                  active_per_iter=0, active_candidates=8, active_round_end=1),
}


def json_payload() -> dict:
    return _JSON_PAYLOAD


#: the deferred-repair engine comparison (ISSUE 3 tentpole): the PR 2 scan
#: engine with the inline-cond factor fallback (defer_repair=False; under
#: the client vmap every append event materializes the O(cap^3) eigh) vs
#: the branch-free deferred engine with client-batched kernels, at the
#: paper's trajectory window cap=128.
ENGINE_CFG = dict(local_steps=2, n_features=64, traj_capacity=128,
                  active_per_iter=5, active_candidates=64, active_round_end=5,
                  lengthscale=0.5, noise=1e-5)


def _bench_one(algo: str, n_clients: int, rounds: int) -> dict:
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, n_clients, DIM, 5.0, 0.001)
    cfg = launch_common.make_config(algo, dim=DIM, n_clients=n_clients,
                                    lengthscale=0.5, noise=1e-5, **_ALGOS[algo])
    x0 = jnp.full((DIM,), 0.5, jnp.float32)
    rff = None
    if cfg.is_fzoos:
        rff = rfflib.make_rff(jax.random.PRNGKey(1), cfg.n_features, DIM,
                              cfg.lengthscale)
    query, gval = obj.quadratic_query, obj.quadratic_global_value
    mean_fn = lambda tree: jax.tree_util.tree_map(lambda a: jnp.mean(a, axis=0), tree)

    def fresh_states():
        return alg.init_states(cfg, jax.random.PRNGKey(2), x0)

    # -- seed driver inner loop: one jitted round + one eager F eval per round
    round_jit = jax.jit(
        lambda s, sx: alg.run_round(cfg, rff, query, cobjs, s, sx, mean_fn, None)
    )
    jax.block_until_ready(round_jit(fresh_states(), x0)[1].server_x)  # compile

    def time_old() -> float:
        states, sx = fresh_states(), x0
        jax.block_until_ready(states.x)
        fvals = [gval(cobjs, sx)]
        t0 = time.time()
        for _ in range(rounds):
            states, stats = round_jit(states, sx)
            sx = stats.server_x
            fvals.append(gval(cobjs, sx))
        jax.block_until_ready((sx, fvals))
        return time.time() - t0

    # -- scan engine inner loop: one donated chunk step per CHUNK rounds
    step = rounds_mod.make_chunk_step(
        rounds_mod.sim_chunk_fn(cfg, rff, query, gval, None, CHUNK)
    )

    def fresh_run_state():
        hist = rounds_mod.history_init(rounds, x0, gval(cobjs, x0))
        return fresh_states(), hist

    s_w, h_w = fresh_run_state()
    jax.block_until_ready(step(s_w, h_w, cobjs, x0, jnp.int32(0))[2])  # compile

    def time_new() -> float:
        states, hist = fresh_run_state()
        jax.block_until_ready((states.x, hist.xs))
        sx = x0
        t0 = time.time()
        for off in range(0, rounds, CHUNK):
            states, hist, sx = step(states, hist, cobjs, sx, jnp.int32(off))
        jax.block_until_ready(hist.xs)
        return time.time() - t0

    old_pr = min(time_old() for _ in range(REPEATS)) / rounds
    new_pr = min(time_new() for _ in range(REPEATS)) / rounds
    return {
        "algo": algo,
        "n_clients": n_clients,
        "old_ms_per_round": old_pr * 1e3,
        "new_ms_per_round": new_pr * 1e3,
        "old_rounds_per_sec": 1.0 / old_pr,
        "new_rounds_per_sec": 1.0 / new_pr,
        "speedup": old_pr / new_pr,
        "old_dispatches_per_round": 2.0,
        "new_dispatches_per_round": 1.0 / CHUNK,
        "rounds_measured": rounds,
    }


def _bench_engine(n_clients: int, rounds: int, defer: bool) -> dict:
    """Steady-state ms/round of the SCANNED vmapped fzoos engine at cap=128.

    ``defer=False`` is the PR 2 engine (inline-cond clamped-eigh fallback,
    per-client vmapped kernels); ``defer=True`` is the deferred-repair
    branch-free engine with client-batched kernels.  Both run through the
    same pre-warmed donated chunk step, so the measured delta is the round
    BODY, not driver overhead.
    """
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, n_clients, DIM, 5.0, 0.001)
    cfg = launch_common.make_config("fzoos", dim=DIM, n_clients=n_clients,
                                    defer_repair=defer, **ENGINE_CFG)
    x0 = jnp.full((DIM,), 0.5, jnp.float32)
    rff = rfflib.make_rff(jax.random.PRNGKey(1), cfg.n_features, DIM, cfg.lengthscale)
    query, gval = obj.quadratic_query, obj.quadratic_global_value

    step = rounds_mod.make_chunk_step(
        rounds_mod.sim_chunk_fn(cfg, rff, query, gval, None, CHUNK)
    )

    def fresh():
        states = alg.init_states(cfg, jax.random.PRNGKey(2), x0)
        hist = rounds_mod.history_init(rounds, x0, gval(cobjs, x0))
        return states, hist

    s_w, h_w = fresh()
    s_w, h_w, _ = step(s_w, h_w, cobjs, x0, jnp.int32(0))  # compile chunk
    if defer:
        s_w = rounds_mod.boundary_repair_on_device(s_w, cfg)  # compile boundary
    jax.block_until_ready(s_w.x)

    def time_once() -> tuple[float, float]:
        states, hist = fresh()
        jax.block_until_ready((states.x, hist.xs))
        sx = x0
        t0 = time.time()
        for off in range(0, rounds, CHUNK):
            states, hist, sx = step(states, hist, cobjs, sx, jnp.int32(off))
            if defer:
                # production boundary: device-decided repair, no host sync
                states = rounds_mod.boundary_repair_on_device(states, cfg)
        jax.block_until_ready(hist.xs)
        dt = time.time() - t0
        rep = float(jnp.nanmean(hist.repair_rate[:rounds]))
        return dt, rep

    best, rep = float("inf"), 0.0
    for _ in range(REPEATS):
        dt, rep = time_once()
        best = min(best, dt)
    pr = best / rounds
    return {
        "defer_repair": defer,
        "n_clients": n_clients,
        "traj_capacity": ENGINE_CFG["traj_capacity"],
        "ms_per_round": pr * 1e3,
        "rounds_per_sec": 1.0 / pr,
        "repair_rate": rep,
        "rounds_measured": rounds,
    }


#: partial-participation benchmark shape (client pool, core/pool.py): the
#: pool holds N=256 clients on the host; only the K=64 cohort ever touches
#: the device.  The dense comparison runs the SAME K=64 clients through the
#: plain scan engine, so the pooled-vs-dense delta isolates what partial
#: participation adds: the host gather/scatter at each chunk boundary plus
#: the zero-rate masked aggregation the pooled body always carries.
POOL_N, POOL_K = 256, 64


def _bench_pool(pool_size: int, cohort: int, rounds: int) -> dict:
    """Steady-state ms/round of the pooled engine (N on host, K on device)
    vs the dense engine at n_clients=K -- same mesh footprint, ONE cohort
    executable reused across every sampled cohort (pool.run_pooled_rounds
    keys its step cache on K, not on the member ids)."""
    import dataclasses

    from repro.core import pool as pool_mod
    from repro.faults import FaultConfig

    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, pool_size, DIM, 5.0, 0.001)
    cfg = launch_common.make_config("fedzo", dim=DIM, n_clients=pool_size,
                                    lengthscale=0.5, noise=1e-5,
                                    **_ALGOS["fedzo"])
    ccfg = dataclasses.replace(cfg, n_clients=cohort)
    x0 = jnp.full((DIM,), 0.5, jnp.float32)
    query, gval = obj.quadratic_query, obj.quadratic_global_value
    cobjs_host = jax.device_get(cobjs)

    # -- dense engine at K clients: the mesh-footprint-matched baseline
    dense_cobjs = jax.tree_util.tree_map(lambda a: jnp.asarray(a[:cohort]),
                                         cobjs_host)
    dense_step = rounds_mod.make_chunk_step(
        rounds_mod.sim_chunk_fn(ccfg, None, query, gval, None, CHUNK)
    )

    def fresh_dense():
        states = alg.init_states(ccfg, jax.random.PRNGKey(2), x0)
        hist = rounds_mod.history_init(rounds, x0, gval(dense_cobjs, x0))
        return states, hist

    s_w, h_w = fresh_dense()
    jax.block_until_ready(dense_step(s_w, h_w, dense_cobjs, x0, jnp.int32(0))[2])

    def time_dense() -> float:
        states, hist = fresh_dense()
        jax.block_until_ready((states.x, hist.xs))
        sx = x0
        t0 = time.time()
        for off in range(0, rounds, CHUNK):
            states, hist, sx = dense_step(states, hist, dense_cobjs, sx,
                                          jnp.int32(off))
        jax.block_until_ready(hist.xs)
        return time.time() - t0

    # -- pooled engine: the run_pooled_rounds steady-state inner loop (the
    # zero-rate masked body it always compiles), minus checkpoint I/O
    pooled_step = rounds_mod.make_chunk_step(
        rounds_mod.sim_chunk_fn(ccfg, None, query, gval, None, CHUNK,
                                faults=FaultConfig())
    )

    def fresh_pool():
        pool = pool_mod.init_pool(cfg, jax.random.PRNGKey(2), x0)
        hist = rounds_mod.history_init(rounds, x0, gval(cobjs, x0))
        return pool, hist

    pool_w, h_w = fresh_pool()
    idx_w = pool_mod.sample_cohort(0, 0, pool_size, cohort)
    cs_w = pool_w.gather(idx_w)
    co_w = jax.tree_util.tree_map(lambda a: jnp.asarray(a[idx_w]), cobjs_host)
    jax.block_until_ready(pooled_step(cs_w, h_w, co_w, x0, jnp.int32(0))[2])

    def time_pooled() -> float:
        pool, hist = fresh_pool()
        jax.block_until_ready(hist.xs)
        sx = x0
        t0 = time.time()
        for off in range(0, rounds, CHUNK):
            idx = pool_mod.sample_cohort(0, off, pool_size, cohort)
            cstates = pool.gather(idx)
            cco = jax.tree_util.tree_map(lambda a: jnp.asarray(a[idx]),
                                         cobjs_host)
            cstates, hist, sx = pooled_step(cstates, hist, cco, sx,
                                            jnp.int32(off))
            pool.scatter(idx, cstates)
        jax.block_until_ready(hist.xs)
        return time.time() - t0

    # -- isolated gather/scatter boundary cost (host indexing + transfers)
    def time_gather_scatter() -> float:
        pool, _ = fresh_pool()
        best = float("inf")
        for off in range(8):
            t0 = time.time()
            idx = pool_mod.sample_cohort(0, off, pool_size, cohort)
            cstates = pool.gather(idx)
            jax.block_until_ready(cstates.x)
            pool.scatter(idx, cstates)
            best = min(best, time.time() - t0)
        return best

    dense_pr = min(time_dense() for _ in range(REPEATS)) / rounds
    pooled_pr = min(time_pooled() for _ in range(REPEATS)) / rounds
    return {
        "pool_size": pool_size,
        "cohort": cohort,
        "dense_ms_per_round": dense_pr * 1e3,
        "pooled_ms_per_round": pooled_pr * 1e3,
        "dense_rounds_per_sec": 1.0 / dense_pr,
        "pooled_rounds_per_sec": 1.0 / pooled_pr,
        "pool_overhead_ratio": pooled_pr / dense_pr,
        "gather_scatter_msec": time_gather_scatter() * 1e3,
        "rounds_measured": rounds,
    }


#: boundary-overhead benchmark config (ISSUE 5 tentpole): moderate per-round
#: compute so the BOUNDARY work (repair decision + checkpoint write) is
#: visible against the chunk, at N=64 clients like the engine comparison.
BOUNDARY_CFG = dict(local_steps=1, n_features=32, traj_capacity=64,
                    active_per_iter=2, active_candidates=32,
                    active_round_end=2, lengthscale=0.5, noise=1e-5)


def _bench_boundary(n_clients: int, boundaries: int) -> dict:
    """DISPATCH-GAP latency per chunk boundary: the ms the Python driver
    spends between dispatching chunk k and being free to dispatch chunk k+1.

    That gap is the boundary cost that matters -- on a pod the device keeps
    computing regardless, so driver stall is what serializes the pipeline.
    (An end-to-end wall-clock loop cannot isolate it on a CPU-only box: the
    background write contends with chunk compute for the same cores, which
    a real host+accelerator pair does not.)

      * ``pr3_host``: the PR 3 boundary -- host flag read
        (`repair_flagged_clients`) + blocking single-file
        `save_round_state` (device_get of everything + inline npz write);
      * ``zerosync``: device-decided repair dispatch + host snapshot
        (`prepare_round_state`) + background-write submit.  The write
        itself is drained OUTSIDE the timed region, emulating steady state
        where it completes under the next chunk's multi-hundred-ms compute
        (`scan_only` chunks here run ~0.4 s, writes measure ~18 ms).

    Idle-device measurement understates the pr3 gap if anything (its
    device_get would also flush in-flight compute), so the comparison is
    conservative.  Also reports the isolated repair-decision latencies and
    the snapshot/write component costs.
    """
    import tempfile
    from functools import partial

    from repro.checkpoint import io as ckpt_io

    cfg = launch_common.make_config("fzoos", dim=DIM, n_clients=n_clients,
                                    **BOUNDARY_CFG)
    x0 = jnp.full((DIM,), 0.5, jnp.float32)

    def fresh():
        states = alg.init_states(cfg, jax.random.PRNGKey(2), x0)
        hist = rounds_mod.history_init(8 * CHUNK, x0, jnp.zeros((), jnp.float32))
        return states, hist

    states, hist = fresh()
    states = rounds_mod.boundary_repair_on_device(states, cfg)  # compile
    jax.block_until_ready(states.x)

    # -- isolated repair-decision latency (healthy flags, the steady state)
    reps = 20
    t0 = time.time()
    for _ in range(reps):
        states, _ = rounds_mod.repair_flagged_clients(states, cfg)
    host_us = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        states = rounds_mod.boundary_repair_on_device(states, cfg)
    jax.block_until_ready(states.factor.gram)
    dev_us = (time.time() - t0) / reps * 1e6

    # -- checkpoint component costs (informational)
    payload = ckpt_io.prepare_round_state(states, hist)
    t0 = time.time()
    for _ in range(5):
        payload = ckpt_io.prepare_round_state(states, hist)
    prep_ms = (time.time() - t0) / 5 * 1e3
    with tempfile.TemporaryDirectory() as td:
        t0 = time.time()
        for i in range(5):
            ckpt_io.write_round_state(td, i, payload)
        write_ms = (time.time() - t0) / 5 * 1e3

    # -- full boundary dispatch gap, best-of over `boundaries` boundaries
    def pr3_gap():
        s, h = fresh()
        jax.block_until_ready(s.x)
        best = float("inf")
        with tempfile.TemporaryDirectory() as td:
            for i in range(boundaries):
                t0 = time.time()
                s, _ = rounds_mod.repair_flagged_clients(s, cfg)
                ckpt_io.save_round_state(td, i, s, h)
                best = min(best, time.time() - t0)
        return best

    def zerosync_gap():
        s, h = fresh()
        jax.block_until_ready(s.x)
        best = float("inf")
        with tempfile.TemporaryDirectory() as td:
            writer = ckpt_io.AsyncCheckpointWriter()
            for i in range(boundaries):
                t0 = time.time()
                s = rounds_mod.boundary_repair_on_device(s, cfg)
                p = ckpt_io.prepare_round_state(s, h)
                writer.submit(partial(ckpt_io.write_round_state, td, i, p))
                best = min(best, time.time() - t0)
                writer.wait()  # untimed: the write hides under the next chunk
        return best

    # Floor at the timer resolution (0.05 ms) instead of 0: compare_payload
    # skips metrics whose committed baseline is <= 0, and a literal 0.0
    # would permanently exempt the zero-sync boundary from the CI gate.
    # (The deterministic no-device_get assertion in test_deferred_repair.py
    # is the primary guard; this metric tracks magnitude.)  The component
    # decompositions below use `_usec`/`_msec` key spellings ON PURPOSE:
    # they are informational microsecond-scale wall timings that vary
    # machine to machine, and the `_us`/`_ms` suffixes would put them under
    # the --compare regression gate (run.py _LOWER_BETTER).
    floor_ms = 0.05
    return {
        "n_clients": n_clients,
        "chunk": CHUNK,
        "traj_capacity": BOUNDARY_CFG["traj_capacity"],
        "pr3_host_ms_per_boundary": max(pr3_gap() * 1e3, floor_ms),
        "zerosync_ms_per_boundary": max(zerosync_gap() * 1e3, floor_ms),
        "repair_decide_host_usec": host_us,
        "repair_decide_device_usec": dev_us,
        "ckpt_prepare_msec": prep_ms,
        "ckpt_write_msec": write_ms,
        "boundaries_measured": boundaries,
    }


def run(quick: bool) -> list[Row]:
    rounds = 4 * CHUNK if quick else 12 * CHUNK
    rows = []
    _JSON_PAYLOAD.clear()
    _JSON_PAYLOAD.update(
        {"chunk": CHUNK, "dim": DIM, "configs": {k: dict(v) for k, v in _ALGOS.items()},
         "engine_config": dict(ENGINE_CFG), "quick": bool(quick)}
    )
    for algo in _ALGOS:
        for n in (8, 64):
            m = _bench_one(algo, n, rounds)
            _JSON_PAYLOAD[f"{algo}_n{n}"] = m
            for drv in ("old", "new"):
                rows.append(Row(
                    name=f"round_driver_{algo}_{drv}_n{n}",
                    us_per_call=m[f"{drv}_ms_per_round"] * 1e3,
                    derived=(f"rounds_per_sec={m[f'{drv}_rounds_per_sec']:.1f};"
                             f"dispatches_per_round={m[f'{drv}_dispatches_per_round']:g}"
                             + (f";speedup={m['speedup']:.2f}x" if drv == "new" else "")),
                ))

    # -- vmapped-engine body: PR 2 inline-cond vs deferred-repair (cap=128)
    eng_rounds = CHUNK if quick else 2 * CHUNK
    for n in (8, 64):
        m_old = _bench_engine(n, eng_rounds, defer=False)
        m_new = _bench_engine(n, eng_rounds, defer=True)
        speedup = m_old["ms_per_round"] / m_new["ms_per_round"]
        _JSON_PAYLOAD[f"engine_fzoos_n{n}"] = {
            "inline_ms_per_round": m_old["ms_per_round"],
            "deferred_ms_per_round": m_new["ms_per_round"],
            "inline_rounds_per_sec": m_old["rounds_per_sec"],
            "deferred_rounds_per_sec": m_new["rounds_per_sec"],
            "speedup": speedup,
            "repair_rate": m_new["repair_rate"],
            "n_clients": n,
            "traj_capacity": ENGINE_CFG["traj_capacity"],
            "rounds_measured": eng_rounds,
        }
        for tag, m in (("inline", m_old), ("deferred", m_new)):
            rows.append(Row(
                name=f"engine_fzoos_{tag}_n{n}",
                us_per_call=m["ms_per_round"] * 1e3,
                derived=(f"rounds_per_sec={m['rounds_per_sec']:.2f};cap=128"
                         + (f";speedup={speedup:.2f}x;repair_rate={m['repair_rate']:.3f}"
                            if tag == "deferred" else "")),
            ))

    # -- partial participation: pooled N=256/K=64 vs dense K=64
    p = _bench_pool(POOL_N, POOL_K, rounds)
    _JSON_PAYLOAD[f"pool_n{POOL_N}_k{POOL_K}"] = p
    for tag in ("dense", "pooled"):
        rows.append(Row(
            name=f"pool_{tag}_n{POOL_N}_k{POOL_K}",
            us_per_call=p[f"{tag}_ms_per_round"] * 1e3,
            derived=(f"rounds_per_sec={p[f'{tag}_rounds_per_sec']:.1f}"
                     + (f";overhead={p['pool_overhead_ratio']:.2f}x;"
                        f"gather_scatter_msec={p['gather_scatter_msec']:.2f}"
                        if tag == "pooled" else "")),
        ))

    # -- chunk-boundary overhead: PR 3 host-sync boundary vs zero-sync
    b = _bench_boundary(64, 8 if quick else 16)
    _JSON_PAYLOAD["boundary_n64"] = b
    for tag in ("pr3_host", "zerosync"):
        rows.append(Row(
            name=f"boundary_{tag}_n64",
            us_per_call=b[f"{tag}_ms_per_boundary"] * 1e3,
            derived=(f"ckpt_prepare_msec={b['ckpt_prepare_msec']:.1f};"
                     f"ckpt_write_msec={b['ckpt_write_msec']:.1f};"
                     f"decide_host_usec={b['repair_decide_host_usec']:.0f};"
                     f"decide_device_usec={b['repair_decide_device_usec']:.0f}"),
        ))
    return rows
