"""Benchmark harness entry point (deliverable d).

One module per paper table/figure + the roofline table + kernel microbench.
Prints ``name,us_per_call,derived`` CSV per row.  Modules that expose a
``json_payload()`` hook additionally get their metrics serialized to
``BENCH_<name>.json`` next to this file, so the perf trajectory (e.g. the
surrogate-step speedup and factor_refactor_rate from the kernels module) is
machine-readable across PRs.

    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-sized)
    REPRO_BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --only fig1,roofline
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

MODULES = {
    "fig1": "benchmarks.fig1_synthetic",
    "fig2": "benchmarks.fig2_attack",
    "fig3": "benchmarks.fig3_metric",
    "fig4": "benchmarks.fig4_disparity",
    "fig5": "benchmarks.fig5_localsteps",
    "fig6": "benchmarks.fig6_features",
    "thm1": "benchmarks.thm1_rates",
    "kernels": "benchmarks.kernels_bench",
    "rounds": "benchmarks.rounds_bench",
    "roofline": "benchmarks.roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset of " + ",".join(MODULES))
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    quick = not (args.full or os.environ.get("REPRO_BENCH_FULL"))

    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(MODULES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        import importlib

        t0 = time.time()
        try:
            mod = importlib.import_module(MODULES[name])
            rows = mod.run(quick=quick)
            for row in rows:
                print(row.csv(), flush=True)
            payload = getattr(mod, "json_payload", lambda: None)()
            if payload:
                path = os.path.join(os.path.dirname(__file__), f"BENCH_{name}.json")
                with open(path, "w") as f:
                    json.dump(payload, f, indent=2, sort_keys=True)
                print(f"# {name}: wrote {path}", flush=True)
            print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name}: FAILED\n# " + traceback.format_exc().replace("\n", "\n# "),
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
