"""Benchmark harness entry point (deliverable d).

One module per paper table/figure + the roofline table + kernel microbench.
Prints ``name,us_per_call,derived`` CSV per row.  Modules that expose a
``json_payload()`` hook additionally get their metrics serialized to
``BENCH_<name>.json`` next to this file, so the perf trajectory (e.g. the
surrogate-step speedup and factor_refactor_rate from the kernels module) is
machine-readable across PRs.

    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-sized)
    REPRO_BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --only fig1,roofline

``--compare`` diffs the fresh run against the COMMITTED ``BENCH_*.json``
files instead of overwriting them, and exits nonzero on any perf metric
regressing by more than ``--compare-tol`` (default 20%) -- so perf claims
are checkable in CI without a dashboard.  Metric direction is inferred
from the key name: ``*_us`` / ``*ms_per_round`` are lower-is-better,
``*per_sec`` / ``*speedup`` are higher-is-better; everything else (shape
descriptors, rates, flags) is informational and ignored.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

MODULES = {
    "fig1": "benchmarks.fig1_synthetic",
    "fig2": "benchmarks.fig2_attack",
    "fig3": "benchmarks.fig3_metric",
    "fig4": "benchmarks.fig4_disparity",
    "fig5": "benchmarks.fig5_localsteps",
    "fig6": "benchmarks.fig6_features",
    "thm1": "benchmarks.thm1_rates",
    "kernels": "benchmarks.kernels_bench",
    "rounds": "benchmarks.rounds_bench",
    "roofline": "benchmarks.roofline",
    "faults": "benchmarks.faults_bench",
}


#: key-name suffix/substring -> metric direction for --compare.
_LOWER_BETTER = ("_us", "_ms", "ms_per_round", "ms_per_boundary")
_HIGHER_BETTER = ("per_sec", "speedup")

#: Keys that are DELIBERATELY informational: meaningful numbers we record
#: but refuse to gate on.  Rates move with workload shape, not perf; the
#: `_usec`/`_msec` spellings are machine-dependent wall-I/O timings; the
#: overhead ratio is already gated through its two ms_per_round parents.
#: Any direction-less key NOT matched here shows up in the ``ungated:``
#: summary that --compare prints per BENCH file, so silently-untracked
#: metrics are visible instead of vanishing from the regression gate.
_INFORMATIONAL = ("repair_rate", "refactor_rate", "drop_rate",
                  "quarantine_rate", "mask_overhead_ratio",
                  "pool_overhead_ratio", "_usec", "_msec")


def _metric_direction(key: str) -> str | None:
    """'lower' / 'higher' for perf metrics, None for informational values."""
    if any(key.endswith(s) for s in _LOWER_BETTER):
        return "lower"
    if any(s in key for s in _HIGHER_BETTER):
        return "higher"
    return None


def _is_informational(key: str) -> bool:
    return any(key.endswith(s) for s in _INFORMATIONAL)


def ungated_keys(payload: dict) -> list[str]:
    """Dotted keys of numeric leaves the regression gate ignores, split out
    from the explicit allowlist: ``['cap (!)', 'repair_rate']`` style, with
    ``(!)`` marking keys that are neither gated nor allowlisted."""
    out = []
    for key, _ in _walk_metrics(payload):
        leaf = key.rsplit(".", 1)[-1]
        if _metric_direction(leaf) is not None:
            continue
        out.append(key if _is_informational(leaf) else f"{key} (!)")
    return sorted(out)


def _walk_metrics(payload, prefix=""):
    """Yield (dotted_key, value) for every numeric leaf of a payload.

    The ``backend`` identity subtree holds only strings, so it never
    contributes metrics."""
    if isinstance(payload, dict):
        for k, v in payload.items():
            yield from _walk_metrics(v, f"{prefix}{k}.")
    elif isinstance(payload, (int, float)) and not isinstance(payload, bool):
        yield prefix.rstrip("."), float(payload)


def backend_identity() -> dict:
    """Stamp recorded into every BENCH_*.json: numbers from a CPU run and a
    TPU run are not comparable, so --compare refuses cross-backend diffs."""
    import jax

    return {
        "platform": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
    }


def compare_payload(name: str, fresh: dict, committed_path: str, tol: float) -> list[str]:
    """Regressions (> tol relative) of fresh vs the committed BENCH json."""
    if not os.path.exists(committed_path):
        return [f"{name}: no committed baseline at {committed_path}"]
    with open(committed_path) as f:
        committed = json.load(f)
    base_backend = (committed.get("backend") or {}).get("platform")
    fresh_backend = (fresh.get("backend") or {}).get("platform")
    if base_backend and fresh_backend and base_backend != fresh_backend:
        return [
            f"{name}: REFUSING cross-backend comparison -- committed baseline "
            f"is {base_backend} ({(committed['backend']).get('device_kind')}), "
            f"this run is {fresh_backend}; re-baseline on the matching backend"
        ]
    base = dict(_walk_metrics(committed))
    regressions = []
    for key, val in _walk_metrics(fresh):
        direction = _metric_direction(key.rsplit(".", 1)[-1])
        if direction is None or key not in base or base[key] <= 0:
            continue
        rel = val / base[key] - 1.0
        if (direction == "lower" and rel > tol) or (direction == "higher" and rel < -tol):
            regressions.append(
                f"{name}.{key}: {base[key]:.3g} -> {val:.3g} "
                f"({rel:+.1%}, {direction}-is-better)"
            )
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset of " + ",".join(MODULES))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--compare", action="store_true",
                    help="diff against committed BENCH_*.json (no overwrite); "
                         "exit nonzero on >tol perf regression")
    ap.add_argument("--compare-tol", type=float, default=0.2,
                    help="relative regression tolerance for --compare")
    args = ap.parse_args()
    quick = not (args.full or os.environ.get("REPRO_BENCH_FULL"))

    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(MODULES)
    print("name,us_per_call,derived")
    failures = 0
    regressions: list[str] = []
    for name in names:
        import importlib

        t0 = time.time()
        try:
            mod = importlib.import_module(MODULES[name])
            rows = mod.run(quick=quick)
            for row in rows:
                print(row.csv(), flush=True)
            payload = getattr(mod, "json_payload", lambda: None)()
            if payload:
                payload["backend"] = backend_identity()
                path = os.path.join(os.path.dirname(__file__), f"BENCH_{name}.json")
                if args.compare:
                    regs = compare_payload(name, payload, path, args.compare_tol)
                    regressions.extend(regs)
                    status = f"{len(regs)} regressions vs {path}" if regs else f"no regressions vs {path}"
                    print(f"# {name}: {status}", flush=True)
                    ungated = ungated_keys(payload)
                    if ungated:
                        print(f"# {name}: ungated: " + ", ".join(ungated), flush=True)
                else:
                    with open(path, "w") as f:
                        json.dump(payload, f, indent=2, sort_keys=True)
                    print(f"# {name}: wrote {path}", flush=True)
            print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name}: FAILED\n# " + traceback.format_exc().replace("\n", "\n# "),
                  flush=True)
    for r in regressions:
        print(f"# REGRESSION {r}", flush=True)
    if failures or regressions:
        sys.exit(1)


if __name__ == "__main__":
    main()
