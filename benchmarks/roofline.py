"""Roofline analysis (deliverable g): turn the dry-run artifacts into the
three roofline terms per (arch x shape) on the single-pod mesh.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        (197e12 bf16)
    memory term     = HLO_bytes_per_device / HBM_bw             (819e9 B/s)
    collective term = collective_bytes_per_device / link_bw     (50e9 B/s)

cost_analysis() runs on the post-SPMD module, so flops/bytes are already
per-device; the scan-undercount is fixed upstream by the depth-2/4 unrolled
extrapolation (launch/dryrun.py).  MODEL_FLOPS uses the classic 6*N*D for
training (N = active params, D = global tokens) and 2*N*D for inference
steps, divided across devices, so the useful-compute ratio exposes remat and
redundant work.
"""

from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import BACKEND_ROOFLINE, ICI_BW
from repro.models.model import INPUT_SHAPES

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
# Per-backend constants come from the shared table in launch/mesh.py -- the
# same numbers the kernel block autotuner keys on, so the bench-reported
# envelopes and the tuned block shapes can never disagree.  Roofline tables
# model the TPU target regardless of the host backend running the analysis.
_TPU = BACKEND_ROOFLINE["tpu"]
PEAK_FLOPS_BF16 = _TPU["peak_flops"]
HBM_BW = _TPU["hbm_bw"]
HBM_PER_CHIP = _TPU["hbm_bytes"]

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def model_flops_global(rec: dict) -> float:
    """Analytic useful flops for the step (global, all devices)."""
    sh = INPUT_SHAPES[rec["shape"]]
    n_active = rec.get("active_params") or rec.get("params") or 0
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n_active * tokens  # fwd+bwd
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sh["global_batch"]


def load_records(mesh: str = "pod16x16", art_dir: str | None = None, tag: str = "") -> list[dict]:
    out = []
    pattern = f"*__{mesh}{('__' + tag) if tag else ''}.json"
    for path in sorted(glob.glob(os.path.join(art_dir or ART_DIR, pattern))):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        if not tag and len(parts) != 3:
            continue  # skip tagged ablation artifacts in the main table
        with open(path) as f:
            out.append(json.load(f))
    return out


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "cost" not in rec:
        return None
    n_dev = rec["n_devices"]
    flops = rec["cost"].get("flops", 0.0)
    byts = rec["cost"].get("bytes accessed", 0.0)
    coll = rec.get("collectives", {}).get("total_bytes", 0.0)

    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops_global(rec) / n_dev
    useful = mf / flops if flops else 0.0

    mem = rec.get("memory", {})
    resident = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0) + mem.get(
        "output_size_in_bytes", 0
    )
    # arguments and outputs alias for params/cache in steady state; report both
    fits = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0) <= HBM_PER_CHIP

    hint = {
        "compute": "raise MXU utilization / cut remat recompute (flops-bound)",
        "memory": "cut HBM traffic: fuse attention/softmax, bf16 temps, larger blocks",
        "collective": "reshard to cut all-gathers (bigger per-device tiles) or overlap collectives",
    }[dominant]

    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": useful,
        "resident_bytes": resident,
        "fits_16g": fits,
        "hint": hint,
    }


def table(mesh: str = "pod16x16", art_dir: str | None = None) -> list[dict]:
    rows = []
    for rec in load_records(mesh, art_dir):
        a = analyze(rec)
        if a:
            rows.append(a)
        elif rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                         "dominant": "SKIP", "hint": rec.get("reason", "")})
        elif rec.get("status") == "error":
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                         "dominant": "ERROR", "hint": rec.get("error", "")[:90]})
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    return rows


def render_markdown(rows: list[dict]) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful | fits 16G |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["dominant"] in ("SKIP", "ERROR"):
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | {r['dominant']} | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {'Y' if r['fits_16g'] else 'N'} |"
        )
    return "\n".join(out)


def run(quick: bool = True):
    from benchmarks.common import Row

    rows = table()
    md = render_markdown(rows)
    os.makedirs(os.path.join(ART_DIR, ".."), exist_ok=True)
    with open(os.path.join(ART_DIR, "..", "roofline.md"), "w") as f:
        f.write(md + "\n")
    out = []
    for r in rows:
        if r["dominant"] in ("SKIP", "ERROR"):
            out.append(Row(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                           f"status={r['dominant']}"))
            continue
        dom_t = r[f"t_{r['dominant']}_s"]
        out.append(Row(
            name=f"roofline/{r['arch']}/{r['shape']}",
            us_per_call=dom_t * 1e6,  # modeled step time (dominant term)
            derived=(f"dominant={r['dominant']};compute_s={r['t_compute_s']:.3e};"
                     f"memory_s={r['t_memory_s']:.3e};collective_s={r['t_collective_s']:.3e};"
                     f"useful={r['useful_ratio']:.2f};fits16G={'Y' if r['fits_16g'] else 'N'}"),
        ))
    return out


if __name__ == "__main__":
    for row in run():
        print(row.csv())
