"""Paper Fig. 5/7/9: effect of the number T of local updates.

Thm. 2 prediction: at fixed eta, larger T improves communication efficiency
(fewer rounds to epsilon) with sub-linear gains (term G is T-independent).
"""

from __future__ import annotations

import jax

from benchmarks.common import Row, algo_config, best_f, rounds_to_target, run_algo
from repro.core import objectives as obj


def run(quick: bool = True) -> list[Row]:
    d, n = 40, 5
    rounds = 16 if quick else 30
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, n, d, 5.0, 0.001)
    f0 = float(obj.quadratic_global_value(cobjs, jax.numpy.full((d,), 0.5)))
    fstar = obj.quadratic_fstar(d)
    target = fstar + 0.35 * (f0 - fstar)
    rows = []
    for t_steps in (5, 10) if quick else (5, 10, 20):
        cfg = algo_config("fzoos", d, n, local_steps=t_steps,
                          n_features=256, traj_capacity=160)
        res, dt = run_algo(cfg, jax.random.PRNGKey(1), cobjs,
                           obj.quadratic_query, obj.quadratic_global_value, rounds)
        rows.append(Row(
            name=f"fig5/fzoos/T={t_steps}",
            us_per_call=dt / rounds * 1e6,
            derived=(f"bestF={best_f(res):+.4f};"
                     f"rounds_to_eps={rounds_to_target(res.f_values, target)};"
                     f"queries_total={int(res.queries[-1])}"),
        ))
    return rows
