"""Paper Fig. 6: (a) number M of random features; (b) adaptive vs fixed
gradient correction.

Thm. 2 prediction: larger M helps more when heterogeneity C is larger;
the adaptive gamma = 1/t beats fixed gamma = 1 when surrogate error along
the local horizon matters (Appx. C.3).
"""

from __future__ import annotations

import jax

from benchmarks.common import Row, algo_config, best_f, run_algo
from repro.core import objectives as obj
import dataclasses


def run(quick: bool = True) -> list[Row]:
    d, n = 40, 5
    rounds = 14 if quick else 30
    rows = []
    for c_het in (5.0, 50.0):
        key = jax.random.PRNGKey(0)
        cobjs = obj.make_quadratic(key, n, d, c_het, 0.001)
        # (a) M ablation
        for m in (64, 512):
            cfg = algo_config("fzoos", d, n, n_features=m, traj_capacity=160)
            res, dt = run_algo(cfg, jax.random.PRNGKey(1), cobjs,
                               obj.quadratic_query, obj.quadratic_global_value, rounds)
            rows.append(Row(
                name=f"fig6a/fzoos/C={c_het}/M={m}",
                us_per_call=dt / rounds * 1e6,
                derived=f"bestF={best_f(res):+.4f};lastF={float(res.f_values[-1]):+.4f}",
            ))
        # (b) adaptive (1/t) vs fixed (gamma = 1) correction length
        for mode, gconst, label in (("inv_t", 1.0, "adaptive_1_over_t"), ("const", 1.0, "fixed_1")):
            cfg = algo_config("fzoos", d, n, n_features=256, traj_capacity=160)
            cfg = dataclasses.replace(cfg, gamma_mode=mode, gamma_const=gconst)
            res, dt = run_algo(cfg, jax.random.PRNGKey(1), cobjs,
                               obj.quadratic_query, obj.quadratic_global_value, rounds)
            rows.append(Row(
                name=f"fig6b/fzoos/C={c_het}/{label}",
                us_per_call=dt / rounds * 1e6,
                derived=f"bestF={best_f(res):+.4f};lastF={float(res.f_values[-1]):+.4f}",
            ))
    return rows
