"""Paper Fig. 3 (+10-12): federated non-differentiable metric optimization
(1 - precision) under varying P.

CPU-scale reduction of Appx. E.3: Covertype stand-in tabular task, N=7
clients as in the paper, perturbing the trained MLP's output layer.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, algo_config
from repro.core import algorithms as alg
from repro.core import model_objectives as mobj

ALGOS = ("fzoos", "fedzo", "scaffold2")


def run(quick: bool = True) -> list[Row]:
    n_clients = 7
    rounds = 8 if quick else 20
    rows = []
    for p_shared in (0.6, 1.0):
        key = jax.random.PRNGKey(7)
        cobjs, d = mobj.make_metric_objective(key, n_clients=n_clients,
                                              p_shared=p_shared, n_eval=192)
        base = float(mobj.metric_global_value(cobjs, jnp.full((d,), 0.5)))
        for name in ALGOS:
            cfg = algo_config(name, d, n_clients, local_steps=5, eta=0.02,
                              n_features=256, traj_capacity=96,
                              active_per_iter=3, active_candidates=30,
                              active_round_end=3)
            t0 = time.time()
            res = alg.simulate(cfg, jax.random.PRNGKey(1), cobjs,
                               mobj.metric_query, mobj.metric_global_value, rounds)
            dt = time.time() - t0
            rows.append(Row(
                name=f"fig3/{name}/P={p_shared}",
                us_per_call=dt / rounds * 1e6,
                derived=(f"one_minus_precision_init={base:.4f};"
                         f"best={float(jnp.min(res.f_values)):.4f};"
                         f"queries={int(res.queries[-1])}"),
            ))
    return rows
