"""Paper Fig. 2: federated black-box adversarial attack success rate under
varying client heterogeneity P.

CPU-scale reduction of Appx. E.2: synthetic blob-image victims (no CIFAR in
the container), 8x8 images (d=64), 3 target images, N=6 clients,
P in {0.4, 0.8}.  Success = averaged margin < 0 (the paper's criterion).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, algo_config
from repro.core import algorithms as alg
from repro.core import model_objectives as mobj

ALGOS = ("fzoos", "fedzo", "scaffold2")


def run(quick: bool = True) -> list[Row]:
    """Success at a MATCHED per-client query budget (the paper's Fig. 2
    x-axis is queries): each algorithm gets as many rounds as the budget
    affords, so FZooS's per-round query thrift becomes extra rounds."""
    n_images = 2 if quick else 5
    n_clients = 6
    budget = 900 if quick else 2200
    rows = []
    for p_shared in (0.4, 0.8):
        for name in ALGOS:
            succ, queries, dt_total, rounds_used = 0, 0, 0.0, 0
            for img_i in range(n_images):
                key = jax.random.PRNGKey(100 + img_i)
                cobjs, _ = mobj.make_attack_objective(
                    key, n_clients=n_clients, p_shared=p_shared, side=8,
                    train_per_client=192,
                )
                d = int(cobjs.z.shape[-1])
                cfg = algo_config(name, d, n_clients, local_steps=5, eta=0.02,
                                  n_features=128, traj_capacity=96,
                                  active_per_iter=3, active_candidates=30,
                                  active_round_end=3)
                rounds = max(budget // cfg.queries_per_round(), 1)
                rounds_used = rounds
                t0 = time.time()
                res = alg.simulate(cfg, jax.random.PRNGKey(img_i), cobjs,
                                   mobj.attack_query, mobj.attack_global_value, rounds)
                dt_total += time.time() - t0
                if float(jnp.min(res.f_values)) < 0:
                    succ += 1
                queries += int(res.queries[-1])
            rows.append(Row(
                name=f"fig2/{name}/P={p_shared}",
                us_per_call=dt_total / max(rounds_used * n_images, 1) * 1e6,
                derived=(f"success_rate={succ / n_images:.2f};"
                         f"rounds={rounds_used};"
                         f"queries_per_client={queries // n_images}"),
            ))
    return rows
