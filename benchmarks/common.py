"""Shared helpers for the paper-figure benchmarks.

Each benchmark module exposes ``run(quick: bool) -> list[Row]``; run.py
aggregates them into the ``name,us_per_call,derived`` CSV contract.
Scale: CPU-sized reductions of the paper's settings (dims and rounds noted
per row so EXPERIMENTS.md can compare trends, not absolute numbers).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float  # wall microseconds per communication round (or call)
    derived: str  # headline metric(s), ';'-separated k=v

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def algo_config(
    name: str, dim: int, n_clients: int, *, local_steps=10, eta=0.005,
    q=20, fd_lambda=5e-3, n_features=512, traj_capacity=160,
    active_per_iter=5, active_candidates=50, active_round_end=5,
) -> alg.AlgoConfig:
    """Paper Appx. E settings adapted to the CPU-scale reproductions."""
    return alg.AlgoConfig(
        name=name, dim=dim, n_clients=n_clients, local_steps=local_steps,
        eta=eta, q=q, fd_lambda=fd_lambda, n_features=n_features,
        traj_capacity=traj_capacity, active_per_iter=active_per_iter,
        active_candidates=active_candidates, active_round_end=active_round_end,
        lengthscale=0.5, noise=1e-5,
    )


def run_algo(cfg, key, cobjs, query, global_value, rounds, diag=None):
    t0 = time.time()
    res = alg.simulate(cfg, key, cobjs, query, global_value, rounds,
                       diag_global_grad=diag)
    dt = time.time() - t0
    return res, dt


def rounds_to_target(f_values: jax.Array, target: float) -> int:
    """First round index where F <= target (or -1)."""
    hit = np.where(np.asarray(f_values) <= target)[0]
    return int(hit[0]) if len(hit) else -1


def queries_at_round(res, r: int) -> int:
    if r <= 0:
        return 0
    return int(res.queries[min(r, len(res.queries)) - 1])


def best_f(res) -> float:
    return float(jnp.min(res.f_values))
