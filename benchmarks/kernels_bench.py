"""Microbenchmarks for the surrogate hot loops at the paper's real-world
dims (Covertype: d=2189, M up to 1e4; trajectory windows 128-512).

On CPU the Pallas kernels execute via the jnp oracle path (interpret mode is
a correctness tool, not a perf path); the numbers here are the CPU substrate
baseline that the TPU kernels replace.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.kernels import ops


def _timeit(fn, *args, iters=5):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters


def run(quick: bool = True) -> list[Row]:
    key = jax.random.PRNGKey(0)
    cases = [
        ("covertype", 128, 2189, 1000),
        ("synthetic", 256, 300, 512),
    ]
    if not quick:
        cases.append(("covertype_bigM", 512, 2189, 10000))
    rows = []
    for label, n, d, m in cases:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        x = jax.random.normal(k1, (n, d))
        v = jax.random.normal(k2, (m, d))
        b = jax.random.uniform(k3, (m,), maxval=6.28)
        w = jax.random.normal(k4, (m,))

        t_feat = _timeit(jax.jit(lambda x, v, b: ops.rff_features(x, v, b)), x, v, b)
        t_grad = _timeit(jax.jit(lambda x, v, b, w: ops.rff_grad(x, v, b, w)), x, v, b, w)
        t_gram = _timeit(jax.jit(lambda a, c: ops.sqexp(a, c, 1.0)), x, x)

        flops_feat = 2 * n * d * m
        rows.append(Row(f"kernels/rff_features/{label}", t_feat * 1e6,
                        f"n={n};d={d};M={m};gflops={flops_feat / t_feat / 1e9:.2f}"))
        rows.append(Row(f"kernels/rff_grad/{label}", t_grad * 1e6,
                        f"n={n};d={d};M={m};gflops={2 * flops_feat / t_grad / 1e9:.2f}"))
        rows.append(Row(f"kernels/sqexp_gram/{label}", t_gram * 1e6,
                        f"n={n};d={d};gflops={2 * n * n * d / t_gram / 1e9:.2f}"))
    return rows
