"""Microbenchmarks for the surrogate hot loops at the paper's real-world
dims (Covertype: d=2189, M up to 1e4; trajectory windows 128-512).

On CPU the Pallas kernels execute via the jnp oracle path (interpret mode is
a correctness tool, not a perf path); the numbers here are the CPU substrate
baseline that the TPU kernels replace.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import gp_surrogate as gp
from repro.kernels import ops

#: filled by run(); run.py serializes it to BENCH_kernels.json.  The payload
#: sizes (cap=128, d=20, n_cand=100) are fixed regardless of quick/full mode
#: so the file stays comparable across PRs; "quick" is recorded anyway.
_JSON_PAYLOAD: dict = {}


def json_payload() -> dict:
    return _JSON_PAYLOAD


def _timeit(fn, *args, iters=5):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters


def _timeit_tree(fn, *args, iters=20):
    """Like _timeit for functions returning pytrees."""
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    out = None
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


# ---------------------------------------------------------------------------
# Per-local-step surrogate update: the seed's eigh-from-scratch path vs the
# incremental Gram-factor cache + fused scoring (ISSUE 1 tentpole).  One
# "step" is the full FZooS local-iteration surrogate workload: append the
# iterate, score n_cand actives, append them, then evaluate grad_mean --
# i.e. two factorization events and one candidate sweep.
# ---------------------------------------------------------------------------


def _surrogate_step_bench(cap=128, d=20, n_cand=100, n_act=5, lengthscale=1.0):
    hyper = gp.default_hyper(lengthscale, 1e-4)
    key = jax.random.PRNGKey(0)

    def step_seed(traj, x, k):
        traj = gp.traj_append(traj, x, jnp.sum(x))
        cands = gp.select_active_queries(k, traj, hyper, x, n_cand, n_act, 0.01)
        traj = gp.traj_append_batch(traj, cands, jnp.sum(cands, -1))
        g = gp.grad_mean(traj, hyper, x)
        return traj, jnp.clip(x - 0.01 * g, 0.0, 1.0)

    def step_cached(traj, factor, x, k):
        traj, factor = gp.traj_extend(traj, factor, x[None, :], jnp.sum(x)[None], hyper)
        cands = gp.select_active_queries_cached(k, traj, factor, hyper, x, n_cand, n_act, 0.01)
        traj, factor = gp.traj_extend(traj, factor, cands, jnp.sum(cands, -1), hyper)
        g = gp.grad_mean_cached(traj, factor, hyper, x)
        return traj, factor, jnp.clip(x - 0.01 * g, 0.0, 1.0)

    # warm (wrapped) trajectory: the steady-state regime of a long run
    xs0 = jax.random.uniform(key, (cap, d))
    traj = gp.traj_append_batch(gp.traj_init(cap, d), xs0, jnp.sum(xs0, -1))
    factor = gp.factor_init(traj, hyper)
    x0 = jnp.full((d,), 0.5)

    seed_j = jax.jit(step_seed)
    cached_j = jax.jit(step_cached)
    # Interleaved best-of-5: a shared-machine load spike then penalizes both
    # paths instead of whichever happened to be under the timer.
    t_seed, t_cached = float("inf"), float("inf")
    for _ in range(5):
        t_seed = min(t_seed, _timeit_tree(seed_j, traj, x0, key, iters=8))
        t_cached = min(t_cached, _timeit_tree(cached_j, traj, factor, x0, key, iters=8))

    # refactor rate over a realistic clustered run (radius-0.01 actives)
    tr, fa, x = traj, factor, x0
    for i in range(30):
        tr, fa, x = cached_j(tr, fa, x, jax.random.fold_in(key, i))
    rate = float(fa.n_refactors) / max(float(fa.n_updates), 1.0)
    return {
        "traj_capacity": cap,
        "dim": d,
        "n_candidates": n_cand,
        "active_per_iter": n_act,
        "seed_step_us": t_seed * 1e6,
        "cached_step_us": t_cached * 1e6,
        "speedup": t_seed / t_cached,
        "steps_per_sec_seed": 1.0 / t_seed,
        "steps_per_sec_cached": 1.0 / t_cached,
        "factor_refactor_rate": rate,
    }


def _client_batched_bench(cap=128, d=20, n_cand=100, lengthscale=1.0):
    """Client-batched scoring/grad kernels (ISSUE 3 tentpole c): one launch
    for the whole client batch vs N vmapped single-client launches, at
    N in {8, 64} clients and the paper's active-query shape."""
    from repro.kernels import ops

    key = jax.random.PRNGKey(2)
    out = {}
    for n_clients in (8, 64):
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, n_clients), 3)
        cands = jax.random.uniform(k1, (n_clients, n_cand, d))
        xs = jax.random.uniform(k2, (n_clients, cap, d))
        a = jax.random.normal(k3, (n_clients, cap, cap)) / jnp.sqrt(cap * 1.0)
        binv = jnp.einsum("bij,bkj->bik", a, a) + 0.1 * jnp.eye(cap)
        pmat = binv * jnp.einsum("bcd,bkd->bck", xs, xs)
        alpha = jax.random.normal(k1, (n_clients, cap))

        sc_vmapped = jax.jit(jax.vmap(
            lambda c, x, b, p: ops.uncertainty_scores(
                c, x, b, p, lengthscale=lengthscale, prior=float(d))
        ))
        sc_batched = jax.jit(lambda c, x, b, p: ops.uncertainty_scores_clients(
            c, x, b, p, lengthscale=lengthscale, prior=float(d)))
        gm_vmapped = jax.jit(jax.vmap(
            lambda c, x, al: ops.grad_mean_batch(c, x, al, lengthscale=lengthscale)
        ))
        gm_batched = jax.jit(lambda c, x, al: ops.grad_mean_clients(
            c, x, al, lengthscale=lengthscale))

        t_sc_v = t_sc_b = t_gm_v = t_gm_b = float("inf")
        # Interleaved best-of: the minimum of many alternating rounds is the
        # stable per-path cost on a shared 1-core box (a load spike then
        # penalizes both paths instead of whichever was under the timer).
        for _ in range(6):
            t_sc_v = min(t_sc_v, _timeit(sc_vmapped, cands, xs, binv, pmat, iters=20))
            t_sc_b = min(t_sc_b, _timeit(sc_batched, cands, xs, binv, pmat, iters=20))
            t_gm_v = min(t_gm_v, _timeit(gm_vmapped, cands, xs, alpha, iters=10))
            t_gm_b = min(t_gm_b, _timeit(gm_batched, cands, xs, alpha, iters=10))
        out[f"n{n_clients}"] = {
            "n_clients": n_clients, "cap": cap, "d": d, "n_candidates": n_cand,
            "scores_vmapped_us": t_sc_v * 1e6,
            "scores_batched_us": t_sc_b * 1e6,
            "scores_speedup": t_sc_v / t_sc_b,
            "grad_mean_vmapped_us": t_gm_v * 1e6,
            "grad_mean_batched_us": t_gm_b * 1e6,
            "grad_mean_speedup": t_gm_v / t_gm_b,
        }
    return out


def _tiled_bench(quick=True, d=20, n_cand=100, lengthscale=1.0):
    """Kernel scale-out (ISSUE 6 tentpole): vmapped vs batched vs
    batched-TILED scoring as the trajectory cap grows past VMEM residency.

    cap=128 fits resident (the tiled column equals the resident kernel);
    cap in {512, 1024} exercises the cap-axis grid.  On CPU the tiled
    column runs the Pallas kernel in INTERPRET mode -- a correctness/shape
    demonstration, not a perf path (``tiled_mode`` records which); vmapped
    and batched time the real CPU execution paths (textbook oracle vs the
    fused-epilogue contraction).  ``tiled_max_abs_diff`` is the parity
    check against the vmapped textbook path at the benched shape."""
    on_tpu = jax.default_backend() == "tpu"
    key = jax.random.PRNGKey(6)
    grid = [(8, 128), (8, 512), (8, 1024), (64, 128), (64, 512), (64, 1024)]
    if quick:
        grid.remove((64, 1024))  # ~10s/call in interpret mode; full-mode only
    out = {}
    for n_clients, cap in grid:
        k1, k2 = jax.random.split(jax.random.fold_in(key, n_clients * cap), 2)
        cands = jax.random.uniform(k1, (n_clients, n_cand, d))
        xs = jax.random.uniform(k2, (n_clients, cap, d))
        # Cheap SPD-shaped stand-in (a real Gram-inverse product at
        # N=64/cap=1024 costs ~137 GFLOP just to build).
        binv = jnp.broadcast_to(jnp.eye(cap) + 0.01, (n_clients, cap, cap))
        pmat = binv * jnp.einsum("bcd,bkd->bck", xs, xs)
        block_cap = cap if cap <= 128 else cap // 2

        sc_vmapped = jax.jit(jax.vmap(
            lambda c, x, b, p: ops.uncertainty_scores(
                c, x, b, p, lengthscale=lengthscale, prior=float(d))
        ))
        sc_batched = jax.jit(lambda c, x, b, p: ops.uncertainty_scores_clients(
            c, x, b, p, lengthscale=lengthscale, prior=float(d)))
        sc_tiled = jax.jit(lambda c, x, b, p: ops.uncertainty_scores_clients(
            c, x, b, p, lengthscale=lengthscale, prior=float(d),
            block_n=64, block_cap=block_cap, force_pallas=True))

        iters = {128: 10, 512: 4, 1024: 2}[cap]
        t_v = t_b = float("inf")
        for _ in range(2):  # interleaved best-of (shared-machine noise)
            t_v = min(t_v, _timeit(sc_vmapped, cands, xs, binv, pmat, iters=iters))
            t_b = min(t_b, _timeit(sc_batched, cands, xs, binv, pmat, iters=iters))
        # The interpret-mode tiled column costs seconds/call at large cap;
        # one timed pass is plenty for a correctness/visibility number.
        tile_iters = max(iters // 2, 1) if (on_tpu or cap <= 128) else 1
        t_t = _timeit(sc_tiled, cands, xs, binv, pmat, iters=tile_iters)
        diff = float(jnp.max(jnp.abs(
            sc_tiled(cands, xs, binv, pmat) - sc_vmapped(cands, xs, binv, pmat))))
        out[f"n{n_clients}_cap{cap}"] = {
            "n_clients": n_clients, "cap": cap, "d": d, "n_candidates": n_cand,
            "block_cap": block_cap,
            "tiled_mode": "compiled" if on_tpu else "interpret",
            "scores_vmapped_us": t_v * 1e6,
            "scores_batched_us": t_b * 1e6,
            "scores_tiled_us": t_t * 1e6,
            "batched_speedup": t_v / t_b,
            "tiled_max_abs_diff": diff,
        }
    return out


def _factor_primitive_bench(cap=128):
    """Decision-rule evidence (DESIGN.md Sec. 2.3): one blocked potrf vs one
    eigh vs one sequential-rotation cholupdate at ring capacity."""
    key = jax.random.PRNGKey(1)
    a = jax.random.normal(key, (cap, cap)) / jnp.sqrt(cap * 1.0)
    spd = a @ a.T + 0.1 * jnp.eye(cap)
    chol = jnp.linalg.cholesky(spd)
    xvec = 0.01 * jax.random.normal(key, (cap,))
    t_eigh = _timeit(jax.jit(lambda g: jnp.linalg.eigh(g)[0]), spd)
    t_potrf = _timeit(jax.jit(jnp.linalg.cholesky), spd)
    t_cholup = _timeit_tree(
        jax.jit(lambda L, x: gp.chol_rank1_update(L, x, 1.0, jnp.asarray(1e-6))[0]),
        chol, xvec,
    )
    return {
        "capacity": cap,
        "eigh_us": t_eigh * 1e6,
        "potrf_us": t_potrf * 1e6,
        "cholupdate_us": t_cholup * 1e6,
    }


def run(quick: bool = True) -> list[Row]:
    key = jax.random.PRNGKey(0)
    cases = [
        ("covertype", 128, 2189, 1000),
        ("synthetic", 256, 300, 512),
    ]
    if not quick:
        cases.append(("covertype_bigM", 512, 2189, 10000))
    rows = []
    for label, n, d, m in cases:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        x = jax.random.normal(k1, (n, d))
        v = jax.random.normal(k2, (m, d))
        b = jax.random.uniform(k3, (m,), maxval=6.28)
        w = jax.random.normal(k4, (m,))

        t_feat = _timeit(jax.jit(lambda x, v, b: ops.rff_features(x, v, b)), x, v, b)
        t_grad = _timeit(jax.jit(lambda x, v, b, w: ops.rff_grad(x, v, b, w)), x, v, b, w)
        t_gram = _timeit(jax.jit(lambda a, c: ops.sqexp(a, c, 1.0)), x, x)

        flops_feat = 2 * n * d * m
        rows.append(Row(f"kernels/rff_features/{label}", t_feat * 1e6,
                        f"n={n};d={d};M={m};gflops={flops_feat / t_feat / 1e9:.2f}"))
        rows.append(Row(f"kernels/rff_grad/{label}", t_grad * 1e6,
                        f"n={n};d={d};M={m};gflops={2 * flops_feat / t_grad / 1e9:.2f}"))
        rows.append(Row(f"kernels/sqexp_gram/{label}", t_gram * 1e6,
                        f"n={n};d={d};gflops={2 * n * n * d / t_gram / 1e9:.2f}"))

    # fused GP-surrogate kernels (active-query scoring / batched grad mean)
    cap, d, n = 128, 20, 100
    k1, k2 = jax.random.split(key)
    cands = jax.random.uniform(k1, (n, d))
    xs = jax.random.uniform(k2, (cap, d))
    binv = jnp.eye(cap) + 0.01
    pmat = binv * (xs @ xs.T)
    alpha = jax.random.normal(k1, (cap,))
    t_sc = _timeit(
        jax.jit(lambda c: ops.uncertainty_scores(c, xs, binv, pmat, lengthscale=1.0, prior=float(d))),
        cands,
    )
    t_gm = _timeit(jax.jit(lambda c: ops.grad_mean_batch(c, xs, alpha, lengthscale=1.0)), cands)
    rows.append(Row("kernels/uncertainty_scores/active100", t_sc * 1e6,
                    f"n={n};cap={cap};d={d}"))
    rows.append(Row("kernels/grad_mean_batch/active100", t_gm * 1e6,
                    f"n={n};cap={cap};d={d}"))

    # the per-step surrogate hot path (tentpole) + factor-primitive evidence
    step = _surrogate_step_bench()
    prim = _factor_primitive_bench()
    cb = _client_batched_bench()
    tiled = _tiled_bench(quick=quick)
    _JSON_PAYLOAD.clear()
    _JSON_PAYLOAD.update(
        {"surrogate_step": step, "factor_primitives": prim,
         "client_batched": cb, "tiled": tiled, "quick": bool(quick)}
    )
    for key_n, m in tiled.items():
        rows.append(Row(
            f"tiled/uncertainty_scores/{key_n}", m["scores_tiled_us"],
            f"vmapped_us={m['scores_vmapped_us']:.0f};batched_us={m['scores_batched_us']:.0f};"
            f"batched_speedup={m['batched_speedup']:.2f}x;block_cap={m['block_cap']};"
            f"mode={m['tiled_mode']};max_abs_diff={m['tiled_max_abs_diff']:.1e}"))
    for key_n, m in cb.items():
        rows.append(Row(
            f"client_batched/uncertainty_scores/{key_n}", m["scores_batched_us"],
            f"vmapped_us={m['scores_vmapped_us']:.0f};speedup={m['scores_speedup']:.2f}x;"
            f"cap={m['cap']};n_cand={m['n_candidates']}"))
        rows.append(Row(
            f"client_batched/grad_mean/{key_n}", m["grad_mean_batched_us"],
            f"vmapped_us={m['grad_mean_vmapped_us']:.0f};speedup={m['grad_mean_speedup']:.2f}x;"
            f"cap={m['cap']};n_cand={m['n_candidates']}"))
    rows.append(Row("surrogate_step/seed_eigh", step["seed_step_us"],
                    f"cap={step['traj_capacity']};d={step['dim']};steps_per_sec={step['steps_per_sec_seed']:.1f}"))
    rows.append(Row("surrogate_step/factor_cache", step["cached_step_us"],
                    f"cap={step['traj_capacity']};d={step['dim']};steps_per_sec={step['steps_per_sec_cached']:.1f};"
                    f"speedup={step['speedup']:.2f}x;refactor_rate={step['factor_refactor_rate']:.3f}"))
    rows.append(Row("factor_primitives/eigh", prim["eigh_us"], f"cap={prim['capacity']}"))
    rows.append(Row("factor_primitives/potrf", prim["potrf_us"], f"cap={prim['capacity']}"))
    rows.append(Row("factor_primitives/cholupdate", prim["cholupdate_us"],
                    f"cap={prim['capacity']};sequential-rotation rank-1"))
    return rows
