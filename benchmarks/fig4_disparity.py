"""Paper Fig. 4: gradient quality within one round -- cumulative mean cosine
similarity between ghat and grad F over T local iterations, per algorithm.

FZooS queries 1 + 5 active points per iteration vs Q+1 = 21 for the FD
baselines, yet should achieve the best alignment (the paper's Fig. 4 story).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Row, algo_config
from repro.core import algorithms as alg
from repro.core import objectives as obj

ALGOS = ("fzoos", "fedzo", "fedprox", "scaffold1", "scaffold2")


def run(quick: bool = True) -> list[Row]:
    d, n = 30, 5
    t_steps = 10 if quick else 20
    warm_rounds = 1  # surrogates/control variates need one round of history
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, n, d, 5.0, 0.001)
    diag = lambda x: obj.quadratic_global_grad(cobjs, x)
    # start away from the optimum (0.475 in unit coords) so grad F carries
    # signal and the cosine diagnostic is meaningful
    import jax.numpy as jnp
    x0 = jnp.full((d,), 0.85)
    rows = []
    for name in ALGOS:
        cfg = algo_config(name, d, n, local_steps=t_steps, n_features=256,
                          traj_capacity=160)
        t0 = time.time()
        res = alg.simulate(cfg, jax.random.PRNGKey(1), cobjs, obj.quadratic_query,
                           obj.quadratic_global_value, warm_rounds + 1, x0=x0,
                           diag_global_grad=diag)
        dt = time.time() - t0
        cos = float(np.asarray(res.mean_cos)[warm_rounds])  # measured round
        disp = float(np.asarray(res.mean_disparity)[warm_rounds])
        q_iter = cfg.queries_per_round() / t_steps
        rows.append(Row(
            name=f"fig4/{name}",
            us_per_call=dt / (warm_rounds + 1) * 1e6,
            derived=f"mean_cos={cos:+.3f};mean_disparity={disp:.4f};queries_per_iter={q_iter:.1f}",
        ))
    return rows
