"""Thm. 1 / Appx. D rate check: gradient-estimation error vs query budget.

Paper claim: the trajectory-informed surrogate's error contracts
(geometrically in the uncertainty, term (1) of Thm. 1) as queries accumulate,
while FD improves only at O(1/Q) **and carries an irreducible bias floor
Lambda** (Prop. D.1, eq. 86).  We measure ||estimate - grad f||^2 on one
client's quadratic at matched query budgets.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import fd as fdlib
from repro.core import gp_surrogate as gp
from repro.core import objectives as obj


def run(quick: bool = True) -> list[Row]:
    d = 20
    key = jax.random.PRNGKey(0)
    cobjs = obj.make_quadratic(key, 1, d, 0.0, noise_std=0.001)
    cp = jax.tree_util.tree_map(lambda a: a[0], cobjs)
    xq = jnp.full((d,), 0.5)
    true = obj.quadratic_grad(cp, xq)
    tn = float(jnp.linalg.norm(true))

    budgets = (16, 64, 256) if quick else (16, 64, 256, 1024)
    rows = []
    t0 = time.time()
    for n_q in budgets:
        # GP surrogate: n_q queries spread around the iterate (the
        # trajectory an FZooS client would accumulate locally)
        kq = jax.random.fold_in(key, n_q)
        xs = jnp.clip(xq + 0.05 * jax.random.normal(kq, (n_q, d)), 0, 1)
        ys = jax.vmap(lambda x, k: obj.quadratic_query(cp, x, k))(
            xs, jax.random.split(jax.random.fold_in(kq, 1), n_q)
        )
        traj = gp.traj_append_batch(gp.traj_init(n_q, d), xs, ys)
        hyper = gp.default_hyper(0.5, 1e-5)
        g_gp = gp.grad_mean(traj, hyper, xq)
        err_gp = float(jnp.sum((g_gp - true) ** 2))

        # FD with the same total budget: Q = n_q - 1 directions
        dirs = fdlib.sample_directions(jax.random.fold_in(key, 100 + n_q), n_q - 1, d)
        g_fd = fdlib.fd_grad(obj.quadratic_query, cp, xq,
                             jax.random.fold_in(key, 200 + n_q), dirs, 5e-3)
        err_fd = float(jnp.sum((g_fd - true) ** 2))

        rows.append(Row(
            name=f"thm1/queries={n_q}",
            us_per_call=(time.time() - t0) / len(rows or [1]) * 1e6,
            derived=(f"gp_err={err_gp:.5f};fd_err={err_fd:.5f};"
                     f"ratio={err_fd / max(err_gp, 1e-12):.1f};grad_norm2={tn * tn:.4f}"),
        ))
    return rows
